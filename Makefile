# Developer entry points. `make test` is the tier-1 verify command from
# ROADMAP.md; `make test-fast` deselects the paper-scale tests marked
# @pytest.mark.slow so the quick suite stays under a few minutes.
PY := PYTHONPATH=src python

.PHONY: test test-fast test-priv test-comm test-async test-serve \
	test-byz test-hier test-cov bench bench-round bench-serve bench-smoke

test:
	$(PY) -m pytest -x -q

test-fast:
	$(PY) -m pytest -q -m "not slow"

# quick iteration on the DP delta pipeline + property suite only
# (tests/test_privacy.py, tests/test_property.py, DESIGN.md §9)
test-priv:
	$(PY) -m pytest -q tests/test_privacy.py tests/test_property.py

# quick iteration on the delta-compression transport only
# (tests/test_compression.py + the codec properties, DESIGN.md §10)
test-comm:
	$(PY) -m pytest -q tests/test_compression.py tests/test_property.py

# quick iteration on the fault-tolerant asynchronous federation layer
# (availability simulator, fedbuff, degraded modes — DESIGN.md §11)
test-async:
	$(PY) -m pytest -q tests/test_availability.py tests/test_scan_engine.py

# quick iteration on the serving engine (prefix cache, continuous
# batching, int8 inference — DESIGN.md §12)
test-serve:
	$(PY) -m pytest -q tests/test_serving.py

# quick iteration on the Byzantine attack/defense layer (adversarial
# client simulator, krum/geomedian/norm-bound, stage pipeline —
# DESIGN.md §13)
test-byz:
	$(PY) -m pytest -q tests/test_adversary.py tests/test_property.py

# quick iteration on the client→edge→server hierarchy + the federation
# bugfix regression tests that rode along (DESIGN.md §14)
test-hier:
	$(PY) -m pytest -q tests/test_hierarchy.py tests/test_fedavg.py

# tier-1 suite under pytest-cov (the CI job uploads coverage.xml as a
# non-gating artifact; requires pytest-cov from requirements-dev.txt)
test-cov:
	$(PY) -m pytest -x -q --cov=repro --cov-report=term \
		--cov-report=xml:coverage.xml

bench-round:
	$(PY) -m benchmarks.bench_round

bench-serve:
	$(PY) -m benchmarks.bench_serve

# reduced-config benchmark pass for the CI smoke job: exercises every
# BENCH_*.json writer (round engine, aggregator sweep, attention
# fwd+bwd, DP delta pipeline, compressed transport, fault tolerance,
# Byzantine grid, hierarchy two-hop, serving engine) in a few minutes
bench-smoke:
	$(PY) -m benchmarks.bench_round --rounds 30 --agg-rounds 10 --reps 2 \
		--privacy --priv-rounds 30 --compress --comm-rounds 30 \
		--faults --async-rounds 30 --byzantine --byz-rounds 25 \
		--hierarchy --hier-rounds 30
	$(PY) -m benchmarks.bench_serve --requests 24 --train-rounds 5 \
		--reps 2 --rates 25,50,100

bench:
	$(PY) -m benchmarks.run
