# Developer entry points. `make test` is the tier-1 verify command from
# ROADMAP.md; `make test-fast` deselects the paper-scale tests marked
# @pytest.mark.slow so the quick suite stays under a few minutes.
PY := PYTHONPATH=src python

.PHONY: test test-fast bench bench-round

test:
	$(PY) -m pytest -x -q

test-fast:
	$(PY) -m pytest -q -m "not slow"

bench-round:
	$(PY) -m benchmarks.bench_round

# reduced-config benchmark pass for the CI smoke job: exercises every
# BENCH_*.json writer (round engine, aggregator sweep, attention
# fwd+bwd) in a few minutes
bench-smoke:
	$(PY) -m benchmarks.bench_round --rounds 30 --agg-rounds 10 --reps 2

bench:
	$(PY) -m benchmarks.run
