"""Beyond-paper ablations on the federated preference learner.

  PYTHONPATH=src python -m benchmarks.ablations [--rounds 200]

1. local epochs E in {1, 3, 6, 12} — communication/computation trade-off
   (paper fixes E=6);
2. client participation in {100%, 60%, 30%} per round (paper assumes
   full participation);
3. group heterogeneity (idiosyncrasy scale) in {0.1, 0.35, 1.0} —
   how non-IID-ness moves alignment and fairness.

Results append to results/ablations.json and print as CSV.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.configs import FedConfig, GPOConfig
from repro.core import FederatedGPO
from repro.core.fairness import convergence_round
from repro.data import SurveyConfig, make_survey_data, split_groups

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def run_one(rounds: int, seed: int = 0, local_epochs: int = 6,
            batch_groups: int = 0, idiosyncrasy: float = 0.35) -> dict:
    data = make_survey_data(SurveyConfig(seed=seed,
                                         idiosyncrasy=idiosyncrasy))
    tr, ev = split_groups(data, seed=seed)
    gcfg = GPOConfig(d_embed=data.phi.shape[-1], d_model=96, num_layers=3,
                     num_heads=4, d_ff=192)
    fcfg = FedConfig(num_clients=len(tr), rounds=rounds,
                     local_epochs=local_epochs, batch_groups=batch_groups,
                     eval_every=10, num_context=12, num_target=12,
                     seed=seed)
    fed = FederatedGPO(gcfg, fcfg, data, tr, ev)
    hist = fed.run(rounds=rounds)
    return {
        "local_epochs": local_epochs,
        "batch_groups": batch_groups or len(tr),
        "num_clients": len(tr),
        "idiosyncrasy": idiosyncrasy,
        "final_loss": hist.round_loss[-1],
        "convergence_round": convergence_round(np.asarray(hist.round_loss)),
        "final_as": hist.eval_mean_as[-1],
        "final_fi": hist.eval_fi[-1],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    args = ap.parse_args()
    rows = []
    print("ablation,value,conv_round,final_loss,final_AS,final_FI")
    for e in (1, 3, 6, 12):
        r = run_one(args.rounds, local_epochs=e)
        rows.append({"ablation": "local_epochs", **r})
        print(f"local_epochs,{e},{r['convergence_round']},"
              f"{r['final_loss']:.4f},{r['final_as']:.4f},"
              f"{r['final_fi']:.4f}", flush=True)
    for frac, bg in (("100%", 0), ("60%", 6), ("30%", 3)):
        r = run_one(args.rounds, batch_groups=bg)
        rows.append({"ablation": "participation", **r})
        print(f"participation,{frac},{r['convergence_round']},"
              f"{r['final_loss']:.4f},{r['final_as']:.4f},"
              f"{r['final_fi']:.4f}", flush=True)
    for het in (0.1, 0.35, 1.0):
        r = run_one(args.rounds, idiosyncrasy=het)
        rows.append({"ablation": "heterogeneity", **r})
        print(f"heterogeneity,{het},{r['convergence_round']},"
              f"{r['final_loss']:.4f},{r['final_as']:.4f},"
              f"{r['final_fi']:.4f}", flush=True)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "ablations.json"), "w") as f:
        json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
