"""Round-engine / aggregation / attention-grid perf benchmark.

Measures the three hot paths the fused federated engine touches and
writes ``BENCH_round.json`` (repo root):

1. **round_engine** — rounds/sec of ``FederatedGPO`` with the per-round
   Python loop driver (one jit dispatch + host sync per round, the seed
   behaviour) vs the fused ``lax.scan`` block driver (one dispatch per
   block, on-device metrics). Run on CPU with the paper's round
   structure — 17 groups split 10 train / 7 eval, 6 local epochs/round,
   eval every 10 rounds, 200 communication rounds — at benchmark model
   scale (the GPO predictor shrunk until a round is dispatch-bound,
   which is the regime the scan driver exists for; at paper model scale
   on accelerators the same dispatch tax returns because device rounds
   are fast).
2. **aggregation** — Eq. 3 on the (32, 1e6) flattened client matrix:
   jnp weighted-sum vs the Pallas ``fedavg_reduce`` kernel (GB/s), and
   the (C, P) flatten itself: legacy per-client Python-loop flatten vs
   the single vmapped tree-ravel (``tree_ravel_clients``).
3. **gpo_attention** — banded grid vs full predicated grid: visited-tile
   ratio (the O(S*m + S) claim at the grid level) and wall-clock in the
   t >> m eval regime (interpret mode on CPU).

A fourth section sweeps the server-aggregation registry (DESIGN.md §7)
— every strategy through the fused scan engine at the paper's round
structure — and writes ``BENCH_agg.json``: rounds/sec (the aggregation
subsystem's overhead over plain FedAvg) plus the final alignment score
and fairness index per strategy (the quality axes the strategies trade).

A fifth section benchmarks the TRAINING hot path — fwd+bwd attention,
dense jnp autodiff vs the banded custom-VJP kernels (DESIGN.md §8) —
and writes ``BENCH_attn.json``: fwd and bwd visited-tile counts (banded
strictly below the dense grid) and wall-clock at t >> m shapes.

A sixth section (``--privacy``) benchmarks the DP client-delta pipeline
(DESIGN.md §9) and writes ``BENCH_priv.json``: the (C, P) clip+reduce
micro-bench — baseline unclipped jnp reduce vs the jnp clip path vs the
fused Pallas ``agg_clip_reduce`` kernel — plus the engine-level
overhead (private vs baseline rounds/sec through the fused scan driver)
and the accountant's final ε.

A seventh section (``--compress``) benchmarks the delta-compression
transport (DESIGN.md §10) and writes ``BENCH_comm.json``: analytic
bytes-on-the-wire per codec, the COMPILED sharded-round all-gather byte
counts (none vs int8, via a subprocess ``dryrun --gpo-fed`` lowering —
the acceptance metric for the ~4× int8 collective saving), the fused
``agg_quant_clip_reduce`` kernel vs the jnp transport chain wall-clock,
and convergence (rounds-to-target-alignment) per codec with and without
error feedback — so the accuracy/communication tradeoff is measured,
not asserted.

An eighth section (``--faults``) benchmarks fault-tolerant asynchronous
federation (DESIGN.md §11) and writes ``BENCH_async.json``: convergence
(alignment-score curves + rounds-to-target + final/worst late training
loss) under client dropout ∈ {0, 0.2, 0.5} with a 70% straggler rate
bounded at 4 rounds of staleness, plain fedavg (staleness_power=0 —
stale arrivals at full weight) vs the staleness-aware buffered fedbuff,
plus the realized per-round survivor counts — the robustness/accuracy
tradeoff is measured, not asserted.

A ninth section (``--byzantine``) benchmarks Byzantine resilience
(DESIGN.md §13) and writes ``BENCH_byz.json``: the attack x defense
grid — {clean, sign_flip, scaled} x {fedavg, krum, geomedian} with
f = 3 of 10 clients corrupt — reporting per-cell alignment curves, tail
alignment, final loss, and a retention summary (attacked tail AS over
each defense's own clean tail AS), so the robustness claim is measured,
not asserted.

Interpret-mode honesty: on CPU the Pallas kernels run in interpret mode,
whose absolute timings are meaningless next to compiled jnp (≈1000x
slow). Every Pallas timing carries its ``mode``; cross-mode speedup
fields are only emitted on real hardware, and interpret-mode Pallas
wall-clocks are skipped unless ``--include-interpret`` is passed
(same-mode kernel-vs-kernel ratios, e.g. banded vs dense grid, are
always reported — the grid is what they measure). Skipped timings emit
a structured ``{"skipped": true, "reason": ...}`` block — never a bare
null or a prose-polluted mode string — so BENCH_*.json stays
machine-diffable across PRs.

CPU runtime knobs (set before jax import, override via env): the legacy
XLA:CPU runtime + single-thread eigen minimise per-op overhead for the
tiny-op graphs this benchmark times, and the ``rbg`` PRNG keeps key
derivation off the critical path. They apply to BOTH sides of every
comparison.

  PYTHONPATH=src python -m benchmarks.bench_round [--rounds 200]
"""
from __future__ import annotations

import os

os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_cpu_use_thunk_runtime=false --xla_cpu_multi_thread_eigen=false "
    "intra_op_parallelism_threads=1")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import functools
import json
import time

import jax

jax.config.update("jax_default_prng_impl", "rbg")

import jax.numpy as jnp
import numpy as np

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_round.json")
AGG_OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_agg.json")
ATTN_OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                             "BENCH_attn.json")
PRIV_OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                             "BENCH_priv.json")
COMM_OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                             "BENCH_comm.json")
ASYNC_OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                              "BENCH_async.json")
BYZ_OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_byz.json")
HIER_OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                             "BENCH_hier.json")


def _pallas_mode() -> str:
    """How Pallas kernels execute on this backend (tags every Pallas
    wall-clock so interpret numbers are never mistaken for native)."""
    return "native" if jax.default_backend() == "tpu" else "interpret"


def _skipped(reason: str) -> dict:
    """Structured skip marker: every intentionally-absent measurement is
    a ``{"skipped": true, "reason": ...}`` block instead of a bare null
    or a prose-polluted mode string, so BENCH_*.json diffs cleanly
    across PRs."""
    return {"skipped": True, "reason": reason}


_INTERPRET_SKIP = ("interpret-mode Pallas wall-clock is not comparable to "
                   "compiled jnp; pass --include-interpret to record it")
_CROSS_MODE_SKIP = ("cross-mode speedup (interpret Pallas vs compiled jnp) "
                    "is meaningless; only emitted on native hardware")


def _pallas_wall(t_pallas, t_jnp: float, gb: float, mode: str) -> dict:
    """The shared Pallas wall-clock entry: timing + same-mode speedup
    when measured, the structured skip block otherwise. One definition
    so the skip contract cannot drift between benchmark sections."""
    if not t_pallas:
        return {**_skipped(_INTERPRET_SKIP), "mode": mode}
    return {
        "mode": mode,
        "us": t_pallas * 1e6,
        "gbps": gb / t_pallas,
        # cross-mode speedups are only honest on native hardware
        "vs_jnp_speedup": (t_jnp / t_pallas if mode == "native"
                           else _skipped(_CROSS_MODE_SKIP)),
    }


def _best_of(fn, reps: int) -> float:
    """Best-of-``reps`` wall-clock seconds (min filters scheduler noise)."""
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out) if out is not None else None
        times.append(time.perf_counter() - t0)
    return min(times)


# ---------------------------------------------------------------------------
# 1. round engine: per-round loop vs fused scan
# ---------------------------------------------------------------------------
def bench_round_engine(rounds: int, reps: int = 5) -> dict:
    from repro.configs import FedConfig, GPOConfig
    from repro.core import FederatedGPO
    from repro.data import SurveyConfig, make_survey_data, split_groups

    data = make_survey_data(SurveyConfig(
        num_groups=17, num_questions=16, d_embed=4, seed=0))
    train_groups, eval_groups = split_groups(data, train_frac=0.6, seed=0)
    gcfg = GPOConfig(d_embed=4, d_model=8, num_layers=1, num_heads=1,
                     d_ff=16)
    fcfg = FedConfig(num_clients=len(train_groups), rounds=rounds,
                     local_epochs=6, eval_every=10, num_context=1,
                     num_target=1)

    result = {
        "rounds": rounds,
        "num_clients": int(len(train_groups)),
        "num_eval_groups": int(len(eval_groups)),
        "local_epochs": fcfg.local_epochs,
        "eval_every": fcfg.eval_every,
    }
    for engine in ("loop", "scan"):
        fed = FederatedGPO(gcfg, fcfg, data, train_groups, eval_groups)
        fed.run(rounds=rounds, engine=engine)  # compile + warm
        dt = _best_of(lambda: fed.run(rounds=rounds, engine=engine), reps)
        result[f"{engine}_rounds_per_sec"] = rounds / dt
        result[f"{engine}_wall_s"] = dt
        print(f"round_engine/{engine}: {rounds / dt:,.1f} rounds/s "
              f"({dt:.3f} s / {rounds} rounds)")
    result["scan_speedup"] = (result["scan_rounds_per_sec"]
                              / result["loop_rounds_per_sec"])
    print(f"round_engine/speedup: {result['scan_speedup']:.2f}x")
    return result


# ---------------------------------------------------------------------------
# 1b. aggregator sweep: every registry strategy through the scan engine
# ---------------------------------------------------------------------------
# hyperparameters chosen so each strategy actually exercises its
# mechanism (momentum/moments on, nonzero trim/prox/temperature)
AGG_SWEEP = {
    "fedavg": {},
    "fedavgm": {"momentum": 0.9, "server_lr": 1.0},
    "fedadam": {"beta1": 0.9, "beta2": 0.99, "tau": 1e-2,
                "server_lr": 1e-2},
    "fedyogi": {"beta1": 0.9, "beta2": 0.99, "tau": 1e-2,
                "server_lr": 1e-2},
    "fedprox": {"prox_mu": 0.01},
    "trimmed_mean": {"trim_frac": 0.1},
    "median": {},
    "adaptive": {"fair_temp": 1.0, "fair_decay": 0.9},
}


def bench_aggregators(rounds: int, reps: int = 3) -> dict:
    from repro.configs import AggConfig, FedConfig, GPOConfig
    from repro.core import FederatedGPO
    from repro.data import SurveyConfig, make_survey_data, split_groups

    data = make_survey_data(SurveyConfig(
        num_groups=17, num_questions=16, d_embed=4, seed=0))
    train_groups, eval_groups = split_groups(data, train_frac=0.6, seed=0)
    gcfg = GPOConfig(d_embed=4, d_model=8, num_layers=1, num_heads=1,
                     d_ff=16)

    result = {
        "rounds": rounds,
        "num_clients": int(len(train_groups)),
        "local_epochs": 6,
        "strategies": {},
    }
    for name, hp in AGG_SWEEP.items():
        fcfg = FedConfig(num_clients=len(train_groups), rounds=rounds,
                         local_epochs=6, eval_every=10, num_context=1,
                         num_target=1, agg=AggConfig(name=name, **hp))
        fed = FederatedGPO(gcfg, fcfg, data, train_groups, eval_groups)
        hist = fed.run(rounds=rounds)  # compile + warm
        dt = _best_of(lambda: fed.run(rounds=rounds), reps)
        entry = {
            "hyperparams": hp,
            "rounds_per_sec": rounds / dt,
            "wall_s": dt,
            "final_loss": hist.round_loss[-1],
            "final_mean_as": hist.eval_mean_as[-1],
            "final_fi": hist.eval_fi[-1],
        }
        result["strategies"][name] = entry
        print(f"agg_sweep/{name}: {rounds / dt:,.1f} rounds/s "
              f"AS={entry['final_mean_as']:.4f} FI={entry['final_fi']:.4f}")
    base = result["strategies"]["fedavg"]["rounds_per_sec"]
    for name, entry in result["strategies"].items():
        entry["throughput_vs_fedavg"] = entry["rounds_per_sec"] / base
    return result


# ---------------------------------------------------------------------------
# 2. aggregation: jnp vs Pallas reduce; loop vs vmapped flatten
# ---------------------------------------------------------------------------
def bench_aggregation(c: int = 32, p: int = 1_000_000, reps: int = 5,
                      include_interpret: bool = False) -> dict:
    from repro.core import fedavg_stacked, normalize_weights
    from repro.kernels import fedavg_reduce
    from repro.utils.pytree import tree_flatten_to_vector, tree_ravel_clients

    key = jax.random.PRNGKey(0)
    stacked = jax.random.normal(key, (c, p))
    w = normalize_weights(jnp.ones((c,)))
    gb = c * p * 4 / 1e9

    jnp_reduce = jax.jit(lambda s, w: fedavg_stacked({"x": s}, w)["x"])
    jnp_reduce(stacked, w)
    t_jnp = _best_of(lambda: jnp_reduce(stacked, w), reps)
    mode = _pallas_mode()
    # interpret-mode Pallas wall-clock vs compiled jnp is a meaningless
    # cross-mode comparison: skip it unless explicitly requested
    if mode == "native" or include_interpret:
        fedavg_reduce(stacked, w)
        t_pallas = _best_of(lambda: fedavg_reduce(stacked, w), reps)
    else:
        t_pallas = None

    # flatten path: a client-stacked tree with 1e6 params over 16 leaves
    leaves = 16
    tree = {f"w{i}": jax.random.normal(jax.random.fold_in(key, i),
                                       (c, p // leaves))
            for i in range(leaves)}

    def loop_flatten(t):  # the pre-refactor per-client Python loop
        return jnp.stack([
            tree_flatten_to_vector(jax.tree.map(lambda x: x[i], t))
            for i in range(c)])

    loop_fn = jax.jit(loop_flatten)
    vmap_fn = jax.jit(tree_ravel_clients)
    t0 = time.perf_counter()
    loop_fn(tree)
    t_loop_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    vmap_fn(tree)
    t_vmap_cold = time.perf_counter() - t0
    t_loop = _best_of(lambda: loop_fn(tree), reps)
    t_vmap = _best_of(lambda: vmap_fn(tree), reps)

    result = {
        "clients": c, "params": p,
        "jnp_reduce_us": t_jnp * 1e6,
        "jnp_reduce_gbps": gb / t_jnp,
        "pallas_reduce": _pallas_wall(t_pallas, t_jnp, gb, mode),
        "loop_flatten_us": t_loop * 1e6,
        "vmapped_flatten_us": t_vmap * 1e6,
        "flatten_speedup": t_loop / t_vmap,
        "loop_flatten_cold_s": t_loop_cold,
        "vmapped_flatten_cold_s": t_vmap_cold,
        "flatten_cold_speedup": t_loop_cold / t_vmap_cold,
    }
    pallas_str = (f"{gb / t_pallas:.2f} GB/s" if t_pallas else "skipped")
    print(f"aggregation/reduce: jnp {gb / t_jnp:.2f} GB/s, "
          f"pallas[{mode}] {pallas_str}")
    print(f"aggregation/flatten: loop {t_loop * 1e6:,.0f} us, "
          f"vmapped {t_vmap * 1e6:,.0f} us "
          f"({result['flatten_speedup']:.2f}x steady, "
          f"{result['flatten_cold_speedup']:.2f}x incl. trace+compile)")
    return result


# ---------------------------------------------------------------------------
# 3. GPO attention: banded vs full grid
# ---------------------------------------------------------------------------
def bench_gpo_grid(s: int = 512, m: int = 8, b: int = 32, h: int = 4,
                   hd: int = 32, reps: int = 3) -> dict:
    from repro.kernels import gpo_attention
    from repro.kernels.gpo_attention import gpo_tile_counts

    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (s, h, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (s, h, hd))
    banded_tiles, full_tiles = gpo_tile_counts(s, m, b, b)

    gpo_attention(q, k, v, num_ctx=m, bq=b, bk=b)
    t_banded = _best_of(
        lambda: gpo_attention(q, k, v, num_ctx=m, bq=b, bk=b), reps)
    gpo_attention(q, k, v, num_ctx=m, bq=b, bk=b, banded=False)
    t_full = _best_of(
        lambda: gpo_attention(q, k, v, num_ctx=m, bq=b, bk=b, banded=False),
        reps)

    result = {
        "seq": s, "num_ctx": m, "block": b, "heads": h,
        "banded_tiles": banded_tiles,
        "full_grid_tiles": full_tiles,
        "tiles_visited_ratio": banded_tiles / full_tiles,
        "banded_us": t_banded * 1e6,
        "full_grid_us": t_full * 1e6,
        # same-mode kernel-vs-kernel ratio: meaningful in either mode
        "wallclock_speedup": t_full / t_banded,
        "mode": _pallas_mode(),
    }
    print(f"gpo_grid: tiles {banded_tiles}/{full_tiles} "
          f"(ratio {result['tiles_visited_ratio']:.3f}), wall "
          f"{t_banded * 1e6:,.0f} vs {t_full * 1e6:,.0f} us "
          f"({result['wallclock_speedup']:.2f}x, {result['mode']})")
    return result


# ---------------------------------------------------------------------------
# 4. fwd+bwd attention: dense autodiff vs the banded custom-VJP kernels
# ---------------------------------------------------------------------------
ATTN_SHAPES = [
    # (s, m, block): the t >> m eval/train regimes the banded grid targets
    (512, 8, 32),
    (512, 32, 32),
    (256, 16, 32),
]


def bench_attn_fwd_bwd(h: int = 4, hd: int = 32, reps: int = 3,
                       include_interpret: bool = False) -> dict:
    """Training-hot-path benchmark (DESIGN.md §8): value_and_grad of a
    scalar loss through (a) the dense masked-softmax jnp path (what
    ``use_pallas_attention=False`` trains with), (b) the banded
    custom-VJP kernel, (c) the full predicated grid under the same
    custom VJP. Banded-vs-dense-grid is a same-mode comparison and is
    always reported; kernel-vs-jnp wall-clock only on real hardware."""
    from repro.kernels import gpo_attention
    from repro.kernels.gpo_attention import (
        gpo_tile_counts,
        gpo_tile_counts_bwd,
    )
    from repro.kernels.ref import ref_gpo_attention

    mode = _pallas_mode()
    result = {"heads": h, "head_dim": hd, "mode": mode, "shapes": []}
    for s, m, b in ATTN_SHAPES:
        key = jax.random.PRNGKey(2)
        q = jax.random.normal(key, (s, h, hd))
        k = jax.random.normal(jax.random.fold_in(key, 1), (s, h, hd))
        v = jax.random.normal(jax.random.fold_in(key, 2), (s, h, hd))
        cot = jax.random.normal(jax.random.fold_in(key, 3), (s, h, hd))

        def make_loss(attn):
            return jax.jit(jax.value_and_grad(
                lambda q, k, v: jnp.vdot(attn(q, k, v), cot),
                argnums=(0, 1, 2)))

        jnp_fn = make_loss(lambda q, k, v: ref_gpo_attention(
            q.transpose(1, 0, 2), k.transpose(1, 0, 2),
            v.transpose(1, 0, 2), num_ctx=m).transpose(1, 0, 2))
        banded_fn = make_loss(functools.partial(
            gpo_attention, num_ctx=m, bq=b, bk=b, banded=True))
        full_fn = make_loss(functools.partial(
            gpo_attention, num_ctx=m, bq=b, bk=b, banded=False))

        jnp_fn(q, k, v)
        t_jnp = _best_of(lambda: jnp_fn(q, k, v), reps)
        banded_fn(q, k, v)
        t_banded = _best_of(lambda: banded_fn(q, k, v), reps)
        full_fn(q, k, v)
        t_full = _best_of(lambda: full_fn(q, k, v), reps)

        fwd_banded, fwd_full = gpo_tile_counts(s, m, b, b)
        bwd_banded, bwd_full = gpo_tile_counts_bwd(s, m, b, b)
        if mode == "native" or include_interpret:
            pallas_wall = {
                "mode": mode,
                "banded_fwd_bwd_us": t_banded * 1e6,
                "dense_grid_fwd_bwd_us": t_full * 1e6,
                # cross-mode ratio: only honest when the kernels are
                # native
                "speedup_vs_jnp_dense": (t_jnp / t_banded
                                         if mode == "native"
                                         else _skipped(_CROSS_MODE_SKIP)),
            }
        else:
            pallas_wall = {**_skipped(_INTERPRET_SKIP), "mode": mode}
        entry = {
            "seq": s, "num_ctx": m, "num_tgt": s - m, "block": b,
            "fwd_tiles": {"banded": fwd_banded, "dense_grid": fwd_full},
            "bwd_tiles": {"banded": bwd_banded, "dense_grid": bwd_full},
            "fwd_bwd_tiles": {"banded": fwd_banded + bwd_banded,
                              "dense_grid": fwd_full + bwd_full},
            "tiles_visited_ratio": (fwd_banded + bwd_banded)
            / (fwd_full + bwd_full),
            "jnp_dense_fwd_bwd_us": t_jnp * 1e6,
            "pallas_wall": pallas_wall,
            # same-mode ratio (both sides run the identical custom-VJP
            # machinery; only the visited grid differs) — always honest
            "speedup_vs_dense_grid": t_full / t_banded,
        }
        result["shapes"].append(entry)
        print(f"attn_fwd_bwd s={s} m={m}: tiles "
              f"{entry['fwd_bwd_tiles']['banded']}/"
              f"{entry['fwd_bwd_tiles']['dense_grid']} "
              f"(ratio {entry['tiles_visited_ratio']:.3f}), banded "
              f"{entry['speedup_vs_dense_grid']:.2f}x vs dense grid "
              f"({mode})")
    return result


# ---------------------------------------------------------------------------
# 5. DP delta pipeline: clip+reduce kernel and engine-level overhead
# ---------------------------------------------------------------------------
def bench_privacy(rounds: int, c: int = 32, p: int = 1_000_000,
                  reps: int = 3, include_interpret: bool = False) -> dict:
    """Clipped-Pallas vs clipped-jnp vs unclipped baseline (DESIGN.md §9).

    Micro: the (C, P) flat-delta reduction — the unclipped jnp weighted
    sum (the pre-privacy hot path), the jnp clip+reduce
    (``clip_noise_reduce`` with use_pallas=False), and the fused
    ``agg_clip_reduce`` kernel. The fused kernel's wall-clock follows
    the interpret-honesty rule: timed (and compared to jnp) only when it
    lowers natively, tagged otherwise.

    Engine: rounds/sec of the fused scan driver with clip+noise on vs
    the non-private baseline, plus the Rényi accountant's ε after the
    run — the end-to-end price of the privacy axis.
    """
    from repro.configs import (AggConfig, FedConfig, GPOConfig,
                               PrivacyConfig)
    from repro.core import FederatedGPO
    from repro.core.privacy import clip_noise_reduce
    from repro.data import SurveyConfig, make_survey_data, split_groups
    from repro.kernels import agg_clip_reduce

    priv = PrivacyConfig(clip_norm=1.0, noise_multiplier=0.0)
    key = jax.random.PRNGKey(3)
    stacked = jax.random.normal(key, (c, p))
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (c,)))
    keys = jax.random.split(jax.random.fold_in(key, 2), c)
    gb = c * p * 4 / 1e9

    base_fn = jax.jit(lambda s, w: jnp.einsum("c,cp->p", w, s))
    base_fn(stacked, w)
    t_base = _best_of(lambda: base_fn(stacked, w), reps)
    jnp_fn = jax.jit(functools.partial(clip_noise_reduce, privacy=priv,
                                       use_pallas=False))
    jnp_fn(stacked, w, keys)
    t_jnp = _best_of(lambda: jnp_fn(stacked, w, keys), reps)
    mode = _pallas_mode()
    if mode == "native" or include_interpret:
        agg_clip_reduce(stacked, w, clip=priv.clip_norm)
        t_pal = _best_of(
            lambda: agg_clip_reduce(stacked, w, clip=priv.clip_norm), reps)
    else:
        t_pal = None

    result = {
        "clip_reduce": {
            "clients": c, "params": p, "clip": priv.clip_norm,
            "baseline_us": t_base * 1e6,
            "baseline_gbps": gb / t_base,
            "jnp_clip_us": t_jnp * 1e6,
            "jnp_clip_gbps": gb / t_jnp,
            "clip_overhead_vs_baseline": t_jnp / t_base,
            "pallas_clip": _pallas_wall(t_pal, t_jnp, gb, mode),
        },
    }
    pal_str = f"{gb / t_pal:.2f} GB/s" if t_pal else "skipped"
    print(f"privacy/clip_reduce: baseline {gb / t_base:.2f} GB/s, "
          f"jnp clip {gb / t_jnp:.2f} GB/s, pallas[{mode}] {pal_str}")

    # engine-level overhead at the round-engine benchmark's model scale
    data = make_survey_data(SurveyConfig(
        num_groups=17, num_questions=16, d_embed=4, seed=0))
    train_groups, eval_groups = split_groups(data, train_frac=0.6, seed=0)
    gcfg = GPOConfig(d_embed=4, d_model=8, num_layers=1, num_heads=1,
                     d_ff=16)
    engine = {"rounds": rounds}
    for label, pcfg in (
            ("baseline", PrivacyConfig()),
            ("private", PrivacyConfig(clip_norm=0.5,
                                      noise_multiplier=1.0))):
        fcfg = FedConfig(num_clients=len(train_groups), rounds=rounds,
                         local_epochs=6, eval_every=10, num_context=1,
                         num_target=1, agg=AggConfig(), privacy=pcfg)
        fed = FederatedGPO(gcfg, fcfg, data, train_groups, eval_groups)
        hist = fed.run(rounds=rounds)  # compile + warm
        dt = _best_of(lambda: fed.run(rounds=rounds), reps)
        engine[f"{label}_rounds_per_sec"] = rounds / dt
        if label == "private":
            engine["clip"] = pcfg.clip_norm
            engine["noise_multiplier"] = pcfg.noise_multiplier
            engine["final_eps"] = hist.round_eps[-1]
    engine["private_overhead_frac"] = (
        engine["baseline_rounds_per_sec"]
        / engine["private_rounds_per_sec"] - 1.0)
    result["round_engine"] = engine
    print(f"privacy/round_engine: baseline "
          f"{engine['baseline_rounds_per_sec']:,.1f} r/s, private "
          f"{engine['private_rounds_per_sec']:,.1f} r/s "
          f"({100 * engine['private_overhead_frac']:.1f}% overhead, "
          f"eps={engine['final_eps']:.2f})")
    return result


# ---------------------------------------------------------------------------
# 6. compressed transport: wire bytes, fused kernel, convergence
# ---------------------------------------------------------------------------
def _lower_comm_bytes(compress: str, agg: str = "median",
                      clients: int = 8, edges: int = 1) -> dict:
    """Compile the sharded round in a SUBPROCESS ``dryrun --gpo-fed`` and
    return its collective byte counts. A subprocess because the forced
    multi-device host platform must be set before jax import, which this
    process already spent on the benchmark flags."""
    import subprocess
    import sys
    import tempfile

    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
        path = f.name
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--gpo-fed",
           "--agg", agg, "--compress", compress, "--clients", str(clients),
           "--edges", str(edges), "--out", path]
    try:
        try:
            subprocess.run(cmd, check=True, capture_output=True, text=True,
                           timeout=900)
        except subprocess.CalledProcessError as e:
            # surface the actual XLA/JAX error, not just the exit status
            raise RuntimeError(
                f"dryrun exited {e.returncode}: "
                f"{(e.stderr or '').strip()[-500:]}") from e
        with open(path) as fh:
            return json.loads(fh.read().strip().splitlines()[-1])
    finally:
        if os.path.exists(path):
            os.unlink(path)


def bench_comm(rounds: int, c: int = 16, p: int = 262_144, reps: int = 3,
               topk_frac: float = 0.01, include_interpret: bool = False,
               skip_lower: bool = False) -> dict:
    """Delta-compression transport benchmark (DESIGN.md §10).

    Bytes: the analytic per-round client→server payload per codec, plus
    the COMPILED sharded-round all-gather bytes (robust family, none vs
    int8 — the collective the codec shrinks), both flat-parsed and
    trip-count-aware via ``launch/hlo_cost.py``.

    Wall-clock: the full (C, P) transport chain — DP release + EF +
    int8 codec + weighted reduce — as the fused
    ``agg_quant_clip_reduce`` kernel vs the jnp chain vs the
    uncompressed baseline reduce (interpret-honesty rule applies).

    Convergence: rounds-to-target-alignment per codec with and without
    error feedback against the uncompressed baseline, through the fused
    scan engine at the round-engine benchmark's model scale.
    """
    from repro.configs import (AggConfig, CompressionConfig, FedConfig,
                               GPOConfig, PrivacyConfig)
    from repro.core import FederatedGPO, make_aggregator
    from repro.core import compression as cmod
    from repro.data import SurveyConfig, make_survey_data, split_groups

    result = {}

    # -- analytic bytes-on-the-wire per round (client uploads) ----------
    k = cmod.topk_count(p, topk_frac)
    dense = 4 * c * p
    int8 = c * (p + 4)  # int8 payload + one f32 scale per client
    topk_logical = c * k * 8  # f32 value + int32 index per kept coord
    result["payload_bytes"] = {
        "clients": c, "params": p,
        "dense_f32": dense,
        "int8": int8,
        "int8_reduction": dense / int8,
        "topk_frac": topk_frac,
        "topk_kept_per_client": k,
        # what a sparse encoding would ship; the simulation (and the
        # sharded all-gather) keeps the dense f32 layout — recorded so
        # the gap between logical and simulated bytes is explicit
        "topk_logical": topk_logical,
        "topk_logical_reduction": dense / topk_logical,
    }
    print(f"comm/payload: dense {dense/1e6:.1f} MB, int8 {int8/1e6:.1f} MB "
          f"({dense/int8:.2f}x), topk logical {topk_logical/1e6:.2f} MB "
          f"({dense/topk_logical:.1f}x)")

    # -- compiled sharded all-gather bytes (the acceptance metric) ------
    if skip_lower:
        result["sharded_allgather"] = _skipped(
            "--skip-lower passed (subprocess dryrun lowering disabled)")
    else:
        try:
            lowered = {kind: _lower_comm_bytes(kind)
                       for kind in ("none", "int8")}
            ag = {kind: d["hlo_cost_collective_bytes_by_kind"].get(
                "all-gather", 0.0) for kind, d in lowered.items()}
            result["sharded_allgather"] = {
                "agg": "median", "clients": 8,
                "bytes_f32": ag["none"],
                "bytes_int8": ag["int8"],
                "reduction": ag["none"] / ag["int8"],
                "flat_hlo_bytes": {
                    kind: d["collective_bytes_by_kind"]
                    for kind, d in lowered.items()},
            }
            print(f"comm/sharded_allgather: f32 {ag['none']:,.0f} B -> "
                  f"int8 {ag['int8']:,.0f} B "
                  f"({ag['none'] / ag['int8']:.2f}x fewer)")
        except Exception as e:  # lowering is environment-sensitive
            result["sharded_allgather"] = _skipped(
                f"dryrun lowering failed: {type(e).__name__}: {e}")
            print(f"comm/sharded_allgather: skipped ({e})")

    # -- kernel vs jnp transport wall-clock -----------------------------
    priv = PrivacyConfig(clip_norm=1.0, noise_multiplier=0.5)
    comp = CompressionConfig(kind="int8")
    agg = make_aggregator(AggConfig(), num_clients=c)
    key = jax.random.PRNGKey(11)
    stacked = jax.random.normal(key, (c, p))
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (c,)))
    keys = jax.random.split(jax.random.fold_in(key, 2), c)
    resid = jnp.zeros((c, p), jnp.float32)
    gb = c * p * 4 / 1e9

    base_fn = jax.jit(lambda s, w: jnp.einsum("c,cp->p", w, s))
    base_fn(stacked, w)
    t_base = _best_of(lambda: base_fn(stacked, w), reps)
    jnp_fn = jax.jit(functools.partial(
        cmod.transport_delta_flat, privacy=priv, comp=comp, agg=agg,
        use_pallas=False))
    jnp_fn(stacked, w, keys, resid=resid)
    t_jnp = _best_of(lambda: jnp_fn(stacked, w, keys, resid=resid), reps)
    mode = _pallas_mode()
    if mode == "native" or include_interpret:
        # like-for-like with the jnp chain: the pallas transport also
        # samples its noise + rounding uniforms inside the timed call
        pal_fn = jax.jit(functools.partial(
            cmod.transport_delta_flat, privacy=priv, comp=comp, agg=agg,
            use_pallas=True))
        pal_fn(stacked, w, keys, resid=resid)
        t_pal = _best_of(
            lambda: pal_fn(stacked, w, keys, resid=resid), reps)
    else:
        t_pal = None
    result["transport_kernel"] = {
        "clients": c, "params": p, "clip": priv.clip_norm,
        "noise_multiplier": priv.noise_multiplier,
        "baseline_reduce_us": t_base * 1e6,
        "baseline_reduce_gbps": gb / t_base,
        "jnp_transport_us": t_jnp * 1e6,
        "jnp_transport_gbps": gb / t_jnp,
        "transport_overhead_vs_baseline": t_jnp / t_base,
        "pallas_fused": _pallas_wall(t_pal, t_jnp, gb, mode),
    }
    pal_str = f"{gb / t_pal:.2f} GB/s" if t_pal else "skipped"
    print(f"comm/transport: baseline {gb / t_base:.2f} GB/s, jnp chain "
          f"{gb / t_jnp:.2f} GB/s, fused pallas[{mode}] {pal_str}")

    # -- convergence: rounds to target alignment, EF on/off -------------
    data = make_survey_data(SurveyConfig(
        num_groups=17, num_questions=16, d_embed=4, seed=0))
    train_groups, eval_groups = split_groups(data, train_frac=0.6, seed=0)
    gcfg = GPOConfig(d_embed=4, d_model=8, num_layers=1, num_heads=1,
                     d_ff=16)
    sweep = {
        "none": CompressionConfig(),
        "int8_ef": CompressionConfig(kind="int8", error_feedback=True),
        "int8_noef": CompressionConfig(kind="int8", error_feedback=False),
        "topk_ef": CompressionConfig(kind="topk", topk_frac=topk_frac,
                                     error_feedback=True),
        "topk_noef": CompressionConfig(kind="topk", topk_frac=topk_frac,
                                       error_feedback=False),
    }
    runs = {}
    for label, ccfg in sweep.items():
        fcfg = FedConfig(num_clients=len(train_groups), rounds=rounds,
                         local_epochs=6, eval_every=5, num_context=1,
                         num_target=1, compression=ccfg)
        fed = FederatedGPO(gcfg, fcfg, data, train_groups, eval_groups)
        hist = fed.run(rounds=rounds)
        dt = _best_of(lambda: fed.run(rounds=rounds), max(1, reps - 1))
        runs[label] = (hist, rounds / dt)
    target = 0.98 * runs["none"][0].eval_mean_as[-1]
    conv = {"rounds": rounds, "target_mean_as": target}
    for label, (hist, rps) in runs.items():
        reached = [r for r, a in zip(hist.eval_rounds, hist.eval_mean_as)
                   if a >= target]
        conv[label] = {
            "final_mean_as": hist.eval_mean_as[-1],
            "final_loss": hist.round_loss[-1],
            "rounds_per_sec": rps,
            "rounds_to_target": (reached[0] if reached
                                 else _skipped("target alignment not "
                                               f"reached in {rounds} "
                                               "rounds")),
        }
        rt = conv[label]["rounds_to_target"]
        print(f"comm/convergence {label}: AS={hist.eval_mean_as[-1]:.4f} "
              f"rounds_to_target="
              f"{rt if isinstance(rt, int) else 'not reached'} "
              f"({rps:,.1f} r/s)")
    result["convergence"] = conv
    return result


def bench_async(rounds: int, reps: int = 2) -> dict:
    """Fault-tolerance benchmark (DESIGN.md §11): convergence under
    client dropout + stragglers, fedavg vs the staleness-aware fedbuff.

    For dropout ∈ {0, 0.2, 0.5} (online_prob = 1 − dropout, plus a 70%
    straggler rate bounded at 4 rounds of staleness) each strategy runs
    the fused scan engine.  The "fedavg" cell is the plain synchronous
    baseline — ``staleness_power=0`` so stale arrivals land at FULL
    weight, exactly the failure mode FedBuff's discounted buffering
    exists to fix.  The learning rate is deliberately aggressive
    (1e-2 × 6 local epochs) so the global model moves far enough per
    round that 4-round-stale full-weight deltas actually hurt;
    alignment score is evaluated on 4-context/4-target batches to cut
    eval noise.  Recorded per cell: the full AS curve, the tail AS
    (mean of the last 4 evals), final + worst second-half training
    loss (stale full-weight applies show up as late loss spikes),
    rounds-to-target against 0.95× the fault-free baseline's tail AS,
    realized survivor stats, and rounds/sec.  The tradeoff is
    measured, not asserted: at this scale both strategies reach the
    alignment target, and the separation shows up in the loss column —
    under 50% dropout fedbuff holds a lower and flatter training loss
    than plain fedavg.
    """
    from repro.configs import (AggConfig, AvailabilityConfig, FedConfig,
                               GPOConfig)
    from repro.core import FederatedGPO
    from repro.data import SurveyConfig, make_survey_data, split_groups

    data = make_survey_data(SurveyConfig(
        num_groups=17, num_questions=16, d_embed=4, seed=0))
    train_groups, eval_groups = split_groups(data, train_frac=0.6, seed=0)
    gcfg = GPOConfig(d_embed=4, d_model=8, num_layers=1, num_heads=1,
                     d_ff=16)
    max_staleness = 4
    straggler_prob = 0.7
    aggs = {
        "fedavg": AggConfig(name="fedavg", staleness_power=0.0),
        "fedbuff": AggConfig(name="fedbuff", buffer_k=2),
    }

    def run_cell(agg, avail):
        fcfg = FedConfig(num_clients=len(train_groups), rounds=rounds,
                         local_epochs=6, lr=1e-2, eval_every=5,
                         num_context=4, num_target=4, agg=agg,
                         avail=avail)
        fed = FederatedGPO(gcfg, fcfg, data, train_groups, eval_groups)
        hist = fed.run(rounds=rounds)
        dt = _best_of(lambda: fed.run(rounds=rounds), max(1, reps - 1))
        return hist, rounds / dt

    def tail_as(hist):
        tail = hist.eval_mean_as[-4:]
        return sum(tail) / len(tail)

    base_hist, base_rps = run_cell(aggs["fedavg"], AvailabilityConfig())
    target = 0.95 * tail_as(base_hist)
    result = {
        "rounds": rounds,
        "clients": len(train_groups),
        "max_staleness": max_staleness,
        "straggler_prob": straggler_prob,
        "target_mean_as": target,
        "baseline_fedavg_fault_free": {
            "tail_mean_as": tail_as(base_hist),
            "final_loss": base_hist.round_loss[-1],
            "rounds_per_sec": base_rps,
        },
    }
    print(f"async/baseline fedavg fault-free: "
          f"tailAS={tail_as(base_hist):.4f} ({base_rps:,.1f} r/s)")
    for dropout in (0.0, 0.2, 0.5):
        avail = AvailabilityConfig(online_prob=1.0 - dropout,
                                   crash_prob=0.05,
                                   straggler_prob=straggler_prob,
                                   max_staleness=max_staleness,
                                   rejoin_rounds=1)
        for name, agg in aggs.items():
            hist, rps = run_cell(agg, avail)
            reached = [r for r, a in zip(hist.eval_rounds,
                                         hist.eval_mean_as)
                       if a >= target]
            surv = hist.round_survivors
            late = hist.round_loss[len(hist.round_loss) // 2:]
            cell = {
                "dropout": dropout,
                "tail_mean_as": tail_as(hist),
                "final_mean_as": hist.eval_mean_as[-1],
                "final_loss": hist.round_loss[-1],
                "max_late_loss": max(late),
                "eval_rounds": list(hist.eval_rounds),
                "eval_mean_as": [round(a, 4)
                                 for a in hist.eval_mean_as],
                "rounds_per_sec": rps,
                "mean_survivors_per_round": (sum(surv) / len(surv)
                                             if surv else None),
                "zero_survivor_rounds": sum(1 for s in surv if s == 0),
                "rounds_to_target": (reached[0] if reached
                                     else _skipped("target alignment "
                                                   "not reached in "
                                                   f"{rounds} rounds")),
            }
            result[f"{name}_dropout_{dropout:g}"] = cell
            rt = cell["rounds_to_target"]
            print(f"async/{name} dropout={dropout:g}: "
                  f"tailAS={cell['tail_mean_as']:.4f} "
                  f"loss={cell['final_loss']:.4f}"
                  f"/max-late={cell['max_late_loss']:.4f} "
                  f"survivors/round={cell['mean_survivors_per_round']:.1f}"
                  f" rounds_to_target="
                  f"{rt if isinstance(rt, int) else 'not reached'} "
                  f"({rps:,.1f} r/s)")
    return result


def bench_byzantine(rounds: int, reps: int = 2) -> dict:
    """Byzantine attack x defense grid (DESIGN.md §13): convergence of
    the fused scan engine under adversarial clients, plain fedavg vs the
    robust defenses.

    10 train clients, f = 3 attackers (< C/2 - 1, inside every defense's
    breakdown point); attacks ∈ {clean, sign_flip, scaled λ=30};
    defenses ∈ {fedavg, krum, geomedian}.  Same tiny-GPO round structure
    as the §11 fault bench so rounds are dispatch-cheap.  The horizon is
    deliberately SHORT (default 25 rounds): sign_flip at f = 3/10 cuts
    the mean update to 0.4× (it slows convergence rather than reversing
    it) and scaled model-replacement self-limits once honest deltas
    shrink, so at long horizons undefended fedavg quietly recovers and
    the grid measures nothing.  Recorded per cell: the AS curve, the
    tail AS (mean of the last 4 evals), final loss, and rounds/sec.
    The acceptance claim — krum/geomedian hold tail alignment within 5%
    of the clean run under both model-poisoning attacks while
    undefended fedavg degrades — is derived in the emitted ``summary``
    block (tail AS over the clean undefended fedavg baseline, the
    natural control every cell shares), measured, not asserted.
    """
    from repro.configs import (AdversaryConfig, AggConfig, FedConfig,
                               GPOConfig)
    from repro.core import FederatedGPO
    from repro.data import SurveyConfig, make_survey_data, split_groups

    data = make_survey_data(SurveyConfig(
        num_groups=17, num_questions=16, d_embed=4, seed=0))
    train_groups, eval_groups = split_groups(data, train_frac=0.6, seed=0)
    gcfg = GPOConfig(d_embed=4, d_model=8, num_layers=1, num_heads=1,
                     d_ff=16)
    c = len(train_groups)
    f = 3  # < C/2 - 1 for C = 10: inside krum's f <= (C-3)/2 breakdown
    scale = 30.0
    attacks = {
        "clean": AdversaryConfig(),
        "sign_flip": AdversaryConfig(kind="sign_flip", num_attackers=f),
        "scaled": AdversaryConfig(kind="scaled", num_attackers=f,
                                  scale=scale),
    }
    defenses = {
        "fedavg": AggConfig(name="fedavg"),
        "krum": AggConfig(name="krum", num_malicious=f),
        "geomedian": AggConfig(name="geomedian"),
    }

    def tail_as(hist):
        tail = hist.eval_mean_as[-4:]
        return sum(tail) / len(tail)

    def run_cell(adv, agg):
        fcfg = FedConfig(num_clients=c, rounds=rounds, local_epochs=6,
                         lr=1e-2, eval_every=5, num_context=4,
                         num_target=4, agg=agg, adversary=adv)
        fed = FederatedGPO(gcfg, fcfg, data, train_groups, eval_groups)
        hist = fed.run(rounds=rounds)
        dt = _best_of(lambda: fed.run(rounds=rounds), max(1, reps - 1))
        return hist, rounds / dt

    result = {"rounds": rounds, "clients": c, "attackers": f,
              "attack_scale": scale}
    grid = {}
    for aname, adv in attacks.items():
        for dname, agg in defenses.items():
            hist, rps = run_cell(adv, agg)
            cell = {
                "tail_mean_as": tail_as(hist),
                "final_mean_as": hist.eval_mean_as[-1],
                "final_loss": hist.round_loss[-1],
                "eval_rounds": list(hist.eval_rounds),
                "eval_mean_as": [round(a, 4) for a in hist.eval_mean_as],
                "rounds_per_sec": rps,
            }
            grid[f"{aname}|{dname}"] = cell
            print(f"byz/{aname} x {dname}: "
                  f"tailAS={cell['tail_mean_as']:.4f} "
                  f"loss={cell['final_loss']:.4f} ({rps:,.1f} r/s)")
    result["grid"] = grid

    # acceptance summary: per-cell tail retention relative to the clean
    # undefended fedavg baseline (the control every cell shares)
    baseline = grid["clean|fedavg"]["tail_mean_as"]
    summary = {"baseline_clean_fedavg_tail_as": baseline}
    for dname in defenses:
        for aname in attacks:
            if aname == "clean":
                continue
            att = grid[f"{aname}|{dname}"]["tail_mean_as"]
            summary[f"{dname}_retention_{aname}"] = att / baseline
    result["summary"] = summary
    for k, v in sorted(summary.items()):
        print(f"byz/summary {k}: {v:.4f}")
    return result


def bench_hierarchy(rounds: int, reps: int = 2,
                    skip_lower: bool = False) -> dict:
    """Two-level client→edge→server aggregation (DESIGN.md §14).

    Bytes: the COMPILED sharded-round collective schedule, flat vs the
    ('edge', 'data') two-hop mesh, read per-op from the optimized HLO
    via ``launch/hlo_cost.py``: the robust family's O(C·P) all-gather
    splits into an intra-edge (C/E)·P hop plus a cross-edge E·P hop,
    and with the §10 int8 codec the cross-edge hop shrinks 4x again
    (multiplicative). The linear family's all-reduce total is recorded
    unchanged — a torus all-reduce already IS the composed two-hop
    schedule.

    Wall-clock: the stacked scan engine, flat vs E={2, 4} median over
    8 clients (same tiny-GPO round structure as the §11/§13 benches).
    On one host this measures the Python-loop edge pre-reduce overhead,
    not a network win — the byte section is where the topology pays.

    Equivalence: the linear E=2 run's final loss against the flat run
    (reassociation-level agreement), measured, not asserted.
    """
    from repro.configs import (AggConfig, CompressionConfig, FedConfig,
                               GPOConfig, HierarchyConfig)
    from repro.core import FederatedGPO
    from repro.data import SurveyConfig, make_survey_data, split_groups

    result = {}

    # -- compiled two-hop collective bytes (subprocess dryrun --edges) --
    if skip_lower:
        result["lowered"] = _skipped("--skip-lower")
    else:
        def payload_gathers(r):
            return sorted(b * m for k, b, m in r["collective_ops"]
                          if k == "all-gather" and b * m >= 1024)

        med_flat = _lower_comm_bytes("none", agg="median", clients=8)
        med_hier = _lower_comm_bytes("none", agg="median", clients=8,
                                     edges=4)
        int8_hier = _lower_comm_bytes("int8", agg="median", clients=8,
                                      edges=4)
        avg_flat = _lower_comm_bytes("none", agg="fedavg", clients=8)
        avg_hier = _lower_comm_bytes("none", agg="fedavg", clients=8,
                                     edges=4)
        [flat_ag] = payload_gathers(med_flat)
        hier_ags = payload_gathers(med_hier)
        cross = max(hier_ags)
        int8_cross = min(payload_gathers(int8_hier))
        result["lowered"] = {
            "clients": 8, "edges": 4,
            "robust_flat_all_gather_bytes": flat_ag,
            "robust_two_hop_all_gather_bytes": hier_ags,
            "cross_edge_bytes": cross,
            "cross_edge_reduction": flat_ag / cross,
            "two_hop_total_reduction": flat_ag / sum(hier_ags),
            "int8_cross_edge_bytes": int8_cross,
            "int8_cross_edge_reduction": flat_ag / int8_cross,
            "linear_all_reduce_flat": avg_flat[
                "collective_bytes_by_kind"].get("all-reduce", 0),
            "linear_all_reduce_two_hop": avg_hier[
                "collective_bytes_by_kind"].get("all-reduce", 0),
        }
        print(f"hier/lowered: flat gather {flat_ag:,.0f} B -> two-hop "
              f"{hier_ags} B (cross-edge {flat_ag / cross:.1f}x smaller,"
              f" int8 cross-edge {flat_ag / int8_cross:.1f}x)")

    # -- stacked engine wall-clock + linear equivalence -----------------
    data = make_survey_data(SurveyConfig(
        num_groups=13, num_questions=16, d_embed=4, seed=0))
    train_groups, eval_groups = split_groups(data, seed=0)  # 8 train
    gcfg = GPOConfig(d_embed=4, d_model=8, num_layers=1, num_heads=1,
                     d_ff=16)

    def run_cell(agg, num_edges):
        fcfg = FedConfig(num_clients=len(train_groups), rounds=rounds,
                         local_epochs=6, eval_every=max(10, rounds),
                         num_context=1, num_target=1, agg=agg,
                         compression=CompressionConfig(
                             kind="none", error_feedback=False),
                         hierarchy=HierarchyConfig(num_edges=num_edges))
        fed = FederatedGPO(gcfg, fcfg, data, train_groups, eval_groups)
        hist = fed.run(rounds=rounds, engine="scan")  # compile + warm
        dt = _best_of(lambda: fed.run(rounds=rounds, engine="scan"),
                      reps)
        return hist, rounds / dt

    result["rounds"] = rounds
    result["clients"] = int(len(train_groups))
    for name, edges in (("median_flat", 1), ("median_e2", 2),
                        ("median_e4", 4), ("fedavg_flat", 1),
                        ("fedavg_e2", 2)):
        agg = AggConfig(name=name.split("_")[0])
        hist, rps = run_cell(agg, edges)
        result[name] = {"edges": edges, "rounds_per_sec": rps,
                        "final_loss": hist.round_loss[-1]}
        print(f"hier/{name}: {rps:,.1f} rounds/s "
              f"loss={hist.round_loss[-1]:.4f}")
    result["linear_e2_final_loss_drift"] = abs(
        result["fedavg_e2"]["final_loss"]
        - result["fedavg_flat"]["final_loss"])
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--agg-rounds", type=int, default=100,
                    help="rounds per strategy in the aggregator sweep")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--skip-agg", action="store_true",
                    help="skip the aggregator sweep / BENCH_agg.json")
    ap.add_argument("--skip-attn", action="store_true",
                    help="skip the fwd+bwd attention benchmark / "
                         "BENCH_attn.json (the slowest section in "
                         "interpret mode; quick round-engine iteration)")
    ap.add_argument("--privacy", action="store_true",
                    help="also run the DP delta-pipeline benchmark and "
                         "write BENCH_priv.json (DESIGN.md §9)")
    ap.add_argument("--priv-rounds", type=int, default=100,
                    help="rounds per engine config in the privacy bench")
    ap.add_argument("--compress", action="store_true",
                    help="also run the delta-compression transport "
                         "benchmark and write BENCH_comm.json "
                         "(DESIGN.md §10)")
    ap.add_argument("--comm-rounds", type=int, default=60,
                    help="rounds per codec config in the compression "
                         "convergence sweep")
    ap.add_argument("--faults", action="store_true",
                    help="also run the fault-tolerance benchmark "
                         "(dropout x {fedavg, fedbuff}) and write "
                         "BENCH_async.json (DESIGN.md §11)")
    ap.add_argument("--async-rounds", type=int, default=80,
                    help="rounds per cell in the fault-tolerance sweep")
    ap.add_argument("--byzantine", action="store_true",
                    help="also run the Byzantine attack x defense grid "
                         "and write BENCH_byz.json (DESIGN.md §13)")
    ap.add_argument("--byz-rounds", type=int, default=25,
                    help="rounds per cell in the Byzantine grid (kept "
                         "short on purpose — see bench_byzantine)")
    ap.add_argument("--hierarchy", action="store_true",
                    help="also run the client→edge→server hierarchy "
                         "benchmark and write BENCH_hier.json "
                         "(DESIGN.md §14)")
    ap.add_argument("--hier-rounds", type=int, default=30,
                    help="rounds per cell in the hierarchy wall-clock "
                         "sweep")
    ap.add_argument("--skip-lower", action="store_true",
                    help="skip the subprocess dryrun lowering in the "
                         "compression bench (the compiled all-gather "
                         "byte counts)")
    ap.add_argument("--include-interpret", action="store_true",
                    help="also time Pallas kernels in interpret mode on "
                         "CPU (absolute numbers are NOT comparable to "
                         "compiled jnp; tagged mode=interpret)")
    args = ap.parse_args()

    report = {
        "backend": jax.default_backend(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "prng": "rbg",
        "round_engine": bench_round_engine(args.rounds, args.reps),
        "aggregation": bench_aggregation(
            reps=args.reps, include_interpret=args.include_interpret),
        "gpo_attention": bench_gpo_grid(),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {os.path.abspath(OUT_PATH)}")

    if not args.skip_attn:
        attn_report = {
            "backend": jax.default_backend(),
            "xla_flags": os.environ.get("XLA_FLAGS", ""),
            "prng": "rbg",
            "attn_fwd_bwd": bench_attn_fwd_bwd(
                reps=args.reps, include_interpret=args.include_interpret),
        }
        with open(ATTN_OUT_PATH, "w") as f:
            json.dump(attn_report, f, indent=2)
        print(f"wrote {os.path.abspath(ATTN_OUT_PATH)}")

    if args.privacy:
        priv_report = {
            "backend": jax.default_backend(),
            "xla_flags": os.environ.get("XLA_FLAGS", ""),
            "prng": "rbg",
            "privacy": bench_privacy(
                args.priv_rounds, reps=min(args.reps, 3),
                include_interpret=args.include_interpret),
        }
        with open(PRIV_OUT_PATH, "w") as f:
            json.dump(priv_report, f, indent=2)
        print(f"wrote {os.path.abspath(PRIV_OUT_PATH)}")

    if args.compress:
        comm_report = {
            "backend": jax.default_backend(),
            "xla_flags": os.environ.get("XLA_FLAGS", ""),
            "prng": "rbg",
            "comm": bench_comm(
                args.comm_rounds, reps=min(args.reps, 3),
                include_interpret=args.include_interpret,
                skip_lower=args.skip_lower),
        }
        with open(COMM_OUT_PATH, "w") as f:
            json.dump(comm_report, f, indent=2)
        print(f"wrote {os.path.abspath(COMM_OUT_PATH)}")

    if args.faults:
        async_report = {
            "backend": jax.default_backend(),
            "xla_flags": os.environ.get("XLA_FLAGS", ""),
            "prng": "rbg",
            "async": bench_async(args.async_rounds,
                                 reps=min(args.reps, 2)),
        }
        with open(ASYNC_OUT_PATH, "w") as f:
            json.dump(async_report, f, indent=2)
        print(f"wrote {os.path.abspath(ASYNC_OUT_PATH)}")

    if args.byzantine:
        byz_report = {
            "backend": jax.default_backend(),
            "xla_flags": os.environ.get("XLA_FLAGS", ""),
            "prng": "rbg",
            "byzantine": bench_byzantine(args.byz_rounds,
                                         reps=min(args.reps, 2)),
        }
        with open(BYZ_OUT_PATH, "w") as f:
            json.dump(byz_report, f, indent=2)
        print(f"wrote {os.path.abspath(BYZ_OUT_PATH)}")

    if args.hierarchy:
        hier_report = {
            "backend": jax.default_backend(),
            "xla_flags": os.environ.get("XLA_FLAGS", ""),
            "prng": "rbg",
            "hierarchy": bench_hierarchy(args.hier_rounds,
                                         reps=min(args.reps, 2),
                                         skip_lower=args.skip_lower),
        }
        with open(HIER_OUT_PATH, "w") as f:
            json.dump(hier_report, f, indent=2)
        print(f"wrote {os.path.abspath(HIER_OUT_PATH)}")

    if not args.skip_agg:
        agg_report = {
            "backend": jax.default_backend(),
            "xla_flags": os.environ.get("XLA_FLAGS", ""),
            "prng": "rbg",
            "agg_sweep": bench_aggregators(args.agg_rounds,
                                           min(args.reps, 3)),
        }
        with open(AGG_OUT_PATH, "w") as f:
            json.dump(agg_report, f, indent=2)
        print(f"wrote {os.path.abspath(AGG_OUT_PATH)}")


if __name__ == "__main__":
    main()
