"""Serving-engine benchmark (DESIGN.md §12) — writes ``BENCH_serve.json``.

Measures the production serving claims of the multi-tenant GPO engine:

1. **latency_sweep** — saturation p50/p99 latency and throughput across
   engine batch caps x prefix-cache hit ratios (the two levers the
   engine adds over one-at-a-time ``predict_preferences``). All shape
   buckets are warmed before timing so compile time never pollutes a
   latency percentile.
2. **qps_at_slo** — offered-rate sweep with open-loop uniform arrivals:
   the highest rate whose p99 stays under the SLO. The SLO is
   calibrated on this machine (a multiple of the unloaded p50) so the
   sweep measures queueing behaviour, not host speed.
3. **prefix_cache** — the same trace served cold (every prefix
   prefilled) and warm (every prefix cached): same-mode wall-clock
   speedup, and a bit-equality assertion between the two result sets —
   the cache is only allowed to be faster, never different.
4. **int8** — engine wall-clock and prediction max-abs-diff, int8
   weights vs f32 (the documented serving tolerance), plus the fused
   int8-matmul kernel vs its jnp oracle. Pallas wall-clocks follow the
   repo rule: interpret-mode timings are recorded only with
   ``--include-interpret`` and never compared cross-mode; skipped
   measurements are structured ``{"skipped": true, "reason": ...}``
   blocks.

  PYTHONPATH=src python -m benchmarks.bench_serve
  PYTHONPATH=src python -m benchmarks.bench_serve --requests 24 \
      --train-rounds 5 --rates 20,40   # reduced CI smoke configuration
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_serve.json")


def _pallas_mode() -> str:
    return "native" if jax.default_backend() == "tpu" else "interpret"


def _skipped(reason: str) -> dict:
    return {"skipped": True, "reason": reason}


_INTERPRET_SKIP = ("interpret-mode Pallas wall-clock is not comparable to "
                   "compiled jnp; pass --include-interpret to record it")


def _best_of(fn, reps: int) -> float:
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out) if out is not None else None
        times.append(time.perf_counter() - t0)
    return min(times)


def _make_predictor(train_rounds: int, seed: int):
    """A briefly-trained GPO predictor + its survey population: latency
    does not depend on the weights, but the int8 max-abs-diff should be
    reported on a real predictor, not random init."""
    from repro.configs import FedConfig, GPOConfig
    from repro.core import FederatedGPO
    from repro.data import SurveyConfig, make_survey_data, split_groups

    data = make_survey_data(SurveyConfig(seed=seed))
    tr, ev = split_groups(data)
    gcfg = GPOConfig(d_embed=data.phi.shape[-1])
    fed = FederatedGPO(gcfg, FedConfig(num_clients=len(tr),
                                       rounds=train_rounds, seed=seed),
                       data, tr, ev)
    fed.run(rounds=train_rounds)
    return fed.global_params, gcfg, data, list(ev)


def _server(params, gcfg, data, *, max_batch=8, int8=False,
            cache_entries=256):
    from repro.configs import ServeConfig
    from repro.core import PreferenceServer

    return PreferenceServer(
        params, gcfg,
        ServeConfig(max_batch=max_batch, int8_weights=int8,
                    cache_entries=cache_entries),
        num_options=data.num_options)


def _timed_trace(server, trace) -> tuple[list, float]:
    """Warm every shape bucket the trace exercises, then run it timed
    from a cold cache (the realized hit ratio is the trace's own)."""
    server.run_trace(trace)  # compile warmup (untimed)
    server.reset(clear_cache=True)
    t0 = time.perf_counter()
    results = server.run_trace(trace)
    return results, time.perf_counter() - t0


# ---------------------------------------------------------------------------
# 1. saturation latency sweep: batch cap x hit ratio
# ---------------------------------------------------------------------------
def bench_latency_sweep(params, gcfg, data, groups, *, requests: int,
                        batch_caps, hit_ratios) -> dict:
    from repro.core import latency_summary, make_request_trace

    out = {}
    for cap in batch_caps:
        for hr in hit_ratios:
            trace = make_request_trace(data, groups,
                                       num_requests=requests,
                                       hit_ratio=hr, seed=17)
            server = _server(params, gcfg, data, max_batch=cap)
            results, wall = _timed_trace(server, trace)
            s = latency_summary(results, wall)
            out[f"batch{cap}_hit{hr:.2f}"] = {
                "max_batch": cap, "hit_ratio": hr,
                "p50_ms": s["p50_ms"], "p99_ms": s["p99_ms"],
                "qps": s["qps"], "realized_hit_rate": s["hit_rate"],
                "batches": len(server.batches),
            }
            print(f"  batch={cap} hit={hr:.2f}: p50={s['p50_ms']:.1f}ms "
                  f"p99={s['p99_ms']:.1f}ms qps={s['qps']:.1f}")
    return out


# ---------------------------------------------------------------------------
# 2. QPS at SLO: offered-rate sweep
# ---------------------------------------------------------------------------
def bench_qps_at_slo(params, gcfg, data, groups, *, requests: int,
                     rates, slo_multiple: float) -> dict:
    from repro.core import latency_summary, make_request_trace

    server = _server(params, gcfg, data, max_batch=8)
    # calibrate the SLO: unloaded p50 (single requests, no queueing)
    calib = make_request_trace(data, groups, num_requests=8,
                               hit_ratio=0.0, seed=23)
    server.run_trace(calib)  # warmup
    lat = []
    for req in calib:
        server.reset(clear_cache=True)
        server.submit(req)
        t0 = time.perf_counter()
        server.step()
        lat.append(time.perf_counter() - t0)
    unloaded_p50_ms = float(np.percentile(np.asarray(lat) * 1e3, 50))
    slo_ms = slo_multiple * unloaded_p50_ms

    points = {}
    best = 0.0
    for rate in rates:
        trace = make_request_trace(data, groups, num_requests=requests,
                                   hit_ratio=0.5, rate=rate, seed=29)
        server.reset(clear_cache=True)
        t0 = time.perf_counter()
        results = server.run_trace(trace, reset=False)
        wall = time.perf_counter() - t0
        s = latency_summary(results, wall)
        ok = s["p99_ms"] <= slo_ms and server.stats.rejected == 0
        if ok:
            best = max(best, rate)
        points[f"rate{rate:g}"] = {
            "offered_qps": rate, "achieved_qps": s["qps"],
            "p50_ms": s["p50_ms"], "p99_ms": s["p99_ms"],
            "rejected": server.stats.rejected, "meets_slo": ok,
        }
        print(f"  rate={rate:g}/s: p99={s['p99_ms']:.1f}ms "
              f"(slo {slo_ms:.1f}ms) -> {'OK' if ok else 'violates'}")
    return {"unloaded_p50_ms": unloaded_p50_ms, "slo_ms": slo_ms,
            "slo_multiple": slo_multiple, "qps_at_slo": best,
            "points": points}


# ---------------------------------------------------------------------------
# 3. prefix cache: cold vs warm, bit-equality
# ---------------------------------------------------------------------------
def bench_prefix_cache(params, gcfg, data, groups, *, requests: int,
                       reps: int) -> dict:
    from repro.core import make_request_trace

    # every request shares one of 2 prefixes with LARGE contexts (the
    # regime the cache exists for: prefill is the O(M^2) half)
    trace = make_request_trace(data, groups, num_requests=requests,
                               hit_ratio=1.0 - 2.0 / requests,
                               num_context=(24, 32), num_target=(2, 4),
                               seed=31)
    server = _server(params, gcfg, data, max_batch=8)
    server.run_trace(trace)  # warmup

    def run_cold():
        server.reset(clear_cache=True)
        return server.run_trace(trace, reset=False)

    def run_warm():
        server.reset(clear_cache=False)  # keep the populated cache
        return server.run_trace(trace, reset=False)

    cold_results = run_cold()
    warm_results = run_warm()
    cold_by_rid = {c.rid: c.pred for c in cold_results}
    bit_equal = all(np.array_equal(cold_by_rid[c.rid], c.pred)
                    for c in warm_results)
    assert bit_equal, "prefix-cache hit diverged from cold path"
    t_cold = _best_of(run_cold, reps)
    t_warm = _best_of(run_warm, reps)
    print(f"  cold={t_cold*1e3:.1f}ms warm={t_warm*1e3:.1f}ms "
          f"speedup={t_cold / t_warm:.2f}x bit_equal={bit_equal}")
    return {
        "requests": requests, "unique_prefixes": 2,
        "cold_ms": t_cold * 1e3, "warm_ms": t_warm * 1e3,
        "warm_speedup": t_cold / t_warm,
        "warm_hit_rate": float(np.mean(
            [c.cache_hit for c in warm_results])),
        "hit_bit_equal_to_miss": bool(bit_equal),
    }


# ---------------------------------------------------------------------------
# 4. int8: engine tolerance + fused kernel microbench
# ---------------------------------------------------------------------------
def bench_int8(params, gcfg, data, groups, *, requests: int, reps: int,
               include_interpret: bool) -> dict:
    from repro.core import make_request_trace
    from repro.kernels import int8_matmul, quantize_linear
    from repro.kernels.ref import ref_int8_matmul

    mode = _pallas_mode()
    trace = make_request_trace(data, groups, num_requests=requests,
                               hit_ratio=0.5, seed=37)
    f32_server = _server(params, gcfg, data)
    int8_server = _server(params, gcfg, data, int8=True)
    f32_results, _ = _timed_trace(f32_server, trace)
    int8_results, _ = _timed_trace(int8_server, trace)
    f32_by_rid = {c.rid: c.pred for c in f32_results}
    max_abs = max(float(np.abs(f32_by_rid[c.rid] - c.pred).max())
                  for c in int8_results)
    print(f"  int8-vs-f32 prediction max_abs_diff={max_abs:.4f} "
          f"({mode} kernel)")

    measure = mode == "native" or include_interpret
    if measure:
        t_f32 = _best_of(
            lambda: f32_server.run_trace(trace, clear_cache=True), reps)
        t_int8 = _best_of(
            lambda: int8_server.run_trace(trace, clear_cache=True), reps)
        engine_wall = {"mode": mode, "f32_ms": t_f32 * 1e3,
                       "int8_ms": t_int8 * 1e3}
    else:
        engine_wall = {**_skipped(_INTERPRET_SKIP), "mode": mode}

    # fused kernel vs jnp oracle (dequantize-then-matmul)
    m, k, n = 256, 256, 256
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    ql = quantize_linear(jax.random.normal(jax.random.PRNGKey(1), (k, n)))
    got = np.asarray(int8_matmul(x, ql.q, ql.scale))
    want = np.asarray(ref_int8_matmul(x, ql.q, ql.scale))
    kernel_max_abs = float(np.abs(got - want).max())
    if measure:
        t_kernel = _best_of(lambda: int8_matmul(x, ql.q, ql.scale), reps)
        t_oracle = _best_of(
            lambda: ref_int8_matmul(x, ql.q, ql.scale), reps)
        kernel_wall = {"mode": mode, "kernel_us": t_kernel * 1e6,
                       "jnp_oracle_us": t_oracle * 1e6}
    else:
        kernel_wall = {**_skipped(_INTERPRET_SKIP), "mode": mode}

    return {
        "prediction_max_abs_diff": max_abs,
        "tolerance_documented": 0.05,
        "within_tolerance": bool(max_abs < 0.05),
        "engine_wall": engine_wall,
        "kernel": {"shape_mkn": [m, k, n],
                   "max_abs_diff_vs_oracle": kernel_max_abs,
                   "wall": kernel_wall},
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--train-rounds", type=int, default=20)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch-caps", default="1,4,8",
                    help="comma-separated engine batch caps (>= 3 for "
                         "the acceptance sweep)")
    ap.add_argument("--hit-ratios", default="0.0,0.5,0.9",
                    help="comma-separated prefix-cache hit ratios")
    ap.add_argument("--rates", default="25,50,100,200,400",
                    help="offered rates (req/s) for the SLO sweep — "
                         "should bracket the saturation throughput so "
                         "the p99-vs-SLO knee is actually observed")
    ap.add_argument("--slo-multiple", type=float, default=20.0,
                    help="SLO = this multiple of the unloaded p50 "
                         "(calibrated per machine)")
    ap.add_argument("--include-interpret", action="store_true",
                    help="record interpret-mode Pallas wall-clocks "
                         "(debug only; never cross-mode compared)")
    args = ap.parse_args()

    batch_caps = [int(b) for b in args.batch_caps.split(",")]
    hit_ratios = [float(h) for h in args.hit_ratios.split(",")]
    rates = [float(r) for r in args.rates.split(",")]

    print(f"training predictor ({args.train_rounds} rounds) ...")
    params, gcfg, data, groups = _make_predictor(args.train_rounds,
                                                 args.seed)
    print("1. saturation latency sweep")
    latency = bench_latency_sweep(params, gcfg, data, groups,
                                  requests=args.requests,
                                  batch_caps=batch_caps,
                                  hit_ratios=hit_ratios)
    print("2. offered-rate sweep (QPS at SLO)")
    slo = bench_qps_at_slo(params, gcfg, data, groups,
                           requests=args.requests, rates=rates,
                           slo_multiple=args.slo_multiple)
    print("3. prefix cache cold vs warm")
    cache = bench_prefix_cache(params, gcfg, data, groups,
                               requests=args.requests, reps=args.reps)
    print("4. int8 weights")
    int8 = bench_int8(params, gcfg, data, groups,
                      requests=min(args.requests, 16), reps=args.reps,
                      include_interpret=args.include_interpret)

    report = {
        "backend": jax.default_backend(),
        "pallas_mode": _pallas_mode(),
        "requests": args.requests,
        "train_rounds": args.train_rounds,
        "latency_sweep": latency,
        "qps_at_slo": slo,
        "prefix_cache": cache,
        "int8": int8,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {os.path.normpath(OUT_PATH)}")


if __name__ == "__main__":
    main()
