"""Shared experiment runner for the paper-figure benchmarks.

One (federated, centralized) pair of runs on identical data/split/seeds
feeds Fig. 2 (convergence), Fig. 4 (alignment) and Fig. 5 (fairness).
Results are cached as JSON so `python -m benchmarks.run` is cheap to
re-run; delete results/paper_run*.json to force recomputation.

Scale note: the paper runs 1300 rounds on an A30; the benchmark default is
CPU-sized (multiple seeds x 400 rounds). EXPERIMENTS.md §Paper-claims uses
a full-length overnight run of the same code path.
"""
from __future__ import annotations

import json
import os
import re
from dataclasses import asdict, dataclass

import numpy as np

from repro.configs import FedConfig, GPOConfig
from repro.core import CentralizedGPO, FederatedGPO
from repro.core.fairness import convergence_round
from repro.data import SurveyConfig, make_survey_data, split_groups

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


@dataclass
class RunResult:
    fed_loss: list
    cen_loss: list
    eval_rounds: list
    fed_as: list
    cen_as: list
    fed_fi: list
    cen_fi: list
    fed_scores_last: list
    cen_scores_last: list


def run_pair(rounds: int, seed: int, num_groups: int = 17,
             num_questions: int = 120, d_embed: int = 48) -> RunResult:
    data = make_survey_data(SurveyConfig(
        num_groups=num_groups, num_questions=num_questions,
        d_embed=d_embed, seed=seed))
    tr, ev = split_groups(data, train_frac=0.6, seed=seed)
    gcfg = GPOConfig(d_embed=d_embed, d_model=96, num_layers=3,
                     num_heads=4, d_ff=192)
    fcfg = FedConfig(num_clients=len(tr), rounds=rounds, local_epochs=6,
                     lr=3e-4, eval_every=10, num_context=12, num_target=12,
                     seed=seed)
    fed = FederatedGPO(gcfg, fcfg, data, tr, ev)
    hist_f = fed.run(rounds=rounds)
    cen = CentralizedGPO(gcfg, fcfg, data, tr, ev)
    hist_c = cen.run(epochs=rounds)
    return RunResult(
        fed_loss=hist_f.round_loss, cen_loss=hist_c.round_loss,
        eval_rounds=hist_f.eval_rounds,
        fed_as=hist_f.eval_mean_as, cen_as=hist_c.eval_mean_as,
        fed_fi=hist_f.eval_fi, cen_fi=hist_c.eval_fi,
        fed_scores_last=np.asarray(hist_f.eval_scores[-1]).tolist(),
        cen_scores_last=np.asarray(hist_c.eval_scores[-1]).tolist())


def load_or_run(rounds: int = 400, seeds=(0, 1, 2, 3),
                tag: str = "paper_run") -> list[RunResult]:
    """Paper protocol: results averaged over four random seeds (§4.1)."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{tag}_{rounds}.json")
    if os.path.exists(path):
        with open(path) as f:
            return [RunResult(**r) for r in json.load(f)]
    # reuse a longer cached run if one exists (e.g. the full-length
    # paper-claims artifact) rather than recomputing a shorter one
    import glob

    for cand in sorted(glob.glob(os.path.join(RESULTS_DIR, "paper_*.json")),
                       reverse=True):
        m = re.search(r"_(\d+)\.json$", cand)
        if m and int(m.group(1)) >= rounds:
            with open(cand) as f:
                return [RunResult(**r) for r in json.load(f)]
    results = [run_pair(rounds, s) for s in seeds]
    with open(path, "w") as f:
        json.dump([asdict(r) for r in results], f)
    return results


def summarize(results: list[RunResult]) -> dict:
    """The paper's three headline numbers, averaged over seeds."""
    speedups, as_improvements, fi_gaps = [], [], []
    fed_conv, cen_conv = [], []
    for r in results:
        rf = convergence_round(np.asarray(r.fed_loss))
        rc = convergence_round(np.asarray(r.cen_loss))
        fed_conv.append(rf)
        cen_conv.append(rc)
        speedups.append(100.0 * (rc - rf) / max(rc, 1))
        as_improvements.append(
            100.0 * (r.fed_as[-1] - r.cen_as[-1]) / max(r.cen_as[-1], 1e-9))
        fi_gaps.append(r.fed_fi[-1] - r.cen_fi[-1])
    return {
        "fed_convergence_round": float(np.mean(fed_conv)),
        "cen_convergence_round": float(np.mean(cen_conv)),
        "convergence_speedup_pct": float(np.mean(speedups)),
        "alignment_improvement_pct": float(np.mean(as_improvements)),
        "fed_final_as": float(np.mean([r.fed_as[-1] for r in results])),
        "cen_final_as": float(np.mean([r.cen_as[-1] for r in results])),
        "fed_final_fi": float(np.mean([r.fed_fi[-1] for r in results])),
        "cen_final_fi": float(np.mean([r.cen_fi[-1] for r in results])),
        "fi_gap": float(np.mean(fi_gaps)),
    }
