"""Render the §Dry-run / §Roofline markdown tables from the sweep JSONL.

  PYTHONPATH=src python -m benchmarks.roofline_table \
      --in results/dryrun.jsonl [--mp results/dryrun_mp.jsonl]
"""
from __future__ import annotations

import argparse
import json


def _fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def load(path: str) -> list[dict]:
    rows = []
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if "error" not in r:
                rows.append(r)
    return rows


def roofline_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | kind | compute_ms | memory_ms | collective_ms "
           "| bottleneck | model/HLO flops | coll. mix |\n"
           "|---|---|---|---:|---:|---:|---|---:|---|\n")
    out = [hdr]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    for r in sorted(rows, key=lambda r: (r["arch"], order[r["shape"]])):
        roof = r["roofline"]
        mix = roof.get("collective_bytes_by_kind", {})
        total = sum(mix.values()) or 1.0
        mix_s = " ".join(
            f"{k.replace('collective-', 'c-')}:{100 * v / total:.0f}%"
            for k, v in sorted(mix.items(), key=lambda kv: -kv[1])[:3])
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {roof['compute_s'] * 1e3:.1f} "
            f"| {roof['memory_s'] * 1e3:.1f} "
            f"| {roof['collective_s'] * 1e3:.1f} "
            f"| **{roof['bottleneck']}** "
            f"| {roof['useful_ratio']:.2f} | {mix_s} |\n")
    return "".join(out)


def memory_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | params | args/chip | temp(total) | compile_s |\n"
           "|---|---|---:|---:|---:|---:|\n")
    out = [hdr]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        mem = r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['params'] / 1e9:.1f}B "
            f"| {_fmt_bytes(mem.get('argument_size_in_bytes', 0))} "
            f"| {_fmt_bytes(mem.get('temp_size_in_bytes', 0))} "
            f"| {r['compile_s']:.0f} |\n")
    return "".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="results/dryrun.jsonl")
    ap.add_argument("--mp", default=None)
    ap.add_argument("--memory", action="store_true")
    args = ap.parse_args()
    rows = load(args.inp)
    print(f"### Roofline (single-pod 16x16, {len(rows)} pairs)\n")
    print(roofline_table(rows))
    if args.memory:
        print("\n### Memory / compile\n")
        print(memory_table(rows))
    if args.mp:
        mp = load(args.mp)
        print(f"\n### Multi-pod 2x16x16 ({len(mp)} pairs lowered+compiled)\n")
        print(roofline_table(mp))


if __name__ == "__main__":
    main()
