"""Benchmark harness — one benchmark per paper table/figure plus the
dry-run roofline table. Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run --only fig2 fig4
  PYTHONPATH=src python -m benchmarks.run --rounds 400

Benchmarks:
  fig2        convergence speed, FL vs centralized (paper Fig. 2 / §4.5)
  fig3        preference-distribution match for eval groups (Fig. 3)
  fig4        mean eval alignment score (Fig. 4 / §4.6)
  fig5        fairness index over training (Fig. 5 / §4.7)
  aggregation FedAvg aggregation microbenchmark (Eq. 3; jnp vs Pallas)
  kernels     per-kernel us/call (interpret mode) vs jnp oracle
  roofline    (arch x shape) roofline table from results/dryrun.jsonl
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
ROWS: list[tuple] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def _timeit(fn, *args, reps: int = 5) -> float:
    fn(*args)  # compile / warmup
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


# ---------------------------------------------------------------------------
def bench_paper_figures(rounds: int) -> None:
    from benchmarks.paper_experiment import load_or_run, summarize

    t0 = time.time()
    results = load_or_run(rounds=rounds)
    s = summarize(results)
    dt = (time.time() - t0) * 1e6
    emit("fig2_convergence_fed_round", dt,
         f"fed_conv={s['fed_convergence_round']:.0f}")
    emit("fig2_convergence_cen_round", 0.0,
         f"cen_conv={s['cen_convergence_round']:.0f}")
    emit("fig2_convergence_speedup", 0.0,
         f"speedup_pct={s['convergence_speedup_pct']:.1f} (paper: 46%)")
    emit("fig4_alignment_fed", 0.0, f"AS={s['fed_final_as']:.4f}")
    emit("fig4_alignment_cen", 0.0, f"AS={s['cen_final_as']:.4f}")
    emit("fig4_alignment_improvement", 0.0,
         f"pct={s['alignment_improvement_pct']:.2f} (paper: ~4%)")
    emit("fig5_fairness_fed", 0.0,
         f"FI={s['fed_final_fi']:.4f} (paper: ~1.0)")
    emit("fig5_fairness_cen", 0.0, f"FI={s['cen_final_fi']:.4f}")
    emit("fig5_fairness_gap", 0.0, f"delta={s['fi_gap']:+.4f}")


def bench_distributions(rounds: int) -> None:
    """Fig. 3: alignment of predicted vs ground-truth answer distributions
    for unseen evaluation groups, federated vs centralized."""
    from benchmarks.paper_experiment import load_or_run

    results = load_or_run(rounds=rounds)
    fed = np.mean([np.mean(r.fed_scores_last) for r in results])
    cen = np.mean([np.mean(r.cen_scores_last) for r in results])
    emit("fig3_eval_group_as_fed", 0.0, f"mean_AS={fed:.4f}")
    emit("fig3_eval_group_as_cen", 0.0, f"mean_AS={cen:.4f}")


def bench_aggregation() -> None:
    """Eq. 3 microbenchmark: stacked-jnp vs flat-Pallas aggregation."""
    from repro.core import fedavg_stacked, normalize_weights
    from repro.kernels import fedavg_reduce

    key = jax.random.PRNGKey(0)
    for c, p in [(10, 1_000_000), (32, 1_000_000)]:
        stacked = jax.random.normal(key, (c, p))
        w = normalize_weights(jnp.ones((c,)))
        t_jnp = _timeit(jax.jit(
            lambda s, w: fedavg_stacked({"x": s}, w)["x"]), stacked, w)
        t_ker = _timeit(lambda s, w: fedavg_reduce(s, w), stacked, w)
        emit(f"fedavg_jnp_c{c}_p{p}", t_jnp,
             f"GBps={c * p * 4 / t_jnp / 1e3:.1f}")
        emit(f"fedavg_pallas_c{c}_p{p}", t_ker,
             "interpret_mode=CPU-validation")


def bench_kernels() -> None:
    from repro.kernels import flash_attention, gpo_attention, ssd_scan
    from repro.kernels.ref import ref_attention, ref_gpo_attention, ref_ssd

    key = jax.random.PRNGKey(1)
    b, s, h, kv, hd = 1, 256, 4, 2, 64
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, hd))
    t = _timeit(lambda: flash_attention(q, k, v, causal=True, bq=64, bk=64))
    t_ref = _timeit(jax.jit(lambda: ref_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3))))
    emit("flash_attention_256", t, f"ref_us={t_ref:.1f}")

    qg = jax.random.normal(key, (128, 4, 32))
    t = _timeit(lambda: gpo_attention(qg, qg, qg, num_ctx=32, bq=32, bk=32))
    t_ref = _timeit(jax.jit(lambda: ref_gpo_attention(
        qg.transpose(1, 0, 2), qg.transpose(1, 0, 2),
        qg.transpose(1, 0, 2), num_ctx=32)))
    emit("gpo_attention_128", t, f"ref_us={t_ref:.1f}")

    bb, ss, hh, pp, nn = 1, 128, 2, 16, 8
    x = jax.random.normal(key, (bb, ss, hh, pp)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(key, (bb, ss, hh)))
    alog = jax.random.normal(key, (hh,)) * 0.5
    B = jax.random.normal(key, (bb, ss, nn)) * 0.5
    C = jax.random.normal(key, (bb, ss, nn)) * 0.5
    D = jnp.ones((hh,))
    t = _timeit(lambda: ssd_scan(x, dt, alog, B, C, D, chunk=32))
    t_ref = _timeit(jax.jit(lambda: ref_ssd(x, dt, alog, B, C, D)))
    emit("ssd_scan_128", t, f"ref_us={t_ref:.1f}")


def bench_roofline() -> None:
    path = os.path.join(RESULTS_DIR, "dryrun.jsonl")
    if not os.path.exists(path):
        emit("roofline_table", 0.0, "missing results/dryrun.jsonl (run "
             "python -m repro.launch.sweep first)")
        return
    n_ok, n_err = 0, 0
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if "error" in r:
                n_err += 1
                continue
            n_ok += 1
            roof = r["roofline"]
            dom = roof["bottleneck"]
            emit(f"roofline_{r['arch']}_{r['shape']}",
                 max(roof["compute_s"], roof["memory_s"],
                     roof["collective_s"]) * 1e6,
                 f"bottleneck={dom};compute_ms={roof['compute_s']*1e3:.1f};"
                 f"memory_ms={roof['memory_s']*1e3:.1f};"
                 f"collective_ms={roof['collective_s']*1e3:.1f};"
                 f"useful={roof['useful_ratio']:.2f}")
    emit("roofline_coverage", 0.0, f"ok={n_ok};errors={n_err}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--rounds", type=int, default=400)
    args = ap.parse_args()
    which = set(args.only or ["fig2", "fig3", "fig4", "fig5",
                              "aggregation", "kernels", "roofline"])
    print("name,us_per_call,derived")
    if which & {"fig2", "fig4", "fig5"}:
        bench_paper_figures(args.rounds)
    if "fig3" in which:
        bench_distributions(args.rounds)
    if "aggregation" in which:
        bench_aggregation()
    if "kernels" in which:
        bench_kernels()
    if "roofline" in which:
        bench_roofline()


if __name__ == "__main__":
    main()
