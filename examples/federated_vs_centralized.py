"""End-to-end driver reproducing the paper's comparison (Figs. 2/4/5):
federated PluralLLM vs centralized GPO on identical data, reporting
convergence round, alignment score, and fairness index.

  PYTHONPATH=src python examples/federated_vs_centralized.py --rounds 300
"""
import argparse

import numpy as np

from benchmarks.paper_experiment import run_pair, summarize


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--seeds", type=int, nargs="*", default=[0, 1])
    args = ap.parse_args()

    results = [run_pair(args.rounds, s) for s in args.seeds]
    s = summarize(results)

    print("\n=== PluralLLM vs centralized GPO "
          f"({args.rounds} rounds, {len(args.seeds)} seeds) ===")
    print(f"convergence round   : fed {s['fed_convergence_round']:.0f} "
          f"vs cen {s['cen_convergence_round']:.0f} "
          f"-> {s['convergence_speedup_pct']:.1f}% faster (paper: 46%)")
    print(f"eval alignment score: fed {s['fed_final_as']:.4f} "
          f"vs cen {s['cen_final_as']:.4f} "
          f"-> {s['alignment_improvement_pct']:+.2f}% (paper: ~+4%)")
    print(f"fairness index      : fed {s['fed_final_fi']:.4f} "
          f"vs cen {s['cen_final_fi']:.4f} "
          f"-> gap {s['fi_gap']:+.4f} (paper: parity, FI ~= 1)")

    r = results[0]
    print("\nloss curve (fed vs cen, every 25 rounds):")
    for i in range(0, args.rounds, max(25, args.rounds // 10)):
        print(f"  round {i:4d}: fed={r.fed_loss[i]:.4f} "
              f"cen={r.cen_loss[i]:.4f}")


if __name__ == "__main__":
    main()
