"""Federated fine-tuning of a ~100M-parameter backbone — the end-to-end
training driver. Four clients hold disjoint synthetic corpora; each round
runs local LM steps and aggregates either full parameters or LoRA
adapters (the paper's technique applied to backbone training) under any
registry aggregation strategy (DESIGN.md §7).

  PYTHONPATH=src python examples/fedlora_finetune.py --rounds 150 \
      --local-steps 2 --mode lora --agg fedavgm
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import AggConfig, get_arch, override
from repro.core import (
    AGGREGATORS,
    broadcast_to_clients,
    init_lora,
    lora_param_count,
    make_aggregator,
    make_backbone_fedavg_round,
    make_fedlora_round,
    normalize_weights,
)
from repro.data import LMDataConfig, synthetic_lm_batches
from repro.launch.specs import count_params
from repro.models import init_params
from repro.optim import adam


def hundred_m_config():
    """A ~100M-parameter member of the qwen2 family (same block type)."""
    return override(
        get_arch("qwen2-0.5b"), name="qwen2-100m", num_layers=16,
        d_model=640, num_heads=10, num_kv_heads=2, head_dim=64,
        d_ff=2560, vocab_size=32000, param_dtype="float32",
        activation_dtype="float32")  # ~114M params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=150)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mode", choices=["full", "lora"], default="lora")
    # fedprox is excluded: its proximal term lives in the GPO engine's
    # local objective, which these backbone trainers don't have
    ap.add_argument("--agg", default="fedavg",
                    choices=[n for n in AGGREGATORS.names()
                             if n != "fedprox"],
                    help="server-aggregation strategy (DESIGN.md §7)")
    args = ap.parse_args()

    cfg = hundred_m_config()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    print(f"backbone: {cfg.name} with {count_params(cfg)/1e6:.0f}M params")

    opt = adam(3e-4)
    c = args.clients
    # heterogeneous client corpora: different seeds + sizes -> Eq. 2 weights
    sizes = jnp.asarray([100.0, 80.0, 60.0, 40.0][:c])
    weights = normalize_weights(sizes)
    iters = [synthetic_lm_batches(LMDataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=10 + i)) for i in range(c)]

    agg = make_aggregator(AggConfig(name=args.agg), num_clients=c)
    if args.mode == "full":
        payload = params
        rnd = jax.jit(make_backbone_fedavg_round(cfg, opt, args.local_steps,
                                                 agg=agg))
    else:
        payload = init_lora(params, key, rank=8)
        print(f"LoRA payload: {lora_param_count(payload)/1e6:.2f}M params "
              f"({100*lora_param_count(payload)/count_params(cfg):.2f}% of "
              "the backbone) — the federated communication volume")
        rnd = jax.jit(make_fedlora_round(cfg, params, opt, args.local_steps,
                                         agg=agg))

    client_state = broadcast_to_clients(payload, c)
    opt_states = jax.vmap(opt.init)(client_state)
    server_state = agg.init(payload)

    t0 = time.time()
    total_steps = 0
    for r in range(args.rounds):
        batches = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[jax.tree.map(lambda *ys: jnp.stack(ys),
                           *[next(iters[i]) for _ in range(args.local_steps)])
              for i in range(c)])
        client_state, opt_states, losses, server_state = rnd(
            client_state, opt_states, batches, weights, server_state)
        total_steps += c * args.local_steps
        if r % max(1, args.rounds // 15) == 0:
            print(f"round {r:4d} ({total_steps:5d} client steps) "
                  f"losses={np.round(np.asarray(losses), 4)}")
    dt = time.time() - t0
    print(f"\n{args.rounds} rounds = {total_steps} client steps "
          f"in {dt:.0f}s; final mean loss "
          f"{float(jnp.mean(losses)):.4f}")


if __name__ == "__main__":
    main()
