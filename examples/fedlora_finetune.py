"""Federated fine-tuning of a ~100M-parameter backbone — the end-to-end
training driver. Four clients hold disjoint synthetic corpora; each round
runs local LM steps and aggregates either full parameters or LoRA
adapters (the paper's technique applied to backbone training) under any
registry aggregation strategy (DESIGN.md §7). ``--clip-norm`` /
``--noise-multiplier`` turn on the DP client-delta pipeline
(DESIGN.md §9): adapters are clipped + noised before aggregation and
the Rényi accountant's ε is printed alongside the losses.
``--compress`` / ``--topk-frac`` add the delta codec (DESIGN.md §10):
int8 stochastic quantization or top-k sparsification with an EF21
error-feedback residual, applied AFTER the DP release — the printed
upload estimate shows the communication saving on the LoRA payload.

  PYTHONPATH=src python examples/fedlora_finetune.py --rounds 150 \
      --local-steps 2 --mode lora --agg fedavgm
  PYTHONPATH=src python examples/fedlora_finetune.py --rounds 50 \
      --mode lora --clip-norm 0.5 --noise-multiplier 0.6
  PYTHONPATH=src python examples/fedlora_finetune.py --rounds 50 \
      --mode lora --compress int8
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (AggConfig, CompressionConfig, PrivacyConfig,
                           get_arch, override)
from repro.core.privacy import make_accountant
from repro.core import (
    AGGREGATORS,
    broadcast_to_clients,
    init_lora,
    lora_param_count,
    make_aggregator,
    make_backbone_fedavg_round,
    make_fedlora_round,
    normalize_weights,
)
from repro.data import LMDataConfig, synthetic_lm_batches
from repro.launch.specs import count_params
from repro.models import init_params
from repro.optim import adam
from repro.utils.pytree import tree_count_params


def hundred_m_config():
    """A ~100M-parameter member of the qwen2 family (same block type)."""
    return override(
        get_arch("qwen2-0.5b"), name="qwen2-100m", num_layers=16,
        d_model=640, num_heads=10, num_kv_heads=2, head_dim=64,
        d_ff=2560, vocab_size=32000, param_dtype="float32",
        activation_dtype="float32")  # ~114M params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=150)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mode", choices=["full", "lora"], default="lora")
    # fedprox is excluded: its proximal term lives in the GPO engine's
    # local objective, which these backbone trainers don't have
    ap.add_argument("--agg", default="fedavg",
                    choices=[n for n in AGGREGATORS.names()
                             if n != "fedprox"],
                    help="server-aggregation strategy (DESIGN.md §7)")
    # DP client-delta pipeline (DESIGN.md §9): 0 = off
    ap.add_argument("--clip-norm", type=float, default=0.0,
                    help="per-client L2 clip on the flat delta (0 = off)")
    ap.add_argument("--noise-multiplier", type=float, default=0.0,
                    help="Gaussian noise std = z * clip-norm per client")
    # delta codec (DESIGN.md §10): none = off
    ap.add_argument("--compress", default="none",
                    choices=["none", "int8", "topk"],
                    help="client->server delta codec")
    ap.add_argument("--topk-frac", type=float, default=0.01,
                    help="fraction of coordinates kept (--compress topk)")
    ap.add_argument("--no-error-feedback", action="store_true",
                    help="disable the EF21 error-feedback residual")
    args = ap.parse_args()

    cfg = hundred_m_config()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    print(f"backbone: {cfg.name} with {count_params(cfg)/1e6:.0f}M params")

    opt = adam(3e-4)
    c = args.clients
    # heterogeneous client corpora: different seeds + sizes -> Eq. 2 weights
    sizes = jnp.asarray([100.0, 80.0, 60.0, 40.0][:c])
    weights = normalize_weights(sizes)
    iters = [synthetic_lm_batches(LMDataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=10 + i)) for i in range(c)]

    agg = make_aggregator(AggConfig(name=args.agg), num_clients=c)
    priv = PrivacyConfig(clip_norm=args.clip_norm,
                         noise_multiplier=args.noise_multiplier)
    priv.validate()
    if priv.enabled:
        print(f"DP pipeline on: clip={priv.clip_norm} "
              f"z={priv.noise_multiplier} (DESIGN.md §9)")
    comp = CompressionConfig(kind=args.compress, topk_frac=args.topk_frac,
                             error_feedback=not args.no_error_feedback)
    comp.validate()
    if args.mode == "full":
        payload = params
        rnd = jax.jit(make_backbone_fedavg_round(cfg, opt, args.local_steps,
                                                 agg=agg, privacy=priv,
                                                 compression=comp))
    else:
        payload = init_lora(params, key, rank=8)
        print(f"LoRA payload: {lora_param_count(payload)/1e6:.2f}M params "
              f"({100*lora_param_count(payload)/count_params(cfg):.2f}% of "
              "the backbone) — the federated communication volume")
        rnd = jax.jit(make_fedlora_round(cfg, params, opt, args.local_steps,
                                         agg=agg, privacy=priv,
                                         compression=comp))
    pdim = tree_count_params(payload)
    if comp.enabled:
        from repro.core.compression import topk_count

        dense = 4 * pdim
        wire = (pdim + 4 if comp.kind == "int8"
                else 8 * topk_count(pdim, comp.topk_frac))
        print(f"compression on: {comp.kind} "
              f"(EF={'on' if comp.error_feedback else 'off'}) — per-client "
              f"upload {wire/1e6:.2f} MB vs {dense/1e6:.2f} MB dense f32 "
              f"({dense/wire:.1f}x; DESIGN.md §10)")

    client_state = broadcast_to_clients(payload, c)
    opt_states = jax.vmap(opt.init)(client_state)
    server_state = agg.init(payload)

    accountant = make_accountant(priv, 1.0)  # full participation
    noise_base = jax.random.PRNGKey(23)
    ef = comp.enabled and comp.error_feedback
    need_key = comp.enabled and (priv.enabled or comp.needs_rng)
    resid = jnp.zeros((c, pdim), jnp.float32) if ef else None
    t0 = time.time()
    total_steps = 0
    for r in range(args.rounds):
        batches = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[jax.tree.map(lambda *ys: jnp.stack(ys),
                           *[next(iters[i]) for _ in range(args.local_steps)])
              for i in range(c)])
        round_args = (client_state, opt_states, batches, weights,
                      server_state)
        if comp.enabled:
            if ef:
                round_args += (resid,)
            if need_key:
                round_args += (jax.random.fold_in(noise_base, r),)
        elif priv.enabled:
            round_args += (jax.random.fold_in(noise_base, r),)
        out = rnd(*round_args)
        client_state, opt_states, losses, server_state = out[:4]
        if ef:
            resid = out[4]
        total_steps += c * args.local_steps
        if r % max(1, args.rounds // 15) == 0:
            eps = (f" eps={accountant.epsilon(r + 1):.3f}"
                   if accountant else "")
            print(f"round {r:4d} ({total_steps:5d} client steps) "
                  f"losses={np.round(np.asarray(losses), 4)}{eps}")
    dt = time.time() - t0
    print(f"\n{args.rounds} rounds = {total_steps} client steps "
          f"in {dt:.0f}s; final mean loss "
          f"{float(jnp.mean(losses)):.4f}")


if __name__ == "__main__":
    main()
