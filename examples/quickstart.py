"""Quickstart: train the PluralLLM federated preference predictor on a
synthetic global-opinion survey and query it for an unseen group.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import FedConfig, GPOConfig
from repro.core import FederatedGPO, predict_preferences
from repro.core.fairness import alignment_score
from repro.data import (
    SurveyConfig,
    make_survey_data,
    sample_icl_batch,
    split_groups,
)


def main() -> None:
    # 1. A synthetic PewResearch-style survey population: 17 groups, 120
    #    multiple-choice questions, frozen-LLM embeddings (stub frontend).
    data = make_survey_data(SurveyConfig(seed=0))
    train_groups, eval_groups = split_groups(data, train_frac=0.6)
    print(f"groups: {len(train_groups)} train clients / "
          f"{len(eval_groups)} held-out")

    # 2. Federated training: each group is a FedAvg client (paper §3).
    #    For differentially-private training (DESIGN.md §9) add
    #      privacy=PrivacyConfig(clip_norm=0.5, noise_multiplier=0.8)
    #    (from repro.configs) — client deltas are then clipped + noised
    #    before aggregation and hist.round_eps tracks the cumulative ε
    #    from the Rényi accountant.
    #    To simulate an unreliable population (DESIGN.md §11) add
    #      avail=AvailabilityConfig(online_prob=0.8, crash_prob=0.05,
    #                               straggler_prob=0.2, max_staleness=4)
    #    — clients then drop out, crash, and upload late on a
    #    deterministic per-seed schedule (hist.round_survivors records
    #    the realized participation); pair it with
    #    agg=AggConfig(name="fedbuff") for staleness-aware buffered
    #    aggregation, and see `bench_round.py --faults` /
    #    `dryrun.py --gpo-fed --faults` for the robustness numbers.
    #    To simulate Byzantine clients (DESIGN.md §13) add
    #      adversary=AdversaryConfig(kind="sign_flip", num_attackers=3)
    #    and pick a defense with agg=AggConfig(name="krum",
    #    num_malicious=3) (or geomedian/median, and/or norm_bound=1.0);
    #    from the CLI the same knobs are `train --trainer gpo
    #    --attack sign_flip --attackers 3 --agg krum` — the attack ×
    #    defense grid lives in `bench_round.py --byzantine`.
    #    For client→edge→server aggregation (DESIGN.md §14) add
    #      hierarchy=HierarchyConfig(num_edges=4)
    #    — each edge pre-reduces its own client block before the
    #    cross-edge hop (the robust family's big all-gather shrinks
    #    from O(C·P) to O(E·P); `dryrun.py --gpo-fed --edges 4` and
    #    `bench_round.py --hierarchy` show the compiled byte counts).
    gpo_cfg = GPOConfig(d_embed=data.phi.shape[-1])
    fed_cfg = FedConfig(num_clients=len(train_groups), rounds=150,
                        local_epochs=6, lr=3e-4, eval_every=25)
    fed = FederatedGPO(gpo_cfg, fed_cfg, data, train_groups, eval_groups)
    hist = fed.run(rounds=150, log_every=25)

    # 3. Serve: predict an UNSEEN group's answer distribution from a few
    #    in-context examples (the paper's reward-model use case). Under
    #    real query load, use the multi-tenant engine instead
    #    (DESIGN.md §12): core.PreferenceServer adds continuous batching
    #    over ragged requests, a prefix/KV cache for repeated group
    #    contexts, and an int8 weight path — see
    #    examples/serve_preferences.py and `serve --gpo`.
    group = int(eval_groups[0])
    batch = sample_icl_batch(jax.random.PRNGKey(42), data, group,
                             num_context=12, num_target=4)
    pred = predict_preferences(fed.global_params, gpo_cfg, batch.ctx_x,
                               batch.ctx_y, batch.tgt_x, data.num_options)
    truth = batch.tgt_y.reshape(-1, data.num_options)
    print(f"\nunseen group {group}: "
          f"AS={float(alignment_score(pred, truth)):.4f}")
    for i in range(2):
        print(f"  q{i} pred : {np.round(np.asarray(pred[i]), 3).tolist()}")
        print(f"  q{i} truth: {np.round(np.asarray(truth[i]), 3).tolist()}")


if __name__ == "__main__":
    main()
