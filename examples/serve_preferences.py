"""Preference serving through the multi-tenant engine: the trained
federated predictor acts as a lightweight group-conditioned reward model
(paper §5) answering ragged-length requests "what would group g answer to
question q?" via ``PreferenceServer`` (DESIGN.md §12) — admission queue,
bucketed continuous batching, prefix/KV cache over shared ICL contexts,
and optional int8 weights.

  PYTHONPATH=src python examples/serve_preferences.py --requests 32
  PYTHONPATH=src python examples/serve_preferences.py --requests 32 --int8
"""
import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.configs import FedConfig, GPOConfig, ServeConfig
from repro.core import (
    FederatedGPO,
    PreferenceServer,
    latency_summary,
    make_request_trace,
)
from repro.core.fairness import alignment_score, fairness_index
from repro.data import SurveyConfig, make_survey_data, split_groups


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--train-rounds", type=int, default=120)
    ap.add_argument("--hit-ratio", type=float, default=0.5)
    ap.add_argument("--int8", action="store_true")
    args = ap.parse_args()

    data = make_survey_data(SurveyConfig(seed=0))
    tr, ev = split_groups(data)
    gcfg = GPOConfig(d_embed=data.phi.shape[-1])
    fcfg = FedConfig(num_clients=len(tr), rounds=args.train_rounds)
    fed = FederatedGPO(gcfg, fcfg, data, tr, ev)
    print(f"training {args.train_rounds} federated rounds ...")
    fed.run(rounds=args.train_rounds)
    params = fed.global_params

    # the serving engine: requests with ragged (context, target) lengths
    # against unseen groups; hit-ratio controls how many share an
    # already-prefilled ICL prefix (the repeated-group serving shape)
    server = PreferenceServer(
        params, gcfg, ServeConfig(int8_weights=args.int8),
        num_options=data.num_options)
    trace = make_request_trace(data, list(ev), num_requests=args.requests,
                               hit_ratio=args.hit_ratio, seed=123)
    server.run_trace(trace[: min(8, len(trace))])  # warmup/compile
    t0 = time.time()
    results = server.run_trace(trace)
    wall = time.time() - t0
    s = latency_summary(results, wall)

    scores = jnp.asarray([
        alignment_score(
            jnp.asarray(c.pred),
            jnp.asarray(np.asarray(data.prefs)[
                trace[c.rid].meta["group"], trace[c.rid].meta["tgt_q"]]))
        for c in results])
    mode = "int8" if args.int8 else "f32"
    print(f"\nserved {s['completed']} requests ({mode}) in "
          f"{wall*1e3:.1f}ms across {len(server.batches)} batches")
    print(f"p50={s['p50_ms']:.2f}ms p99={s['p99_ms']:.2f}ms "
          f"qps={s['qps']:.0f} prefix-cache hit-rate={s['hit_rate']:.2f}")
    print(f"mean AS={float(scores.mean()):.4f}  "
          f"FI={float(fairness_index(scores)):.4f}")


if __name__ == "__main__":
    main()
