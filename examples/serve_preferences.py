"""Batched preference serving: the trained federated predictor acts as a
lightweight group-conditioned reward model (paper §5) answering batched
requests "what would group g answer to question q?".

  PYTHONPATH=src python examples/serve_preferences.py --requests 16
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FedConfig, GPOConfig
from repro.core import FederatedGPO, predict_preferences
from repro.core.fairness import alignment_score, fairness_index
from repro.data import (
    SurveyConfig,
    make_survey_data,
    sample_icl_batch,
    split_groups,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--train-rounds", type=int, default=120)
    args = ap.parse_args()

    data = make_survey_data(SurveyConfig(seed=0))
    tr, ev = split_groups(data)
    gcfg = GPOConfig(d_embed=data.phi.shape[-1])
    fcfg = FedConfig(num_clients=len(tr), rounds=args.train_rounds)
    fed = FederatedGPO(gcfg, fcfg, data, tr, ev)
    print(f"training {args.train_rounds} federated rounds ...")
    fed.run(rounds=args.train_rounds)
    params = fed.global_params

    # batched request path: vmap over (group, context) requests — this is
    # the serving engine; each request carries its own in-context examples
    @jax.jit
    def serve(keys, groups):
        def one(k, g):
            b = sample_icl_batch(k, data, g, fcfg.num_context,
                                 fcfg.num_target)
            pred = predict_preferences(params, gcfg, b.ctx_x, b.ctx_y,
                                       b.tgt_x, data.num_options)
            truth = b.tgt_y.reshape(-1, data.num_options)
            return alignment_score(pred, truth)

        return jax.vmap(one)(keys, groups)

    key = jax.random.PRNGKey(123)
    groups = jnp.asarray(np.resize(ev, args.requests), jnp.int32)
    keys = jax.random.split(key, args.requests)
    serve(keys, groups)  # warmup/compile
    t0 = time.time()
    scores = serve(keys, groups)
    jax.block_until_ready(scores)
    dt = time.time() - t0

    print(f"\nserved {args.requests} requests in {dt*1e3:.1f}ms "
          f"({args.requests/dt:.0f} req/s)")
    print(f"per-unseen-group AS: "
          f"{np.round(np.asarray(scores), 3).tolist()}")
    print(f"mean AS={float(scores.mean()):.4f}  "
          f"FI={float(fairness_index(scores)):.4f}")


if __name__ == "__main__":
    main()
