from repro.checkpoint.checkpoint import (  # noqa: F401
    save_checkpoint,
    restore_checkpoint,
    restore_checkpoint_quantized,
    latest_checkpoint,
)
