"""Pytree checkpointing to .npz (atomic, step-indexed).

Works for model params, optimizer state, and full federated state (stacked
per-client trees). On a real multi-host pod each host saves only addressable
shards; here (single-host) we gather to host memory, which is also what the
dry-run needs.

Every checkpoint carries a CRC32 content checksum (``__crc32__`` entry)
over the sorted leaf names, dtypes, shapes, and raw bytes. ``os.replace``
atomicity rules out a *torn* file, but not silent bit rot or a truncated
copy from another filesystem — ``restore_checkpoint`` recomputes the
checksum on load and raises ``ValueError`` on mismatch (pre-checksum
checkpoints, lacking the entry, still load). A corrupt zip container
(``zipfile.BadZipFile`` out of ``np.load``) is converted to ``ValueError``
too, so callers' existing OSError/ValueError/KeyError handling — e.g.
``launch/serve.py``'s actionable ``--restore`` failure — covers it.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
import zipfile
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_SEP = "|"  # flat-key separator (path components may contain '/')
_CRC_KEY = "__crc32__"  # reserved npz entry: content checksum


def _content_crc(stored: dict[str, np.ndarray]) -> int:
    """CRC32 over the checkpoint payload: sorted (name, dtype, shape,
    bytes) per leaf, chained. Covers renames and dtype/shape rewrites,
    not just flipped payload bytes."""
    crc = 0
    for k in sorted(stored):
        arr = np.ascontiguousarray(stored[k])
        crc = zlib.crc32(k.encode(), crc)
        crc = zlib.crc32(str(arr.dtype).encode(), crc)
        crc = zlib.crc32(str(arr.shape).encode(), crc)
        crc = zlib.crc32(arr.tobytes(), crc)
    return crc & 0xFFFFFFFF


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # numpy cannot serialize ml_dtypes (bfloat16 etc.): store as
            # f32 (exact superset); restore casts back to the model dtype.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _fsync_dir(directory: str) -> None:
    """fsync the directory entry so a rename survives power loss (POSIX
    durability requires syncing the parent dir, not just the file)."""
    fd = os.open(directory, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    metadata: dict | None = None) -> str:
    """Atomic + durable: write to a same-directory temp file, flush and
    fsync it, then ``os.replace`` over the final name and fsync the
    directory. A crash mid-save leaves either the old checkpoint or the
    new one — never a torn .npz — and ``latest_checkpoint`` never sees
    the ``.tmp`` names."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    stored = {k.replace("/", _SEP): v for k, v in flat.items()}
    stored[_CRC_KEY] = np.asarray(_content_crc(stored), np.uint32)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **stored)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        _fsync_dir(directory)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    if metadata is not None:
        meta_path = os.path.join(directory, f"ckpt_{step:08d}.json")
        fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(metadata, f, indent=2, default=str)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, meta_path)
            _fsync_dir(directory)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
    return path


def restore_checkpoint(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shapes/dtypes validated).
    Verifies the ``__crc32__`` content checksum when present and raises
    ``ValueError`` on mismatch or a corrupt zip container."""
    try:
        with np.load(path) as data:
            stored = {k: data[k] for k in data.files}
    except zipfile.BadZipFile as e:
        # np.load leaks the zipfile error type; normalize to ValueError so
        # callers' unreadable-checkpoint handling needs one except clause
        raise ValueError(f"corrupt checkpoint {path!r}: {e}") from e
    crc = stored.pop(_CRC_KEY, None)
    if crc is not None:
        expect = int(np.asarray(crc).ravel()[0])
        actual = _content_crc(stored)
        if actual != expect:
            raise ValueError(
                f"checkpoint {path!r} failed its content checksum "
                f"(stored crc32 {expect:#010x}, recomputed "
                f"{actual:#010x}): the file was corrupted after save")
    flat = {k.replace(_SEP, "/"): v for k, v in stored.items()}

    leaves_with_paths = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    out = []
    for p, leaf in leaves_with_paths:
        key = jax.tree_util.keystr(p)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs model {leaf.shape}")
        out.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_checkpoint_quantized(path: str, like: PyTree) -> PyTree:
    """Serving load path (DESIGN.md §12): restore the f32 GPO params from
    ``path`` (validated against ``like`` exactly as ``restore_checkpoint``)
    and quantize the dense weights to int8 ``QuantizedLinear`` leaves in
    one step. Checkpoints on disk stay f32 — quantization is a load-time
    transform, so the same artifact serves both precisions and the int8
    scales are always derived from the authoritative weights."""
    from repro.core.serving import quantize_gpo_params

    return quantize_gpo_params(restore_checkpoint(path, like))


def latest_checkpoint(directory: str) -> str | None:
    if not os.path.isdir(directory):
        return None
    best, best_step = None, -1
    for fn in os.listdir(directory):
        m = re.fullmatch(r"ckpt_(\d+)\.npz", fn)
        if m and int(m.group(1)) > best_step:
            best, best_step = os.path.join(directory, fn), int(m.group(1))
    return best
