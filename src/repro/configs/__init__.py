"""Architecture configs. Importing this package registers every assigned
architecture in ``ARCHITECTURES``."""
from repro.configs.base import (  # noqa: F401
    ARCHITECTURES,
    ATTN,
    GLOBAL,
    INPUT_SHAPES,
    MAMBA,
    AdversaryConfig,
    AggConfig,
    AvailabilityConfig,
    CompressionConfig,
    FedConfig,
    GPOConfig,
    HierarchyConfig,
    InputShape,
    ModelConfig,
    PrivacyConfig,
    ServeConfig,
    TrainConfig,
    config_dict,
    get_arch,
    override,
    smoke_variant,
)

# registration side effects
from repro.configs import (  # noqa: F401,E402
    gemma2_27b,
    gemma3_27b,
    granite_moe_3b_a800m,
    grok_1_314b,
    llava_next_34b,
    mamba2_780m,
    qwen2_0_5b,
    qwen3_32b,
    whisper_small,
    zamba2_1_2b,
)

ALL_ARCHS = tuple(ARCHITECTURES.names())
