"""Config system.

Every architecture in the assigned pool is expressed as a ``ModelConfig``;
the paper's own module is a ``GPOConfig``; the federated runtime is a
``FedConfig``.  Configs are frozen dataclasses so they can be closed over by
jitted functions and hashed as static arguments.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

from repro.utils.registry import Registry

# ---------------------------------------------------------------------------
# Block kinds
# ---------------------------------------------------------------------------
ATTN = "attn"  # full/windowed self-attention + MLP (dense or MoE)
MAMBA = "mamba"  # Mamba2 SSD block
GLOBAL = -1  # sentinel window: attend to everything (causal)


@dataclass(frozen=True)
class ModelConfig:
    """A single decoder (or encoder-decoder) LM backbone.

    The zoo is expressed with one config class: dense/GQA, MoE, SSM, hybrid,
    enc-dec, and embedding-input (VLM / audio) variants are all field
    combinations, which is what lets one `train_step` / `serve_step` and one
    sharding rule-set cover all ten assigned architectures.
    """

    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""  # citation for the assigned config

    # trunk
    num_layers: int = 2
    d_model: int = 256
    vocab_size: int = 1024

    # attention
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 64
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_logit_softcap: Optional[float] = None  # gemma2-style soft capping
    final_logit_softcap: Optional[float] = None
    rope_theta: float = 10_000.0
    rope_theta_global: Optional[float] = None  # gemma3: different theta for global
    # sliding-window pattern, cycled over attention layers. -1 == global.
    window_pattern: Tuple[int, ...] = (GLOBAL,)

    # MLP / MoE
    d_ff: int = 1024
    num_experts: int = 0  # 0 => dense MLP
    experts_per_token: int = 0
    router_aux_coef: float = 0.01
    moe_capacity_factor: float = 1.25  # tokens dropped beyond capacity

    # SSM (Mamba2 / SSD)
    ssm_state_size: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128

    # layer pattern: cycled to num_layers. ("attn",) pure transformer,
    # ("mamba",) pure SSM. Hybrid (zamba2) uses block_pattern plus
    # shared_attn_every (a single *shared-weight* attention block applied
    # after every k trunk layers, as in Zamba2).
    block_pattern: Tuple[str, ...] = (ATTN,)
    shared_attn_every: int = 0  # 0 => no shared block

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    enc_layers: int = 0
    enc_seq_len: int = 0  # fixed encoder length (e.g. 1500 audio frames)

    # input modality: "tokens" -> int32 token ids; "embeddings" -> the
    # modality frontend is a stub and the model consumes (B, S, d_model)
    # precomputed embeddings (VLM patch embeddings / audio frames).
    input_kind: str = "tokens"

    # numerics
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"

    # serving
    long_context_variant: bool = False  # pure-dense archs get a SWA override
    long_context_window: int = 4096
    # ring-buffer decode caches for sliding-window layers (periodic
    # local:global patterns): local layers allocate W slots instead of the
    # full context (§Perf optimization; off = paper-faithful baseline)
    ring_cache: bool = False

    # normalization
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    scale_embeddings: bool = False  # gemma: embed * sqrt(d_model)
    use_post_norm: bool = False  # gemma2/3 sandwich norm

    # ---------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind, block_pattern cycled to num_layers."""
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    def attn_layer_windows(self, seq_hint: int = 0) -> Tuple[int, ...]:
        """Window size per *attention* layer (cycled window_pattern).

        GLOBAL (-1) stays -1; consumers replace it with the running sequence
        length. Ordering matches the order attention layers appear in
        ``layer_kinds()``.
        """
        n_attn = sum(1 for k in self.layer_kinds() if k == ATTN)
        p = self.window_pattern
        return tuple(p[i % len(p)] for i in range(n_attn))

    def validate(self) -> None:
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, self.name
        if self.is_moe:
            assert 0 < self.experts_per_token <= self.num_experts, self.name
        kinds = set(self.layer_kinds())
        if MAMBA in kinds:
            assert self.ssm_state_size > 0, self.name
        if self.is_encoder_decoder:
            assert self.enc_layers > 0 and self.enc_seq_len > 0, self.name


@dataclass(frozen=True)
class GPOConfig:
    """The paper's module: the transformer-based preference predictor.

    An in-context neural process (Zhao et al. 2023, GPO): inputs are
    (embedding, preference) context pairs and embedding-only targets; the
    model predicts the target preferences. PluralLLM trains this with
    FedAvg across groups.
    """

    d_embed: int = 64  # frozen-backbone embedding width (4096 for Alpaca-7B)
    d_model: int = 128
    num_layers: int = 4
    num_heads: int = 4
    d_ff: int = 256
    dropout: float = 0.0
    norm_eps: float = 1e-6
    # Gaussian likelihood: if learn_sigma the head emits (mu, log_sigma),
    # else sigma=1 and Eq. 1's NLL reduces to MSE (GPO's practice).
    learn_sigma: bool = False
    param_dtype: str = "float32"
    # use the Pallas neural-process attention kernel (interpret mode on
    # CPU; native on TPU) for BOTH inference and training: the kernel
    # carries a flash-style custom VJP (DESIGN.md §8), so gpo_loss under
    # jax.grad runs the banded forward/backward grids instead of the
    # dense masked-softmax einsum. False = jnp everywhere.
    use_pallas_attention: bool = False
    # unroll factor for the depth scan in gpo_apply. The while-loop (and
    # its transpose in the backward pass) is pure overhead at the paper's
    # small num_layers; num_layers (full unroll) removes it at the cost
    # of a slightly larger executable. Same ops either way.
    layer_unroll: int = 1

    @property
    def head_dim(self) -> int:
        return self.d_model // self.num_heads


@dataclass(frozen=True)
class PrivacyConfig:
    """Differential privacy on the client→server delta path (DESIGN.md §9).

    The pipeline sits BETWEEN local training and the ``ServerAggregator``:
    each client's flattened parameter delta is L2-clipped to ``clip_norm``
    and perturbed with per-client Gaussian noise of standard deviation
    ``noise_multiplier * clip_norm`` before any reduction, so it composes
    with every registry strategy (the robust trims rank the *privatized*
    deltas; the linear family reduces them — with
    ``use_pallas_aggregation`` through the fused ``agg_clip_reduce``
    kernel). ``clip_norm == 0`` disables the pipeline entirely: the
    engines trace the exact pre-privacy computation (bit-equal, pinned by
    tests/test_privacy.py).

    Privacy accounting is the Rényi-DP moments accountant
    (``core/privacy.py::RdpAccountant``): each round is one sampled
    Gaussian mechanism with sampling rate q = batch_groups/num_clients
    (1 under full participation), RDP composes linearly over rounds, and
    the per-round ε at ``target_delta`` lands in ``History.round_eps``.
    """

    # per-client L2 clip norm S on the flattened delta; 0.0 disables the
    # whole privacy pipeline (the exact pre-privacy trace)
    clip_norm: float = 0.0
    # Gaussian noise multiplier z: per-client noise std = z * clip_norm.
    # 0.0 = clip-only (no DP guarantee; History.round_eps reports inf).
    noise_multiplier: float = 0.0
    # the δ at which the accountant converts accumulated RDP to ε
    target_delta: float = 1e-5
    # Rényi orders α the accountant tracks (integer-order sampled-
    # Gaussian bound, Mironov et al. 2019)
    accountant_orders: Tuple[int, ...] = tuple(range(2, 33)) + (
        48, 64, 128, 256)

    @property
    def enabled(self) -> bool:
        return self.clip_norm > 0.0

    @property
    def sigma(self) -> float:
        """Per-client noise standard deviation (z * S)."""
        return self.noise_multiplier * self.clip_norm

    def validate(self) -> None:
        if self.clip_norm < 0.0 or self.noise_multiplier < 0.0:
            raise ValueError("clip_norm and noise_multiplier must be >= 0")
        if self.noise_multiplier > 0.0 and self.clip_norm == 0.0:
            raise ValueError(
                "noise_multiplier > 0 requires clip_norm > 0: the noise "
                "scale is z * clip_norm, and unclipped deltas have "
                "unbounded sensitivity (no finite-σ DP guarantee exists)")
        if not 0.0 < self.target_delta < 1.0:
            raise ValueError("target_delta must lie in (0, 1)")


@dataclass(frozen=True)
class AvailabilityConfig:
    """Client availability / failure simulator (DESIGN.md §11).

    Drives the fault-injection layer of the federated round
    (``core/availability.py``): per-round, per-client Bernoulli draws —
    folded out of a per-round fault key, so the failure *schedule* is a
    deterministic function of the seed and bit-identical across the
    scan, loop, and sharded engines — decide which clients are offline,
    which crash after local training (update lost before release), and
    which straggle (their update arrives ``delay`` ∈ [1, max_staleness]
    rounds late and is aggregated with a polynomial staleness discount
    by buffered strategies). Crashed clients stay offline for
    ``rejoin_rounds`` rounds before rejoining (crash-rejoin traces).

    All of it is expressed as per-round masks / staleness vectors that
    live INSIDE the jitted round (no Python-side branching), so the
    fused ``lax.scan`` driver replays identical failure schedules.
    The default (everything benign) disables the layer *statically*:
    the engines trace the exact pre-fault computation, bit-equal to a
    default run (pinned by tests/test_availability.py, the
    privacy/compression degeneracy-pin style).
    """

    # per-round probability a client is reachable at all. 1.0 = always
    # online (disables the availability draw).
    online_prob: float = 1.0
    # probability an online client crashes AFTER local training: the
    # update is lost before release (EF residual untouched, opt state
    # reverts — the machine died), and the client stays offline for
    # ``rejoin_rounds`` further rounds.
    crash_prob: float = 0.0
    # probability an online, non-crashed client is a straggler: its
    # released update arrives ``delay`` rounds late, delay uniform in
    # [1, max_staleness]. While an upload is in flight the client is
    # busy (it does not start a new round).
    straggler_prob: float = 0.0
    # staleness bound: the largest delay a straggler update can have.
    max_staleness: int = 0
    # rounds a crashed client stays offline before rejoining.
    rejoin_rounds: int = 0

    @property
    def enabled(self) -> bool:
        return (self.online_prob < 1.0 or self.crash_prob > 0.0
                or self.straggler_prob > 0.0)

    def release_rate(self) -> float:
        """Per-round probability an (independently) sampled client's
        update is eventually released: online ∧ no crash. Stragglers DO
        release (late), so they count; the crash-rejoin and busy-while-
        in-flight dynamics only lower availability further, so this is
        an upper bound — the conservative direction for the §9 RDP
        accountant (a larger q never under-reports ε)."""
        if not self.enabled:
            return 1.0
        return self.online_prob * (1.0 - self.crash_prob)

    def validate(self) -> None:
        if not 0.0 <= self.online_prob <= 1.0:
            raise ValueError("online_prob must lie in [0, 1]")
        if not 0.0 <= self.crash_prob <= 1.0:
            raise ValueError("crash_prob must lie in [0, 1]")
        if not 0.0 <= self.straggler_prob <= 1.0:
            raise ValueError("straggler_prob must lie in [0, 1]")
        if self.max_staleness < 0 or self.rejoin_rounds < 0:
            raise ValueError(
                "max_staleness and rejoin_rounds must be >= 0")
        if self.straggler_prob > 0.0 and self.max_staleness < 1:
            raise ValueError(
                "straggler_prob > 0 requires max_staleness >= 1: a "
                "straggler's delay is drawn from [1, max_staleness]")


@dataclass(frozen=True)
class AdversaryConfig:
    """Byzantine adversarial-client simulator (DESIGN.md §13).

    Drives the attack-injection layer of the federated round
    (``core/adversary.py``): per round, exactly ``num_attackers``
    clients are marked Byzantine by draws folded out of a per-round
    Byzantine key — the attacker *schedule* is a deterministic function
    of (seed, round, client index) and bit-identical across the scan,
    loop, and sharded engines — and their released deltas (or, for
    ``label_flip``, their local training data) are corrupted before the
    privacy/codec/aggregation stages see them. The threat model is the
    strongest standard one: attackers are omniscient colluders who know
    the honest updates of the round (``alie`` uses their empirical
    moments), but the server-side defenses (krum / multi_krum /
    geomedian / norm_bound, DESIGN.md §13) never learn which clients
    are corrupt.

    The default (``kind="none"``) disables the layer *statically*: the
    engines trace the exact pre-attack computation, bit-equal to a
    pre-PR run (pinned by tests/test_adversary.py, the availability /
    privacy / compression degeneracy-pin style).
    """

    # none | sign_flip | scaled | gaussian | alie | label_flip
    kind: str = "none"
    # Byzantine population size f: exactly f clients (re-drawn each
    # round) attack. Defenses tolerate f below their breakdown point
    # (krum/multi_krum need f <= C - 3 selectable, robust f < C/2).
    num_attackers: int = 0
    # scaled model-replacement factor λ: attacker ships λ·d (λ large
    # drags a mean-style aggregator toward the malicious direction).
    scale: float = 10.0
    # additive Gaussian attack: per-coordinate noise std added to the
    # attacker's honest delta.
    noise_std: float = 1.0
    # ALIE (Baruch et al. 2019): colluding attackers all ship
    # mean_honest + z · std_honest per coordinate — inside the honest
    # spread, so distance-based defenses struggle; z is the deviation.
    alie_z: float = 1.0

    @property
    def enabled(self) -> bool:
        return self.kind != "none" and self.num_attackers > 0

    @property
    def data_level(self) -> bool:
        """Attack corrupts the local training data, not the released
        delta (the delta-stage attack transform is the identity)."""
        return self.kind == "label_flip"

    def validate(self) -> None:
        kinds = ("none", "sign_flip", "scaled", "gaussian", "alie",
                 "label_flip")
        if self.kind not in kinds:
            raise ValueError(
                f"adversary kind {self.kind!r} must be one of {kinds}")
        if self.num_attackers < 0:
            raise ValueError("num_attackers must be >= 0")
        if self.noise_std < 0.0:
            raise ValueError("noise_std must be >= 0")


@dataclass(frozen=True)
class CompressionConfig:
    """Client→server delta-compression stage (DESIGN.md §10).

    Sits BETWEEN the privacy pipeline and the ``ServerAggregator``: the
    (possibly privatized) flat client delta is compressed AFTER the DP
    release — compression is post-processing of the released value, so ε
    is unaffected — and the server consumes the decompressed
    ("transmitted") values. Two codecs:

    * ``int8`` — per-client symmetric quantization: scale s_c =
      max|d_c| / 127, values stochastically rounded to int8 (unbiased:
      E[Q(x)] = x; ``stochastic=False`` rounds to nearest). On the
      sharded engine the robust-aggregator family all-gathers the int8
      payload + f32 scales instead of f32 vectors (~4× fewer collective
      bytes); the linear family dequantizes shard-locally before its
      unchanged one-psum.
    * ``topk`` — magnitude sparsification: per client, entries with
      |d_c[p]| below the ⌈topk_frac·P⌉-th largest magnitude are zeroed
      (ties at the threshold are kept, so at least k survive).

    ``error_feedback`` carries an EF21-style per-client residual
    e_c ← (d̃_c + e_c) − Q(d̃_c + e_c) in the round state (the fused
    scan carry, next to ``AggState``), so compression error accumulates
    into later rounds instead of being lost — the standard fix for
    biased codecs like top-k. ``kind="none"`` (default) disables the
    stage entirely: the engines statically trace the exact
    pre-compression computation (bit-equal, pinned by
    tests/test_compression.py).
    """

    kind: str = "none"  # none | int8 | topk
    # topk: fraction of the flattened parameter axis kept per client
    topk_frac: float = 0.01
    # EF21-style error-feedback residual carried across rounds
    error_feedback: bool = True
    # int8: stochastic rounding (unbiased) vs round-to-nearest
    stochastic: bool = True

    @property
    def enabled(self) -> bool:
        return self.kind != "none"

    @property
    def needs_rng(self) -> bool:
        """The codec draws per-client randomness (stochastic rounding)."""
        return self.kind == "int8" and self.stochastic

    def validate(self) -> None:
        if self.kind not in ("none", "int8", "topk"):
            raise ValueError(
                f"compression kind {self.kind!r} must be one of "
                "'none' | 'int8' | 'topk'")
        if self.kind == "topk" and not 0.0 < self.topk_frac <= 1.0:
            raise ValueError(
                f"topk_frac={self.topk_frac} must lie in (0, 1]")


@dataclass(frozen=True)
class ServeConfig:
    """Multi-tenant reward-model serving engine (DESIGN.md §12).

    Drives ``core/serving.py::PreferenceServer``: a FIFO request queue
    with admission control, a continuous batcher that pads ragged
    context/target lengths to a small static *bucket* set (so the
    jitted ``prefill``/``decode`` shape family stays compile-cached), an
    LRU prefix cache of per-layer context K/V keyed on the shared ICL
    context (hits skip prefill entirely and are bit-equal to the cold
    path — the neural-process mask makes the context encoding exactly
    target-independent), and an optional int8 weight-only inference
    path that quantizes checkpoint weights at load time with the §10
    symmetric-quantization contract.
    """

    # largest number of requests fused into one decode dispatch
    max_batch: int = 8
    # padded batch sizes: the batcher pads a partial batch up to the
    # smallest bucket >= its size (dummy rows, sliced off) so the
    # compiled shape set is the bucket list, not every integer <= max
    batch_buckets: Tuple[int, ...] = (1, 2, 4, 8)
    # padded context / target lengths in POINTS (m questions x A
    # options); requests pad to the smallest bucket that fits. Target
    # buckets must be multiples of the survey's num_options so padded
    # rows reshape into whole questions.
    ctx_buckets: Tuple[int, ...] = (40, 80, 160)
    tgt_buckets: Tuple[int, ...] = (20, 40, 80, 160)
    # admission control: submissions beyond this queue depth are
    # rejected (the caller sees backpressure instead of unbounded
    # latency). 0 = unbounded.
    max_queue: int = 128
    # prefix-cache capacity in entries (LRU eviction); 0 disables the
    # cache (every request prefills — the benchmark cold baseline).
    cache_entries: int = 256
    # quantize the predictor's dense weights to int8 at load time and
    # serve through the fused int8 matmul kernel (DESIGN.md §12)
    int8_weights: bool = False

    def validate(self) -> None:
        for name, buckets in (("batch_buckets", self.batch_buckets),
                              ("ctx_buckets", self.ctx_buckets),
                              ("tgt_buckets", self.tgt_buckets)):
            if not buckets or list(buckets) != sorted(set(buckets)):
                raise ValueError(
                    f"{name} must be non-empty strictly ascending, got "
                    f"{buckets}")
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.max_batch > self.batch_buckets[-1]:
            raise ValueError(
                f"max_batch={self.max_batch} exceeds the largest batch "
                f"bucket {self.batch_buckets[-1]}")
        if self.max_queue < 0 or self.cache_entries < 0:
            raise ValueError("max_queue and cache_entries must be >= 0")


@dataclass(frozen=True)
class AggConfig:
    """Server-aggregation strategy (DESIGN.md §7).

    The paper's Eq. 2-3 FedAvg is ``name="fedavg"`` with the defaults
    below. Every other strategy consumes the same client payload — the
    parameter *delta* each client produced this round — and differs only
    in the stateful server update applied to the weighted delta moment
    (momentum / Adam / Yogi), in the reduction itself (rank-trimmed mean,
    coordinate-wise median), or in how the per-group weights are formed
    (APPA-style fairness-adaptive weights). ``prox_mu`` is the one
    client-side knob: a FedProx proximal term added to the local
    objective, independent of the server rule.
    """

    # registry name: fedavg | fedavgm | fedadam | fedyogi | fedprox |
    # trimmed_mean | median | adaptive | fedbuff | krum | multi_krum |
    # geomedian  (repro.core.aggregation)
    name: str = "fedavg"
    # server learning rate on the aggregated delta (1.0 == paper FedAvg)
    server_lr: float = 1.0
    # fedavgm: server momentum on the delta moment (0.0 degenerates to
    # fedavg exactly)
    momentum: float = 0.9
    # fedadam / fedyogi (Reddi et al. 2021): first/second-moment decays
    # and the adaptivity floor tau. (beta1=0, beta2=1, tau=1) degenerates
    # to fedavg exactly (v stays 0, the update is delta / (0 + 1)).
    beta1: float = 0.9
    beta2: float = 0.99
    tau: float = 1e-3
    # fedprox client-side proximal coefficient mu: local loss grows
    # (mu/2) * ||theta - theta_global||^2. 0.0 == plain local Adam.
    prox_mu: float = 0.0
    # trimmed_mean: fraction of clients trimmed at EACH end of the
    # per-coordinate ranking (k = floor(frac * C), clamped to 2k < C).
    # 0.0 degenerates to the weighted mean exactly.
    trim_frac: float = 0.1
    # adaptive (APPA-style): per-group weights  w_g ∝ p_g * exp(temp *
    # (score_g - mean score))  where score_g is an EMA of the group's
    # local loss — groups the global model serves worst get upweighted,
    # driving the fairness-index metric. temp=0.0 degenerates to the
    # dataset-size weights exactly.
    fair_temp: float = 1.0
    fair_decay: float = 0.9
    # fedbuff (FedBuff-style staleness-aware buffered aggregation,
    # DESIGN.md §11): the server accumulates released client updates in
    # a buffer and applies one server step only once ``buffer_k`` fresh-
    # enough updates have arrived. buffer_k=1 flushes every round and
    # degenerates to fedavg exactly (given full participation).
    buffer_k: int = 4
    # polynomial staleness discount s(τ) = (1 + τ)^(-staleness_power)
    # applied to updates arriving τ rounds late (FedBuff's 1/sqrt(1+τ)
    # at the 0.5 default). The fault-aware round discounts late
    # arrivals for EVERY strategy through this knob; 0.0 recovers the
    # classic synchronous baseline that lands stale deltas at full
    # weight — the failure mode fedbuff's discounted buffering exists
    # to fix (the BENCH_async.json fedavg cells pin it to 0.0).
    staleness_power: float = 0.5
    # krum / multi_krum (Blanchard et al. 2017): the number of Byzantine
    # clients the selection must tolerate. Each client is scored by the
    # sum of its (n - f - 2) smallest squared distances to the others;
    # krum returns the single lowest-scoring delta, multi_krum the
    # weighted mean of the ``multi_krum_m`` lowest. Breakdown point:
    # selection is sound while 2f + 2 < n.
    num_malicious: int = 0
    multi_krum_m: int = 3
    # geomedian: smoothed Weiszfeld iterations and the smoothing floor
    # eps on the per-client distances (jit-stable fixed iteration count;
    # Pillutla et al. 2022). Breakdown point 1/2 of the weight mass.
    geomedian_iters: int = 8
    geomedian_eps: float = 1e-6
    # server-side per-client L2 norm bound (DESIGN.md §13): each
    # client's released delta row is clipped to this norm BEFORE the
    # reduce, bounding any single client's pull on a linear aggregate.
    # Composes with every strategy; 0.0 disables (bit-equal paths).
    norm_bound: float = 0.0


@dataclass(frozen=True)
class HierarchyConfig:
    """Two-level client→edge→server aggregation topology (DESIGN.md §14).

    ``num_edges`` E partitions the round's participants into E contiguous
    edge shards (edge e owns client rows [e·C/E, (e+1)·C/E)); each edge
    pre-reduces its own clients before a cross-edge reduction produces
    the server update:

    * linear family — per-edge weighted partial sums, summed across
      edges (the same weighted moment, reassociated edge-first; the
      sharded engine keeps its single psum, which IS the composed
      two-hop on a real torus).
    * robust family — each edge runs the server rule over its OWN
      clients (per-edge trim / edge-local krum candidate selection) to
      one candidate row, then the same rule runs over the E candidates
      weighted by edge mass. The sharded engine's all-gather splits into
      an intra-edge hop (C/E rows) plus a cross-edge hop of only E
      candidate rows — O(E·P) instead of O(C·P) — and the cross-edge
      hop carries the §10 int8 wire layout when the codec is on. The
      breakdown point changes: attackers concentrated in one edge can
      capture its candidate (see §14).

    ``num_edges == 1`` disables the topology entirely: the pipeline's
    flat aggregate stage is traced unchanged (bit-equal, pinned by
    tests/test_hierarchy.py). Divisibility of the participant count by
    ``num_edges`` is checked by the engines, where it is known.
    """

    num_edges: int = 1

    @property
    def enabled(self) -> bool:
        return self.num_edges > 1

    def validate(self, num_clients: Optional[int] = None) -> None:
        if self.num_edges < 1:
            raise ValueError("num_edges must be >= 1")
        if (num_clients is not None and self.enabled
                and num_clients % self.num_edges != 0):
            raise ValueError(
                f"hierarchy.num_edges={self.num_edges} must divide the "
                f"round's participant count ({num_clients}): edges are "
                "contiguous equal-size client shards")


@dataclass(frozen=True)
class FedConfig:
    """PluralLLM federated runtime (paper §3.1–3.2, §4.3)."""

    num_clients: int = 10  # |G_train|
    num_eval_groups: int = 7  # |G_eval| (60/40 split in the paper)
    rounds: int = 1300  # communication rounds (paper: 1300)
    local_epochs: int = 6  # paper: 6 local epochs per round
    lr: float = 3e-4  # paper: Adam 3e-4
    eval_every: int = 10  # paper: every 10 rounds
    # in-context split per local epoch
    num_context: int = 16  # m context points
    num_target: int = 16  # n - m target points
    batch_groups: int = 0  # 0 => all clients participate each round (paper)
    # re-initialize client Adam moments each round (the paper leaves this
    # unspecified; stale moments vs freshly-aggregated params can slow FL)
    reset_opt_each_round: bool = False
    # round driver: "scan" fuses blocks of rounds into one jitted
    # lax.scan with on-device metric accumulation (DESIGN.md §3); "loop"
    # is the per-round Python dispatch (one jit call + host sync per
    # round), kept for A/B benchmarking and as the paper-faithful
    # reference execution order.
    engine: str = "scan"
    # unroll factor for the fused scan driver (lax.scan unroll): trades
    # compile time for less per-round loop machinery. 1 = no unroll.
    scan_unroll: int = 1
    # aggregate with the Pallas reduction kernels on the flattened
    # (C, P) client-delta matrix instead of the per-leaf jnp reductions
    # (same math either way; see DESIGN.md §4, §7). Applies to both the
    # vmapped and the shard_map engines.
    use_pallas_aggregation: bool = False
    # server-aggregation strategy (DESIGN.md §7); the default AggConfig
    # is the paper's Eq. 2-3 FedAvg.
    agg: AggConfig = AggConfig()
    # differential privacy on the client→server deltas (DESIGN.md §9):
    # per-client L2 clip + Gaussian noise applied BEFORE the aggregator,
    # with Rényi-DP accounting into History.round_eps. The default
    # (clip_norm=0) traces the exact pre-privacy computation.
    privacy: PrivacyConfig = PrivacyConfig()
    # client→server delta compression (DESIGN.md §10): int8 stochastic
    # quantization or top-k sparsification with an EF21-style error-
    # feedback residual, applied AFTER the DP release and BEFORE the
    # aggregator. The default (kind="none") traces the exact
    # pre-compression computation.
    compression: CompressionConfig = CompressionConfig()
    # client availability / failure simulation (DESIGN.md §11): per-
    # round offline/crash/straggler masks with deterministic fold-out
    # keys, a staleness buffer for late arrivals, and graceful-
    # degradation semantics for every aggregation strategy. The default
    # (everything benign) traces the exact pre-fault computation.
    avail: AvailabilityConfig = AvailabilityConfig()
    # Byzantine adversarial-client simulation (DESIGN.md §13): per-
    # round attacker masks with deterministic fold-out keys and delta-
    # or data-level corruption injected between local training and the
    # privacy/codec/aggregation stages. The default (kind="none")
    # traces the exact pre-attack computation.
    adversary: AdversaryConfig = AdversaryConfig()
    # two-level client→edge→server aggregation topology (DESIGN.md §14):
    # num_edges edge shards pre-reduce their clients before the cross-
    # edge reduction — the robust family's dominant all-gather shrinks
    # from O(C·P) to O(E·P) cross-edge, multiplicative with the §10 int8
    # wire layout. The default (num_edges=1) traces the exact flat
    # aggregate stage.
    hierarchy: HierarchyConfig = HierarchyConfig()
    # hard-error instead of warning when a configuration leaks
    # un-privatized client statistics around the DP release — today:
    # agg.name == "adaptive" keeps raw-loss EMAs (DESIGN.md §9) while
    # noise_multiplier > 0 promises a DP guarantee on the deltas.
    strict_privacy: bool = False
    # runtime-level override of GPOConfig.use_pallas_attention: None
    # defers to the model config; True/False forces the attention path
    # for every engine built from this FedConfig (FederatedGPO,
    # make_sharded_round, CentralizedGPO, the --gpo-fed dryrun) without
    # editing the model config it was handed.
    use_pallas_attention: Optional[bool] = None
    seed: int = 0

    def resolve_gpo(self, gpo_cfg: GPOConfig) -> GPOConfig:
        """GPOConfig with this runtime's overrides applied — the single
        plumbing point every training engine calls before tracing."""
        if (self.use_pallas_attention is not None
                and self.use_pallas_attention
                != gpo_cfg.use_pallas_attention):
            gpo_cfg = replace(
                gpo_cfg, use_pallas_attention=self.use_pallas_attention)
        return gpo_cfg


@dataclass(frozen=True)
class TrainConfig:
    """Generic backbone training (LM objective) settings."""

    global_batch: int = 8
    seq_len: int = 128
    steps: int = 10
    lr: float = 3e-4
    warmup_steps: int = 10
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    seed: int = 0
    # remat policy for the layer scan: "none" | "full" | "dots"
    remat: str = "none"


@dataclass(frozen=True)
class InputShape:
    """One of the four assigned workload shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# registry: arch id -> ModelConfig factory
ARCHITECTURES: Registry = Registry("architecture")


def get_arch(name: str) -> ModelConfig:
    cfg = ARCHITECTURES.get(name)
    cfg.validate()
    return cfg


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced variant of the same family: 2 layers, d_model<=512,
    <=4 experts — runnable on CPU in a test."""
    updates = dict(
        num_layers=2,
        d_model=min(cfg.d_model, 256),
        num_heads=4,
        num_kv_heads=min(4, max(1, cfg.num_kv_heads)),
        head_dim=64,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        param_dtype="float32",
        activation_dtype="float32",
    )
    if cfg.is_moe:
        updates.update(num_experts=4, experts_per_token=min(2, cfg.experts_per_token),
                       moe_capacity_factor=4.0)  # drop-free for exact tests
    if cfg.ssm_state_size:
        updates.update(ssm_state_size=min(cfg.ssm_state_size, 32), ssm_head_dim=32,
                       ssm_chunk=16)
    if cfg.is_encoder_decoder:
        updates.update(enc_layers=2, enc_seq_len=32)
    if cfg.shared_attn_every:
        updates.update(shared_attn_every=2)
    if len(cfg.window_pattern) > 1 or cfg.window_pattern[0] != GLOBAL:
        # keep the local/global alternation but shrink windows
        updates.update(
            window_pattern=tuple(min(w, 16) if w > 0 else w for w in cfg.window_pattern)
        )
    out = replace(cfg, name=cfg.name + "-smoke", **updates)
    out.validate()
    return out


def override(cfg, **kw):
    """Dataclass-replace with validation (public config-override hook)."""
    out = replace(cfg, **kw)
    if isinstance(out, ModelConfig):
        out.validate()
    return out


def config_dict(cfg) -> dict:
    return dataclasses.asdict(cfg)
