"""gemma2-27b — dense, 1:1 local:global alternation, logit softcapping
[arXiv:2408.00118]."""
from repro.configs.base import ARCHITECTURES, ATTN, GLOBAL, ModelConfig


@ARCHITECTURES.register("gemma2-27b")
def gemma2_27b() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b",
        family="dense",
        source="arXiv:2408.00118 (Gemma 2)",
        num_layers=46,
        d_model=4608,
        num_heads=32,
        num_kv_heads=16,  # GQA kv=16
        head_dim=128,
        d_ff=36864,
        vocab_size=256000,
        block_pattern=(ATTN,),
        window_pattern=(4096, GLOBAL),  # local, global alternating
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        rope_theta=10_000.0,
        tie_embeddings=True,
        scale_embeddings=True,
        use_post_norm=True,
    )
