"""gemma3-27b — dense, 5:1 local:global, 128k context [hf:google/gemma-3-1b-pt
family card]."""
from repro.configs.base import ARCHITECTURES, ATTN, GLOBAL, ModelConfig


@ARCHITECTURES.register("gemma3-27b")
def gemma3_27b() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b",
        family="dense",
        source="hf:google/gemma-3-1b-pt (Gemma 3 family)",
        num_layers=62,
        d_model=5376,
        num_heads=32,
        num_kv_heads=16,  # GQA kv=16
        head_dim=128,
        d_ff=21504,
        vocab_size=262144,
        qk_norm=True,
        block_pattern=(ATTN,),
        # 5 local : 1 global, local window 1024
        window_pattern=(1024, 1024, 1024, 1024, 1024, GLOBAL),
        rope_theta=10_000.0,  # local layers
        rope_theta_global=1_000_000.0,  # global layers (128k scaling)
        final_logit_softcap=None,  # gemma3 dropped softcap; qk-norm instead
        tie_embeddings=True,
        scale_embeddings=True,
        use_post_norm=True,
    )
