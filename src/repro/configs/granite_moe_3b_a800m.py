"""granite-moe-3b-a800m — fine-grained MoE, 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base].

The assignment line reads "MoE 40e top-8 — 32 experts top-8"; we follow the
explicit trailing note (32 experts, top-8) — recorded in DESIGN.md.
"""
from repro.configs.base import ARCHITECTURES, ATTN, GLOBAL, ModelConfig


@ARCHITECTURES.register("granite-moe-3b-a800m")
def granite_moe_3b_a800m() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        source="hf:ibm-granite/granite-3.0-1b-a400m-base (granite 3.0 MoE)",
        num_layers=32,
        d_model=1536,
        num_heads=24,
        num_kv_heads=8,  # GQA kv=8
        head_dim=64,  # 24 * 64 == 1536
        d_ff=512,  # per-expert (fine-grained experts)
        vocab_size=49155,
        num_experts=32,
        experts_per_token=8,
        block_pattern=(ATTN,),
        window_pattern=(GLOBAL,),
        tie_embeddings=True,
        long_context_variant=True,
        long_context_window=4096,
    )
