"""grok-1-314b — 314B-parameter MoE decoder [hf:xai-org/grok-1]."""
from repro.configs.base import ARCHITECTURES, ATTN, GLOBAL, ModelConfig


@ARCHITECTURES.register("grok-1-314b")
def grok_1_314b() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b",
        family="moe",
        source="hf:xai-org/grok-1",
        num_layers=64,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,  # GQA kv=8
        head_dim=128,  # 48 * 128 == 6144
        d_ff=32768,
        vocab_size=131072,
        num_experts=8,
        experts_per_token=2,
        block_pattern=(ATTN,),
        window_pattern=(GLOBAL,),
        rope_theta=10_000.0,
        tie_embeddings=False,
        # pure full attention: long_500k uses the documented SWA variant
        long_context_variant=True,
        long_context_window=4096,
    )
