"""llava-next-34b — VLM language backbone; anyres vision tiling is a stub
frontend that supplies patch embeddings [hf:llava-hf/llava-v1.6-mistral-7b-hf]."""
from repro.configs.base import ARCHITECTURES, ATTN, GLOBAL, ModelConfig


@ARCHITECTURES.register("llava-next-34b")
def llava_next_34b() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b",
        family="vlm",
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf (anyres tiling)",
        num_layers=60,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,  # GQA kv=8
        head_dim=128,  # 56 * 128 == 7168
        d_ff=20480,
        vocab_size=64000,
        block_pattern=(ATTN,),
        window_pattern=(GLOBAL,),
        # the ViT/SigLIP encoder + projector are a STUB: input_specs()
        # provides pre-projected patch+text embeddings of shape (B, S, d).
        input_kind="embeddings",
        tie_embeddings=False,
        long_context_variant=True,
        long_context_window=4096,
    )
