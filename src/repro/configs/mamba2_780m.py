"""mamba2-780m — attention-free SSM with state-space duality [arXiv:2405.21060]."""
from repro.configs.base import ARCHITECTURES, MAMBA, ModelConfig


@ARCHITECTURES.register("mamba2-780m")
def mamba2_780m() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m",
        family="ssm",
        source="arXiv:2405.21060 (Mamba2 / SSD)",
        num_layers=48,
        d_model=1536,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,  # attention-free, no separate MLP (Mamba2 block includes it)
        vocab_size=50280,
        ssm_state_size=128,
        ssm_expand=2,  # d_inner = 3072
        ssm_head_dim=64,  # 48 SSD heads
        ssm_conv_width=4,
        ssm_chunk=128,
        block_pattern=(MAMBA,),
        tie_embeddings=True,
    )
