"""qwen2-0.5b — small dense GQA with QKV bias [arXiv:2407.10671]."""
from repro.configs.base import ARCHITECTURES, ATTN, GLOBAL, ModelConfig


@ARCHITECTURES.register("qwen2-0.5b")
def qwen2_0_5b() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b",
        family="dense",
        source="arXiv:2407.10671 (Qwen2)",
        num_layers=24,
        d_model=896,
        num_heads=14,
        num_kv_heads=2,  # GQA kv=2
        head_dim=64,  # 14 * 64 == 896
        d_ff=4864,
        vocab_size=151936,
        qkv_bias=True,
        block_pattern=(ATTN,),
        window_pattern=(GLOBAL,),
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        long_context_variant=True,
        long_context_window=4096,
    )
