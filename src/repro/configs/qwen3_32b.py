"""qwen3-32b — dense GQA with qk-norm [hf:Qwen/Qwen3-8B family card]."""
from repro.configs.base import ARCHITECTURES, ATTN, GLOBAL, ModelConfig


@ARCHITECTURES.register("qwen3-32b")
def qwen3_32b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b",
        family="dense",
        source="hf:Qwen/Qwen3-8B (Qwen3 family)",
        num_layers=64,
        d_model=5120,
        num_heads=64,
        num_kv_heads=8,  # GQA kv=8
        head_dim=128,  # qwen3 uses explicit head_dim=128 (q_dim != d_model)
        d_ff=25600,
        vocab_size=151936,
        qk_norm=True,
        block_pattern=(ATTN,),
        window_pattern=(GLOBAL,),
        rope_theta=1_000_000.0,
        tie_embeddings=False,
        long_context_variant=True,
        long_context_window=4096,
    )
