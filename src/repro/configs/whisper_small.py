"""whisper-small — encoder-decoder audio model; mel+conv frontend is a stub
that supplies frame embeddings [arXiv:2212.04356]."""
from repro.configs.base import ARCHITECTURES, ATTN, GLOBAL, ModelConfig


@ARCHITECTURES.register("whisper-small")
def whisper_small() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        family="audio",
        source="arXiv:2212.04356 (Whisper)",
        num_layers=12,  # decoder layers
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab_size=51865,
        block_pattern=(ATTN,),
        window_pattern=(GLOBAL,),
        is_encoder_decoder=True,
        enc_layers=12,
        enc_seq_len=1500,  # 30 s of audio after the conv frontend (stubbed)
        input_kind="tokens",  # decoder side; encoder consumes frame embeddings
        tie_embeddings=True,
        long_context_variant=True,  # decoder self-attn SWA for long_500k
        long_context_window=4096,
    )
