"""zamba2-1.2b — Mamba2 trunk + shared-weight attention blocks [arXiv:2411.15242]."""
from repro.configs.base import ARCHITECTURES, MAMBA, ModelConfig


@ARCHITECTURES.register("zamba2-1.2b")
def zamba2_1_2b() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        source="arXiv:2411.15242 (Zamba2: Mamba2 + shared attn blocks)",
        num_layers=38,  # 38 Mamba2 trunk layers
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,  # spec: GQA kv=32 (== MHA for the shared block)
        head_dim=64,  # 32 * 64 == 2048
        d_ff=8192,  # MLP of the shared attention block
        vocab_size=32000,
        ssm_state_size=64,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_chunk=128,
        block_pattern=(MAMBA,),
        shared_attn_every=6,  # one shared-weight attn+MLP block every 6 layers
        tie_embeddings=True,
    )
