# The paper's primary contribution: the GPO preference predictor trained
# with FedAvg across groups (PluralLLM), plus the centralized baseline,
# fairness metrics, FedLoRA, and the federated backbone trainers.
from repro.core.gpo import (  # noqa: F401
    GPOPrefix,
    gpo_apply,
    gpo_decode,
    gpo_loss,
    gpo_prefill,
    init_gpo_params,
    predict_preferences,
)
from repro.core.fedavg import (  # noqa: F401
    broadcast_to_clients,
    fedavg_allreduce,
    fedavg_flat,
    fedavg_stacked,
    normalize_weights,
)
from repro.core.aggregation import (  # noqa: F401
    AGGREGATORS,
    AggState,
    ServerAggregator,
    make_aggregator,
)
from repro.core.federated import FederatedGPO, History, make_sharded_round  # noqa: F401
from repro.core.adversary import (  # noqa: F401
    apply_attack,
    attacker_mask,
    check_defense_composition,
    flip_preferences,
    fold_byz_key,
    norm_clip_rows,
)
from repro.core.pipeline import (  # noqa: F401
    STAGE_NAMES,
    RoundPipeline,
    make_pipeline,
)
from repro.core.availability import (  # noqa: F401
    FaultState,
    RoundSchedule,
    advance_fault_state,
    fault_draws,
    fold_fault_key,
    init_fault_state,
    masked_mean_weights,
    masked_robust_reduce_flat,
    round_schedule,
    staleness_discount,
    tree_where,
)
from repro.core.compression import (  # noqa: F401
    client_uniform,
    dequantize_int8,
    quantize_int8,
    sparsify_topk,
    topk_thresholds,
    transport_delta_flat,
)
from repro.core.privacy import (  # noqa: F401
    RdpAccountant,
    clip_noise_reduce,
    clip_scales,
    make_accountant,
    private_delta_flat,
    privatize_flat,
)
from repro.core.serving import (  # noqa: F401
    BatchRecord,
    Completed,
    PreferenceServer,
    Request,
    latency_summary,
    make_request_trace,
    quantize_gpo_params,
)
from repro.core.centralized import CentralizedGPO  # noqa: F401
from repro.core import fairness  # noqa: F401
from repro.core.lora import apply_lora, init_lora, lora_param_count  # noqa: F401
from repro.core.trainer import (  # noqa: F401
    greedy_decode,
    lm_loss,
    make_backbone_fedavg_round,
    make_fedlora_round,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
