"""Byzantine adversarial-client simulation (DESIGN.md §13).

The attack-injection layer of the federated round. Three pieces:

1. **A deterministic attacker schedule.** Per round, a *Byzantine key*
   folds out of the round key (``fold_byz_key``, the §11 fault-key
   scheme with its own tag); per-client draws fold the (static) client
   index into it. Exactly ``AdversaryConfig.num_attackers`` clients —
   the f lowest uniform draws — are Byzantine this round, so the
   attacker schedule is a pure function of (seed, round, client index):
   the fused ``lax.scan`` driver, the per-round loop driver, and
   ``make_sharded_round`` replay bit-identical attack traces, and every
   mesh shard recomputes the full-population mask REPLICATED (no
   collective moves to agree on who is corrupt).

2. **Delta-level attack transforms.** ``apply_attack`` corrupts the
   attacked rows of the raw flat (C, P) delta matrix BETWEEN local
   training and the privacy/codec release — the Byzantine client
   controls what it ships, so its corruption passes through DP clipping
   and the transport codec like any honest update:

   * ``sign_flip`` — ship −d (gradient ascent on the global objective);
   * ``scaled`` — ship λ·d (model replacement; a large λ dominates any
     mean-style aggregate);
   * ``gaussian`` — ship d + σ·ε with deterministic per-client fold-out
     noise keys (GLOBAL client indices, so the stacked and sharded
     engines corrupt identically);
   * ``alie`` — "a little is enough" (Baruch et al. 2019): colluding
     attackers all ship mean_honest + z·std_honest per coordinate,
     staying inside the honest empirical spread so distance-based
     defenses cannot separate them. The honest moments come from the
     non-attacked rows (omniscient-collusion threat model); the sharded
     engine psums the masked moment sums (``honest_stats_sharded``) —
     extra collectives are acceptable because only the attack-OFF
     config is byte-pinned.

3. **Data-level preference poisoning.** ``kind="label_flip"`` corrupts
   the attacked clients' LOCAL TRAINING DATA instead of their deltas:
   ``flip_preferences`` maps each preference row p(a|q) to
   (1 − p)/(A − 1) — a simplex-to-simplex pointwise map that exactly
   reverses the preference ordering — inside ``_make_local_train``
   (the delta-stage transform is the identity). The resulting update is
   a *plausible* model delta, the hard case for norm- and distance-
   based defenses.

The benign default (``kind="none"``) disables the layer *statically*:
every engine traces the exact pre-attack computation, bit-equal to a
pre-PR round (pinned by tests/test_adversary.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AdversaryConfig

# fold_in tag deriving the round's Byzantine key from the round key (the
# §9/§10/§11 scheme: one fixed constant, distinct from _NOISE_TAG,
# _QUANT_TAG and _FAULT_TAG).
_BYZ_TAG = 0xBAD0C
# fold_in index deriving an attacker's Gaussian-attack noise key from
# its per-client Byzantine key (index 0 is the attacker-selection draw).
_ATTACK_NOISE_IDX = 1


def fold_byz_key(round_key: jnp.ndarray) -> jnp.ndarray:
    """The round's Byzantine key. Folded from the ROUND key (not the
    per-client training keys) so every engine — and every shard — can
    derive the full population's attacker mask from one replicated
    value."""
    return jax.random.fold_in(round_key, _BYZ_TAG)


def attacker_draws(byz_key: jnp.ndarray, num_clients: int) -> jnp.ndarray:
    """(C,) per-client uniforms; client c's draw depends only on
    (byz_key, c), so subsampling, sharding, and engine choice cannot
    perturb it."""
    def one(c):
        return jax.random.uniform(jax.random.fold_in(byz_key, c), (),
                                  jnp.float32)

    return jax.vmap(one)(jnp.arange(num_clients, dtype=jnp.int32))


def attacker_mask(byz_key: jnp.ndarray, num_clients: int,
                  num_attackers: int) -> jnp.ndarray:
    """(C,) bool: EXACTLY min(f, C) clients attack this round — the f
    lowest uniform draws (a double argsort gives each client its rank;
    jnp argsort is stable, so the mask is deterministic even under
    ties). Re-drawn every round: the Byzantine population moves, the
    harder setting for stateful defenses."""
    f = min(int(num_attackers), num_clients)
    if f <= 0:
        return jnp.zeros((num_clients,), bool)
    u = attacker_draws(byz_key, num_clients)
    rank = jnp.argsort(jnp.argsort(u))
    return rank < f


def attack_noise(byz_key: jnp.ndarray, gids: jnp.ndarray,
                 num_params: int) -> jnp.ndarray:
    """(rows, P) standard normals for the ``gaussian`` attack, keyed by
    GLOBAL client ids so a sharded row and its stacked counterpart draw
    identical noise."""
    def one(g):
        k = jax.random.fold_in(jax.random.fold_in(byz_key, g),
                               _ATTACK_NOISE_IDX)
        return jax.random.normal(k, (num_params,), jnp.float32)

    return jax.vmap(one)(gids.astype(jnp.int32))


def honest_stats(vecs: jnp.ndarray,
                 mask: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Coordinate-wise (mean, std) over the NON-attacked rows of a
    (rows, P) matrix — the empirical spread ALIE steers within. Uses
    the moment form E[x²] − E[x]² so the sharded psum variant computes
    the identical estimator."""
    h = (~mask).astype(jnp.float32)[:, None]
    n = jnp.maximum(jnp.sum(h), 1.0)
    x = vecs.astype(jnp.float32)
    s1 = jnp.sum(h * x, axis=0)
    s2 = jnp.sum(h * x * x, axis=0)
    mean = s1 / n
    var = jnp.maximum(s2 / n - mean * mean, 0.0)
    return mean, jnp.sqrt(var)


def honest_stats_sharded(vecs: jnp.ndarray, mask: jnp.ndarray,
                         axes) -> tuple[jnp.ndarray, jnp.ndarray]:
    """``honest_stats`` over a client-sharded (C_local, P) matrix: the
    masked moment sums psum over the client mesh axes, so colluding
    attackers on different shards agree on the honest spread."""
    h = (~mask).astype(jnp.float32)[:, None]
    x = vecs.astype(jnp.float32)
    n = jnp.maximum(jax.lax.psum(jnp.sum(h), axes), 1.0)
    s1 = jax.lax.psum(jnp.sum(h * x, axis=0), axes)
    s2 = jax.lax.psum(jnp.sum(h * x * x, axis=0), axes)
    mean = s1 / n
    var = jnp.maximum(s2 / n - mean * mean, 0.0)
    return mean, jnp.sqrt(var)


def apply_attack(vecs: jnp.ndarray, mask: jnp.ndarray,
                 adv: AdversaryConfig, byz_key: jnp.ndarray,
                 gids: jnp.ndarray, *,
                 stats: Optional[tuple] = None) -> jnp.ndarray:
    """Corrupt the attacked rows of the raw flat (rows, P) delta matrix.
    ``mask``/``gids`` are this engine's view of the population: the
    attacked flag and GLOBAL client id per row. ``stats`` overrides the
    ALIE honest moments (the sharded engine passes its psum'd ones).
    ``kind`` is static config — the none/label_flip identity never
    traces an attack op."""
    if not adv.enabled or adv.data_level:
        return vecs
    x = vecs.astype(jnp.float32)
    if adv.kind == "sign_flip":
        bad = -x
    elif adv.kind == "scaled":
        bad = adv.scale * x
    elif adv.kind == "gaussian":
        bad = x + adv.noise_std * attack_noise(byz_key, gids, x.shape[1])
    elif adv.kind == "alie":
        mean, std = stats if stats is not None else honest_stats(x, mask)
        bad = jnp.broadcast_to(mean + adv.alie_z * std, x.shape)
    else:  # pragma: no cover - AdversaryConfig.validate rejects earlier
        raise ValueError(f"unknown delta-level attack {adv.kind!r}")
    return jnp.where(mask[:, None], bad, x)


def flip_preferences(y: jnp.ndarray, num_options: int) -> jnp.ndarray:
    """Label-flip poisoning on flattened preference targets: each point
    carries p(a|q) for one option, and (1 − p)/(A − 1) keeps every
    question's row on the simplex (rows sum to 1) while exactly
    reversing the preference ordering — the most-preferred option
    becomes least-preferred. Pointwise, so it needs no per-question
    regrouping of the flattened (t·A,) layout."""
    return (1.0 - y.astype(jnp.float32)) / float(max(num_options - 1, 1))


def norm_clip_rows(vecs: jnp.ndarray, bound: float) -> jnp.ndarray:
    """Server-side norm-bounding defense (``AggConfig.norm_bound``):
    scale each RECEIVED client row to L2 norm ≤ bound, so no single
    client can pull a linear aggregate further than bound/C · server_lr.
    Same floor semantics as the §9 client-side clip (zero rows keep
    scale 1); unlike §9 this clips what the server heard, after any
    DP/codec release, and carries no privacy claim."""
    x = vecs.astype(jnp.float32)
    norms = jnp.sqrt(jnp.sum(jnp.square(x), axis=1))
    scale = jnp.minimum(1.0, bound / jnp.maximum(norms, 1e-12))
    return x * scale[:, None]


_DEFENSE_COMPOSITION_MSG = (
    "agg.name='adaptive' reweighs groups by their RAW per-round local "
    "losses while a Byzantine defense is engaged "
    "(adversary.kind={kind!r}, agg.norm_bound={nb}): a validation-loss-"
    "dependent rule is both un-privatized under noise_multiplier={z} > 0 "
    "(the §9 side channel) and directly attacker-steerable — a Byzantine "
    "client reports whatever loss inflates its own weight, bypassing the "
    "delta-level defense entirely (DESIGN.md §13). Use a loss-free "
    "strategy (krum/geomedian/median) for a defended DP run, or set "
    "FedConfig.strict_privacy=False to proceed with this warning.")


def check_defense_composition(fed_cfg) -> None:
    """Guard the defended-run + adaptive-aggregation + DP-noise
    foot-gun: when an adversarial context is configured (an attack
    simulation or server-side norm bounding) AND the aggregation rule
    depends on client-reported validation losses AND DP noise promises
    a guarantee, the loss channel is simultaneously a privacy leak and
    an unprotected attack surface. Warns loudly by default;
    ``FedConfig.strict_privacy=True`` hard-errors (mirrors
    ``privacy.check_adaptive_privacy``)."""
    defended = (fed_cfg.adversary.enabled
                or fed_cfg.agg.norm_bound > 0.0)
    if (defended and fed_cfg.agg.name == "adaptive"
            and fed_cfg.privacy.enabled
            and fed_cfg.privacy.noise_multiplier > 0.0):
        msg = _DEFENSE_COMPOSITION_MSG.format(
            kind=fed_cfg.adversary.kind, nb=fed_cfg.agg.norm_bound,
            z=fed_cfg.privacy.noise_multiplier)
        if fed_cfg.strict_privacy:
            raise ValueError(msg)
        import warnings
        warnings.warn(msg, UserWarning, stacklevel=2)
