"""Pluggable server aggregation — one algorithm becomes a family
(DESIGN.md §7).

The delta contract: every round, client g trains locally from the
broadcast global model and ships the *delta* d_g = theta_g - theta^t.
The server forms a weighted moment of the deltas (or a robust
order-statistic of them) and applies a stateful update:

    Delta^t   = reduce_g(w_g, d_g)                  (reduce)
    theta^t+1 = theta^t + server_update(Delta^t)    (apply)

Plain FedAvg is the degenerate member (weighted-mean reduce, identity
server update with lr 1): theta + sum_g w_g (theta_g - theta) ==
sum_g w_g theta_g, Eq. 3 exactly (up to float reassociation, since the
weights are normalized). Everything the registry adds — FedAvgM server
momentum, FedAdam/FedYogi server moments (Reddi et al. 2021), the
rank-trimmed mean / coordinate-wise median robust reduces (Yin et al.
2018), APPA-style fairness-adaptive group weights — lives behind the
same three-callable contract, so both ``FederatedGPO`` drivers, the
``shard_map`` production round, and the backbone/LoRA trainers consume
any strategy unchanged:

* ``init(global_params) -> AggState`` — server-side state (momentum /
  moment trees, adaptive per-group scores). The state is a plain pytree:
  it rides in the fused scan carry, replicates across mesh shards, and
  checkpoints like parameters.
* ``weigh(state, weights, idx) -> weights`` — per-round weight
  transform; identity except for ``adaptive``.
* ``reduce(deltas, weights) -> delta`` / ``reduce_flat`` — contraction
  over the client axis. ``linear`` strategies are a weighted sum (under
  ``shard_map`` this is ONE weighted psum; with
  ``use_pallas_aggregation`` the Pallas delta-moment kernel); robust
  strategies rank per coordinate (the Pallas sort/trim kernel).
* ``apply(state, global_params, delta, losses, idx)`` — the stateful
  server update; deterministic given the reduced delta, so under
  ``shard_map`` every shard computes it redundantly on the replicated
  psum output (no second collective).

``step`` composes weigh -> reduce -> apply for the client-stacked
engines; the sharded engine calls the pieces around its collectives.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AggConfig
from repro.core.fedavg import fedavg_stacked
from repro.kernels import (
    agg_momentum_reduce,
    agg_pairwise_dists,
    agg_trimmed_reduce,
    fedavg_reduce,
)
from repro.utils.registry import Registry
from repro.utils.pytree import (
    tree_flatten_to_vector,
    tree_index,
    tree_ravel_clients,
    tree_unflatten_from_vector,
)

PyTree = Any

AGGREGATORS: Registry = Registry("aggregator")


class AggState(NamedTuple):
    """Server-side aggregator state (uniform across strategies so every
    engine carries one structure; unused slots are scalar zeros)."""

    step: jnp.ndarray  # rounds aggregated so far
    m: PyTree  # momentum / first-moment tree (fedavgm, fedadam, fedyogi)
    v: PyTree  # second-moment tree (fedadam, fedyogi)
    scores: PyTree  # adaptive: {"ema", "seen"} (num_clients,) arrays; else 0


@dataclass(frozen=True)
class ServerAggregator:
    """(init, weigh, reduce, apply) over parameter-delta pytrees."""

    name: str
    cfg: AggConfig
    linear: bool  # weighted-sum reduce (ONE psum) vs order-statistic
    needs_losses: bool  # apply consumes per-client losses (adaptive)
    init: Callable[[PyTree], AggState]
    weigh: Callable  # (state, weights, idx) -> weights
    reduce: Callable  # (stacked_deltas, weights) -> delta
    reduce_flat: Callable  # ((C, P), (C,)) -> (P,)  [sharded/kernel form]
    apply: Callable  # (state, global, delta, losses, idx) -> (global, state)
    step: Optional[Callable] = None  # weigh+reduce+apply; set in __post_init__
    # buffered strategies (fedbuff) defer the server step until enough
    # released updates accumulate; the fault-aware round path feeds their
    # apply the realized mass/released counts (DESIGN.md §11)
    buffered: bool = False

    def __post_init__(self):
        if self.step is None:
            def step(state, global_params, deltas, weights, losses=None,
                     idx=None, **kw):
                w = self.weigh(state, weights, idx)
                delta = self.reduce(deltas, w)
                return self.apply(state, global_params, delta,
                                  losses=losses, idx=idx, **kw)

            object.__setattr__(self, "step", step)


def make_aggregator(cfg: AggConfig, *, num_clients: int,
                    use_pallas: bool = False) -> ServerAggregator:
    """Build the configured strategy. ``use_pallas`` routes the client-
    axis reductions through the kernels in ``kernels/agg_reduce.py``."""
    builder = AGGREGATORS.get(cfg.name)
    return builder(cfg, num_clients=num_clients, use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------
def _zeros_state(global_params: PyTree, *, with_m=False,
                 with_v=False) -> AggState:
    zt = lambda: jax.tree.map(  # noqa: E731
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), global_params)
    zero = jnp.zeros((), jnp.float32)
    return AggState(
        step=jnp.zeros((), jnp.int32),
        m=zt() if with_m else zero,
        v=zt() if with_v else zero,
        scores=zero)


def _identity_weigh(state, weights, idx):
    return weights


def _linear_reduce(use_pallas: bool):
    """Weighted delta moment: per-leaf jnp contraction, or the Pallas
    reduction on the raveled (C, P) matrix."""
    if not use_pallas:
        return fedavg_stacked, _flat_weighted_mean

    def reduce(deltas, weights):
        like = tree_index(deltas, 0)
        vecs = tree_ravel_clients(deltas)
        return tree_unflatten_from_vector(
            fedavg_reduce(vecs, weights.astype(jnp.float32)), like)

    def reduce_flat(vecs, weights):
        return fedavg_reduce(vecs, weights.astype(jnp.float32))

    return reduce, reduce_flat


def _flat_weighted_mean(vecs, weights):
    return jnp.einsum("c,cp->p", weights.astype(jnp.float32),
                      vecs.astype(jnp.float32))


def _trim_k(c: int, frac: float) -> int:
    """floor(frac*C), clamped so at least one client survives."""
    return min(int(frac * c), (c - 1) // 2)


def trimmed_mean_reduce_flat(vecs: jnp.ndarray, weights: jnp.ndarray,
                             k: int) -> jnp.ndarray:
    """Pure-jnp rank-trimmed weighted mean on (C, P): stable argsort per
    coordinate, drop k at each end, weighted mean of the survivors with
    weights renormalized. k=0 short-circuits to the exact weighted mean
    (no renormalizing division)."""
    if k == 0:
        return _flat_weighted_mean(vecs, weights)
    x = vecs.astype(jnp.float32)
    c = x.shape[0]
    order = jnp.argsort(x, axis=0)  # jnp argsort is stable
    xs = jnp.take_along_axis(x, order, axis=0)
    ws = weights.astype(jnp.float32)[order]
    keep = ((jnp.arange(c) >= k) & (jnp.arange(c) < c - k))
    keep = keep.astype(jnp.float32)[:, None]
    return jnp.sum(keep * ws * xs, axis=0) / jnp.sum(keep * ws, axis=0)


def _robust_reduce(use_pallas: bool, k_of: Callable[[int], int]):
    """Rank-trim reduce; ``k_of(C)`` maps the (static) client count to
    the trim depth, so partial-participation rounds trim consistently."""

    def reduce_flat(vecs, weights):
        k = k_of(vecs.shape[0])
        if use_pallas and k > 0:
            return agg_trimmed_reduce(vecs, weights.astype(jnp.float32),
                                      trim=k)
        return trimmed_mean_reduce_flat(vecs, weights, k)

    def reduce(deltas, weights):
        like = tree_index(deltas, 0)
        vecs = tree_ravel_clients(deltas)
        return tree_unflatten_from_vector(reduce_flat(vecs, weights), like)

    return reduce, reduce_flat


def _apply_sgd(cfg: AggConfig):
    """theta += server_lr * Delta (FedAvg and the robust strategies)."""

    def apply(state: AggState, global_params, delta, losses=None, idx=None,
              **kw):
        new_g = jax.tree.map(
            lambda g, d: (g.astype(jnp.float32)
                          + cfg.server_lr * d.astype(jnp.float32)
                          ).astype(g.dtype), global_params, delta)
        return new_g, state._replace(step=state.step + 1)

    return apply


# ---------------------------------------------------------------------------
# registry entries. Each builder returns a ServerAggregator; the registry
# stores zero-arg factories (utils/registry.py contract) yielding them.
# ---------------------------------------------------------------------------
def _make_fedavg(cfg, *, num_clients, use_pallas):
    reduce, reduce_flat = _linear_reduce(use_pallas)
    return ServerAggregator(
        name=cfg.name, cfg=cfg, linear=True, needs_losses=False,
        init=lambda g: _zeros_state(g),
        weigh=_identity_weigh, reduce=reduce, reduce_flat=reduce_flat,
        apply=_apply_sgd(cfg))


@AGGREGATORS.register("fedavg")
def _fedavg_factory():
    return _make_fedavg


# fedprox: the proximal term is client-side — FedConfig.agg.prox_mu must
# be set > 0 and feeds the mu-regularizer in federated._make_local_train
# (the GPO engine; the backbone/LoRA trainers reject prox_mu > 0). The
# server rule is FedAvg; the name is registered so configs read as the
# recipe they run.
@AGGREGATORS.register("fedprox")
def _fedprox_factory():
    return _make_fedavg


def _make_fedavgm(cfg, *, num_clients, use_pallas):
    reduce, reduce_flat = _linear_reduce(use_pallas)
    beta = cfg.momentum

    def apply(state: AggState, global_params, delta, losses=None, idx=None,
              **kw):
        new_m = jax.tree.map(
            lambda m, d: beta * m + d.astype(jnp.float32), state.m, delta)
        new_g = jax.tree.map(
            lambda g, m: (g.astype(jnp.float32) + cfg.server_lr * m
                          ).astype(g.dtype), global_params, new_m)
        return new_g, state._replace(step=state.step + 1, m=new_m)

    step = None
    if use_pallas:
        # fused path: the delta-moment kernel emits (Delta, beta*m+Delta)
        # in one pass over the client stream (kernels/agg_reduce.py)
        def step(state, global_params, deltas, weights, losses=None,
                 idx=None, **kw):
            vecs = tree_ravel_clients(deltas)
            m_vec = tree_flatten_to_vector(state.m)
            _, nm_vec = agg_momentum_reduce(
                vecs, weights.astype(jnp.float32), m_vec, beta=beta)
            new_m = tree_unflatten_from_vector(nm_vec, state.m)
            new_g = jax.tree.map(
                lambda g, m: (g.astype(jnp.float32) + cfg.server_lr * m
                              ).astype(g.dtype), global_params, new_m)
            return new_g, state._replace(step=state.step + 1, m=new_m)

    return ServerAggregator(
        name=cfg.name, cfg=cfg, linear=True, needs_losses=False,
        init=lambda g: _zeros_state(g, with_m=True),
        weigh=_identity_weigh, reduce=reduce, reduce_flat=reduce_flat,
        apply=apply, step=step)


@AGGREGATORS.register("fedavgm")
def _fedavgm_factory():
    return _make_fedavgm


def _make_fedadaptive(yogi: bool):
    """FedAdam / FedYogi (Reddi et al. 2021): server Adam on the delta."""

    def make(cfg, *, num_clients, use_pallas):
        reduce, reduce_flat = _linear_reduce(use_pallas)
        b1, b2, tau = cfg.beta1, cfg.beta2, cfg.tau

        def apply(state: AggState, global_params, delta, losses=None,
                  idx=None, **kw):
            new_m = jax.tree.map(
                lambda m, d: b1 * m + (1 - b1) * d.astype(jnp.float32),
                state.m, delta)
            if yogi:
                new_v = jax.tree.map(
                    lambda v, d: v - (1 - b2) * jnp.square(
                        d.astype(jnp.float32)) * jnp.sign(
                        v - jnp.square(d.astype(jnp.float32))),
                    state.v, delta)
            else:
                new_v = jax.tree.map(
                    lambda v, d: b2 * v
                    + (1 - b2) * jnp.square(d.astype(jnp.float32)),
                    state.v, delta)
            new_g = jax.tree.map(
                lambda g, m, v: (g.astype(jnp.float32) + cfg.server_lr * m
                                 / (jnp.sqrt(v) + tau)).astype(g.dtype),
                global_params, new_m, new_v)
            return new_g, state._replace(step=state.step + 1, m=new_m,
                                         v=new_v)

        return ServerAggregator(
            name=cfg.name, cfg=cfg, linear=True, needs_losses=False,
            init=lambda g: _zeros_state(g, with_m=True, with_v=True),
            weigh=_identity_weigh, reduce=reduce, reduce_flat=reduce_flat,
            apply=apply)

    return make


@AGGREGATORS.register("fedadam")
def _fedadam_factory():
    return _make_fedadaptive(yogi=False)


@AGGREGATORS.register("fedyogi")
def _fedyogi_factory():
    return _make_fedadaptive(yogi=True)


def _make_trimmed(cfg, *, num_clients, use_pallas):
    reduce, reduce_flat = _robust_reduce(
        use_pallas, lambda c: _trim_k(c, cfg.trim_frac))
    return ServerAggregator(
        name=cfg.name, cfg=cfg, linear=False, needs_losses=False,
        init=lambda g: _zeros_state(g),
        weigh=_identity_weigh, reduce=reduce, reduce_flat=reduce_flat,
        apply=_apply_sgd(cfg))


@AGGREGATORS.register("trimmed_mean")
def _trimmed_factory():
    return _make_trimmed


def _make_median(cfg, *, num_clients, use_pallas):
    reduce, reduce_flat = _robust_reduce(use_pallas, lambda c: (c - 1) // 2)
    return ServerAggregator(
        name=cfg.name, cfg=cfg, linear=False, needs_losses=False,
        init=lambda g: _zeros_state(g),
        weigh=_identity_weigh, reduce=reduce, reduce_flat=reduce_flat,
        apply=_apply_sgd(cfg))


@AGGREGATORS.register("median")
def _median_factory():
    return _make_median


def _make_adaptive(cfg, *, num_clients, use_pallas):
    """APPA-style adaptive per-group weights: groups whose local loss EMA
    sits above the mean get upweighted (temperature fair_temp), pushing
    the fairness index (Eq. 5-6) up; scores update from this round's
    per-client losses. The ``scores`` slot tracks per-client (ema, seen):
    a client's first observation SEEDS its EMA, and clients never sampled
    yet (partial participation) are treated as sitting at the observed
    mean — never down-weighted merely for not having been sampled."""
    reduce, reduce_flat = _linear_reduce(use_pallas)
    temp, decay = cfg.fair_temp, cfg.fair_decay
    base_apply = _apply_sgd(cfg)

    def weigh(state: AggState, weights, idx):
        if temp == 0.0:
            return weights  # exact dataset-size weights (fedavg)
        ema, seen = state.scores["ema"], state.scores["seen"]
        mean_seen = jnp.sum(ema * seen) / jnp.maximum(jnp.sum(seen), 1.0)
        s_full = jnp.where(seen > 0, ema, mean_seen)
        s = s_full if idx is None else s_full[idx]
        w = weights * jnp.exp(temp * (s - jnp.mean(s)))
        return w / jnp.sum(w)

    def apply(state: AggState, global_params, delta, losses=None, idx=None,
              mask=None, **kw):
        new_g, state = base_apply(state, global_params, delta)
        if losses is not None:
            losses = losses.astype(jnp.float32)
            if idx is None:
                idx = jnp.arange(losses.shape[0])
            ema, seen = state.scores["ema"], state.scores["seen"]
            new_ema = jnp.where(seen[idx] > 0,
                                decay * ema[idx] + (1 - decay) * losses,
                                losses)
            new_seen = jnp.ones_like(seen[idx])
            if mask is not None:
                # fault mode: only clients whose update was RELEASED this
                # round observed a trustworthy loss — crashed/offline rows
                # keep their previous score (DESIGN.md §11)
                new_ema = jnp.where(mask, new_ema, ema[idx])
                new_seen = jnp.where(mask, 1.0, seen[idx])
            state = state._replace(scores={
                "ema": ema.at[idx].set(new_ema),
                "seen": seen.at[idx].set(new_seen)})
        return new_g, state

    def init(global_params):
        state = _zeros_state(global_params)
        return state._replace(scores={
            "ema": jnp.zeros((num_clients,), jnp.float32),
            "seen": jnp.zeros((num_clients,), jnp.float32)})

    return ServerAggregator(
        name=cfg.name, cfg=cfg, linear=True, needs_losses=True,
        init=init, weigh=weigh, reduce=reduce, reduce_flat=reduce_flat,
        apply=apply)


@AGGREGATORS.register("adaptive")
def _adaptive_factory():
    return _make_adaptive


def _make_fedbuff(cfg, *, num_clients, use_pallas):
    """FedBuff-style staleness-aware buffered aggregation (Nguyen et al.
    2022; DESIGN.md §11). The reduce is the same ONE-psum weighted delta
    moment as fedavg; the server step is deferred: the reduced update
    accumulates into a buffer (``AggState.m``) together with its weight
    mass and released-client count (``AggState.scores``), and the server
    applies  theta += server_lr * buffer / mass  only once at least
    ``buffer_k`` client updates have been absorbed since the last flush.

    Staleness discounting happens UPSTREAM in the fault-aware round
    (stale arrivals' weights are scaled by (1+tau)^-staleness_power
    before the reduce); this apply only needs the realized ``mass`` and
    ``released`` count. The synchronous engines pass neither: the
    defaults (mass=1, released=|participants|) make buffer_k <= C flush
    every round — fedbuff with buffer_k=1 is bit-for-bit fedavg there."""
    reduce, reduce_flat = _linear_reduce(use_pallas)
    base_lr = cfg.server_lr
    buffer_k = cfg.buffer_k

    def init(global_params):
        state = _zeros_state(global_params, with_m=True)
        return state._replace(scores={
            "count": jnp.zeros((), jnp.float32),
            "mass": jnp.zeros((), jnp.float32)})

    def apply(state: AggState, global_params, delta, losses=None, idx=None,
              mass=None, released=None, **kw):
        if mass is None:
            mass = jnp.ones((), jnp.float32)  # weights pre-normalized
        if released is None:
            released = jnp.asarray(
                idx.shape[0] if idx is not None else num_clients,
                jnp.float32)
        mass = jnp.asarray(mass, jnp.float32)
        released = jnp.asarray(released, jnp.float32)
        buf = jax.tree.map(
            lambda m, d: m + mass * d.astype(jnp.float32), state.m, delta)
        count = state.scores["count"] + released
        total = state.scores["mass"] + mass
        flush = count >= buffer_k
        scale = jnp.where(flush, base_lr / jnp.maximum(total, 1e-12), 0.0)
        new_g = jax.tree.map(
            lambda g, b: (g.astype(jnp.float32) + scale * b
                          ).astype(g.dtype), global_params, buf)
        new_m = jax.tree.map(lambda b: jnp.where(flush, 0.0, b), buf)
        new_scores = {"count": jnp.where(flush, 0.0, count),
                      "mass": jnp.where(flush, 0.0, total)}
        return new_g, state._replace(step=state.step + 1, m=new_m,
                                     scores=new_scores)

    return ServerAggregator(
        name=cfg.name, cfg=cfg, linear=True, needs_losses=False,
        init=init, weigh=_identity_weigh, reduce=reduce,
        reduce_flat=reduce_flat, apply=apply, buffered=True)


@AGGREGATORS.register("fedbuff")
def _fedbuff_factory():
    return _make_fedbuff


# ---------------------------------------------------------------------------
# Byzantine-robust defenses (DESIGN.md §13). All are mask-tolerant via
# the weights vector: rows with weight 0 (crashed / buffered clients in
# the fault-aware round) are excluded from selection and never chosen.
# ---------------------------------------------------------------------------
# finite sentinel for masked pairwise distances / scores. NOT inf: with
# very few active clients every score would be inf and argmin over
# all-inf is a degenerate tie; a large-but-finite sentinel keeps the
# ordering (active < inactive) strict and the arithmetic NaN-free.
_BIG = jnp.float32(1e30)


def _pairwise_sq_dists(vecs: jnp.ndarray, use_pallas: bool) -> jnp.ndarray:
    if use_pallas:
        return agg_pairwise_dists(vecs)
    x = vecs.astype(jnp.float32)
    sq = jnp.sum(x * x, axis=1)
    return jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * x @ x.T, 0.0)


def krum_scores(vecs: jnp.ndarray, weights: jnp.ndarray, f: int, *,
                use_pallas: bool = False) -> jnp.ndarray:
    """(C,) Krum scores (Blanchard et al. 2017): client c's score is the
    sum of its n − f − 2 smallest squared distances to OTHER active
    clients (n = number of active rows). Lower is better — an attacker
    far from the honest cluster accumulates huge distances. ``weights``
    only gates activity here (weight 0 ⇒ excluded from both scoring and
    selection); magnitudes don't shift the order statistics."""
    x = vecs.astype(jnp.float32)
    c = x.shape[0]
    active = weights.astype(jnp.float32) > 0.0
    n = jnp.sum(active.astype(jnp.int32))
    d = _pairwise_sq_dists(x, use_pallas)
    pair_ok = active[:, None] & active[None, :]
    off_diag = ~jnp.eye(c, dtype=bool)
    d = jnp.where(pair_ok & off_diag, d, _BIG)
    # n is traced (fault rounds mask rows dynamically), so the neighbor
    # count is a traced clamp, applied as a rank predicate on the sorted
    # distance rows rather than a static slice.
    nn = jnp.clip(n - f - 2, 1, c - 1)
    ds = jnp.sort(d, axis=1)
    ranks = jnp.arange(c)[None, :]
    score = jnp.sum(jnp.where(ranks < nn, ds, 0.0), axis=1)
    return jnp.where(active, score, _BIG * jnp.float32(c))


def _make_krum(multi: bool):
    def make(cfg, *, num_clients, use_pallas):
        f = cfg.num_malicious
        m_sel = max(1, min(cfg.multi_krum_m, num_clients))

        def reduce_flat(vecs, weights):
            x = vecs.astype(jnp.float32)
            scores = krum_scores(x, weights, f, use_pallas=use_pallas)
            if not multi:
                return x[jnp.argmin(scores)]
            # multi-Krum: weighted mean of the m_sel best-scored rows
            # (weights renormalized over the selection; zero-weight rows
            # may enter the selection set but contribute 0 mass)
            rank = jnp.argsort(jnp.argsort(scores))
            sel = rank < min(m_sel, x.shape[0])
            w = jnp.where(sel, weights.astype(jnp.float32), 0.0)
            w = w / jnp.maximum(jnp.sum(w), 1e-12)
            return jnp.einsum("c,cp->p", w, x)

        def reduce(deltas, weights):
            like = tree_index(deltas, 0)
            return tree_unflatten_from_vector(
                reduce_flat(tree_ravel_clients(deltas), weights), like)

        return ServerAggregator(
            name=cfg.name, cfg=cfg, linear=False, needs_losses=False,
            init=lambda g: _zeros_state(g),
            weigh=_identity_weigh, reduce=reduce, reduce_flat=reduce_flat,
            apply=_apply_sgd(cfg))

    return make


@AGGREGATORS.register("krum")
def _krum_factory():
    return _make_krum(multi=False)


@AGGREGATORS.register("multi_krum")
def _multi_krum_factory():
    return _make_krum(multi=True)


def geometric_median_flat(vecs: jnp.ndarray, weights: jnp.ndarray, *,
                          iters: int, eps: float) -> jnp.ndarray:
    """Smoothed Weiszfeld iteration for the weighted geometric median
    (Pillutla et al. 2022): y ← Σ_c (w_c/max(‖x_c−y‖, eps)) x_c /
    Σ_c (w_c/max(‖x_c−y‖, eps)), a FIXED ``iters`` steps from the
    weighted mean — fixed so the computation is jit-stable (no traced
    convergence test) and every engine runs the identical schedule.
    Zero-weight rows drop out exactly (w_c = 0 ⇒ zero Weiszfeld mass).
    Breakdown point 1/2: any minority weight mass moves the optimum a
    bounded distance, no matter how far the corrupt rows sit."""
    x = vecs.astype(jnp.float32)
    w = jnp.maximum(weights.astype(jnp.float32), 0.0)
    wn = w / jnp.maximum(jnp.sum(w), 1e-12)
    y0 = jnp.einsum("c,cp->p", wn, x)

    def body(_, y):
        dist = jnp.sqrt(jnp.sum(jnp.square(x - y[None, :]), axis=1))
        inv = w / jnp.maximum(dist, eps)
        return (jnp.einsum("c,cp->p", inv, x)
                / jnp.maximum(jnp.sum(inv), 1e-12))

    return jax.lax.fori_loop(0, iters, body, y0)


def _make_geomedian(cfg, *, num_clients, use_pallas):
    iters, eps = cfg.geomedian_iters, cfg.geomedian_eps

    def reduce_flat(vecs, weights):
        return geometric_median_flat(vecs, weights, iters=iters, eps=eps)

    def reduce(deltas, weights):
        like = tree_index(deltas, 0)
        return tree_unflatten_from_vector(
            reduce_flat(tree_ravel_clients(deltas), weights), like)

    return ServerAggregator(
        name=cfg.name, cfg=cfg, linear=False, needs_losses=False,
        init=lambda g: _zeros_state(g),
        weigh=_identity_weigh, reduce=reduce, reduce_flat=reduce_flat,
        apply=_apply_sgd(cfg))


@AGGREGATORS.register("geomedian")
def _geomedian_factory():
    return _make_geomedian
