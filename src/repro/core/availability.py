"""Client availability / failure simulation (DESIGN.md §11).

The fault-injection layer of the federated round. Three pieces:

1. **A deterministic failure schedule.** Per round, a *fault key* is
   folded out of the round key (``fold_fault_key``); per-client draws
   fold the (static) client index into it (``fault_draws``). The
   schedule — who is offline, who crashes after local training, who
   straggles and by how many rounds — is therefore a pure function of
   (seed, round, client index): the fused ``lax.scan`` driver, the
   per-round loop driver, and ``make_sharded_round`` replay
   bit-identical failure schedules, and every shard of a mesh can
   recompute the full-population schedule REPLICATED (no collective
   moves to agree on who failed).

2. **Fault state that rides the round carry.** ``FaultState`` holds the
   crash-rejoin trace (``offline_until``), and a one-slot-per-client
   staleness buffer for in-flight straggler uploads: the released
   payload (``pending`` — the only parameter-sized piece, shardable
   over the client axis), its arrival round, its weight at send time,
   and the round it was computed (``birth``, for staleness
   discounting). A client with an upload in flight is busy and does not
   start a new round — the realistic straggler trace.

3. **Degraded-mode reductions.** Linear strategies renormalize their
   weights over the survivors; the robust rank-trims shrink their trim
   depth with the *surviving* client count (``masked_robust_reduce``
   computes k from a traced n instead of the static C); a zero-survivor
   round is a no-op on params, ``AggState``, and the EF residual
   (``tree_where`` gates the applied update). Everything is masks and
   ``jnp.where`` — no Python branching inside the jitted round.

EF composition (DESIGN.md §11): a client's EF21 residual row advances
exactly when its compressed delta is *released* — fresh uploads and
straggler sends (they do compress and transmit; the network is what's
slow) advance it at training time; crashed and offline clients never
release, so their rows are untouched.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import AvailabilityConfig

PyTree = Any

# fold_in tag deriving the round's fault key from the round key (the §9
# noise-key scheme: one fixed constant, distinct from every other tag /
# split index the round consumes).
_FAULT_TAG = 0xFA117
# empty slot sentinel for the pending-arrival round
NO_PENDING = jnp.int32(-1)
# denominator floor for survivor-mass renormalization (never divides by
# zero; zero-survivor rounds are where-gated to a no-op anyway)
_MASS_FLOOR = 1e-12


def fold_fault_key(round_key: jnp.ndarray) -> jnp.ndarray:
    """The round's fault key. Folded from the ROUND key (not the
    per-client training keys) so every engine — and every shard — can
    derive the full population's schedule from one replicated value."""
    return jax.random.fold_in(round_key, _FAULT_TAG)


class FaultDraws(NamedTuple):
    """Raw per-client randomness for one round (all (C,))."""

    online: jnp.ndarray  # bool: reachable this round
    crash: jnp.ndarray  # bool: would crash after local train (if online)
    straggle: jnp.ndarray  # bool: would straggle (if online, no crash)
    delay: jnp.ndarray  # int32 in [1, max_staleness]: straggler delay


def fault_draws(fault_key: jnp.ndarray, num_clients: int,
                cfg: AvailabilityConfig) -> FaultDraws:
    """Per-client Bernoulli/delay draws from fold-out keys. Client c's
    draws depend only on (fault_key, c) — subsampling, sharding, and
    engine choice cannot perturb them."""
    hi = max(cfg.max_staleness, 1) + 1

    def one(c):
        k = jax.random.fold_in(fault_key, c)
        u = jax.random.uniform(k, (3,), jnp.float32)
        d = jax.random.randint(jax.random.fold_in(k, 1), (), 1, hi)
        return u, d

    u, delay = jax.vmap(one)(jnp.arange(num_clients, dtype=jnp.int32))
    online = u[:, 0] < cfg.online_prob
    crash = u[:, 1] < cfg.crash_prob
    straggle = jnp.logical_and(u[:, 2] < cfg.straggler_prob,
                               cfg.max_staleness > 0)
    return FaultDraws(online=online, crash=crash, straggle=straggle,
                      delay=delay.astype(jnp.int32))


class FaultState(NamedTuple):
    """Cross-round fault state (rides the scan carry / sharded round
    arguments). ``pending`` is the only parameter-sized leaf — under
    ``make_sharded_round`` it shards over the client axis while every
    other leaf stays replicated (``launch/sharding.py::
    fault_state_shardings``), because the schedule metadata is
    replicated-computable but the payloads live with their clients."""

    round: jnp.ndarray  # () int32: rounds elapsed under this schedule
    offline_until: jnp.ndarray  # (C,) int32: crash-rejoin gate
    pending: jnp.ndarray  # (C, P) f32: in-flight released payloads
    pending_due: jnp.ndarray  # (C,) int32 arrival round; NO_PENDING=empty
    pending_weight: jnp.ndarray  # (C,) f32: raw weight at send time
    pending_birth: jnp.ndarray  # (C,) int32: round the update was made


def init_fault_state(num_clients: int, num_params: int) -> FaultState:
    return FaultState(
        round=jnp.zeros((), jnp.int32),
        offline_until=jnp.zeros((num_clients,), jnp.int32),
        pending=jnp.zeros((num_clients, num_params), jnp.float32),
        pending_due=jnp.full((num_clients,), NO_PENDING, jnp.int32),
        pending_weight=jnp.zeros((num_clients,), jnp.float32),
        pending_birth=jnp.zeros((num_clients,), jnp.int32))


class RoundSchedule(NamedTuple):
    """This round's resolved failure schedule (all (C,) bool except
    ``delay``/``staleness``). Disjoint by construction:
    available = fresh ∪ crashed ∪ straggle."""

    available: jnp.ndarray  # online ∧ rejoined ∧ not busy: trains now
    fresh: jnp.ndarray  # trains AND releases this round
    crashed: jnp.ndarray  # trains, update lost before release
    straggle: jnp.ndarray  # trains, release arrives `delay` rounds late
    arrive: jnp.ndarray  # a buffered upload lands this round
    delay: jnp.ndarray  # (C,) int32 straggler delays
    staleness: jnp.ndarray  # (C,) int32: rounds late, 0 where ~arrive


def round_schedule(fault_key: jnp.ndarray, state: FaultState,
                   cfg: AvailabilityConfig, num_clients: int
                   ) -> RoundSchedule:
    """Resolve the raw draws against the carried fault state."""
    d = fault_draws(fault_key, num_clients, cfg)
    in_flight = jnp.logical_and(state.pending_due >= 0,
                                state.pending_due > state.round)
    rejoined = state.round >= state.offline_until
    available = d.online & rejoined & ~in_flight
    crashed = available & d.crash
    straggle = available & ~d.crash & d.straggle
    fresh = available & ~d.crash & ~d.straggle
    arrive = state.pending_due == state.round
    staleness = jnp.where(arrive, state.round - state.pending_birth, 0)
    return RoundSchedule(available=available, fresh=fresh, crashed=crashed,
                         straggle=straggle, arrive=arrive, delay=d.delay,
                         staleness=staleness.astype(jnp.int32))


def staleness_discount(staleness: jnp.ndarray, power: float) -> jnp.ndarray:
    """Polynomial discount s(τ) = (1 + τ)^(-power) (FedBuff's 1/sqrt at
    power=0.5); τ=0 (fresh) is exactly 1."""
    return (1.0 + staleness.astype(jnp.float32)) ** (-power)


def advance_fault_state(state: FaultState, sched: RoundSchedule,
                        sent: jnp.ndarray, send_weight: jnp.ndarray,
                        rejoin_rounds: int = 0) -> FaultState:
    """Next round's fault state: stragglers' released payloads enter the
    buffer (``sent`` is the full-(C, P) released matrix; only rows where
    ``sched.straggle`` are stored), arrivals clear their slot, crashed
    clients start their rejoin countdown (static ``rejoin_rounds`` extra
    rounds offline after the crashed one)."""
    r = state.round
    strag = sched.straggle
    arr = sched.arrive
    pending = jnp.where(strag[:, None], sent,
                        jnp.where(arr[:, None], 0.0, state.pending))
    due = jnp.where(strag, r + sched.delay,
                    jnp.where(arr, NO_PENDING, state.pending_due))
    weight = jnp.where(strag, send_weight,
                       jnp.where(arr, 0.0, state.pending_weight))
    birth = jnp.where(strag, r, state.pending_birth)
    offline_until = jnp.where(sched.crashed, r + 1 + int(rejoin_rounds),
                              state.offline_until)
    return state._replace(round=r + 1, offline_until=offline_until,
                          pending=pending, pending_due=due,
                          pending_weight=weight, pending_birth=birth)


def tree_where(pred: jnp.ndarray, a: PyTree, b: PyTree) -> PyTree:
    """Leafwise where(pred, a, b) — the zero-survivor no-op gate for
    params and ``AggState`` (pred is a traced scalar bool)."""
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def masked_mean_weights(weights: jnp.ndarray, mask: jnp.ndarray
                        ) -> jnp.ndarray:
    """Linear-family degraded mode: zero non-survivors, renormalize the
    surviving mass. All-zero input stays all-zero (the no-op gate makes
    the round inert regardless)."""
    w = jnp.where(mask, weights.astype(jnp.float32), 0.0)
    return w / jnp.maximum(jnp.sum(w), _MASS_FLOOR)


def masked_robust_reduce_flat(vecs: jnp.ndarray, weights: jnp.ndarray,
                              mask: jnp.ndarray, *, name: str,
                              trim_frac: float = 0.0) -> jnp.ndarray:
    """Rank-trim reduce over the SURVIVING clients of a (C, P) matrix.

    Non-survivors are pushed past the top of every coordinate's ranking
    (+inf sort key) and excluded from the keep window, so the trim depth
    k shrinks with the traced survivor count n: k = min(⌊frac·n⌋,
    ⌊(n−1)/2⌋) for ``trimmed_mean`` — the static-C clamp of
    ``aggregation._trim_k`` applied to the realized n — and
    k = ⌊(n−1)/2⌋ for ``median``. n ≤ 2·k never happens by
    construction; n = 0 returns zeros (callers gate the apply)."""
    x = vecs.astype(jnp.float32)
    c = x.shape[0]
    m = mask.astype(bool)
    n = jnp.sum(m.astype(jnp.int32))
    if name == "median":
        k = jnp.maximum(n - 1, 0) // 2
    elif name == "trimmed_mean":
        k = jnp.minimum(jnp.floor(trim_frac * n.astype(jnp.float32))
                        .astype(jnp.int32), jnp.maximum(n - 1, 0) // 2)
    else:
        raise ValueError(f"no masked robust reduce for strategy {name!r}")
    sort_key = jnp.where(m[:, None], x, jnp.inf)
    order = jnp.argsort(sort_key, axis=0)  # stable; masked rows sink last
    xs = jnp.take_along_axis(x, order, axis=0)
    ws = jnp.where(m, weights.astype(jnp.float32), 0.0)[order]
    ranks = jnp.arange(c, dtype=jnp.int32)[:, None]
    keep = (ranks >= k) & (ranks < n - k)
    num = jnp.sum(jnp.where(keep, ws * xs, 0.0), axis=0)
    den = jnp.sum(jnp.where(keep, ws, 0.0), axis=0)
    return jnp.where(den > 0.0, num / jnp.maximum(den, _MASS_FLOOR), 0.0)
