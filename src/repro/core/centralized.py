"""Centralized GPO baseline (paper §4.3, "Centralized Learning").

The original GPO training loop: ONE model, trained for E epochs; within
each epoch the model is updated *sequentially* for each training group
(one in-context batch per group), unlike FL where updates are aggregated
per communication round. This is the comparison baseline for Figs. 2/4/5.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig, GPOConfig
from repro.core import fairness
from repro.core.federated import History, _make_eval_group
from repro.core.gpo import gpo_loss, init_gpo_params
from repro.data.surveys import SurveyData, sample_icl_batch
from repro.optim import adam


class CentralizedGPO:
    def __init__(self, gpo_cfg: GPOConfig, fed_cfg: FedConfig,
                 data: SurveyData, train_groups: np.ndarray,
                 eval_groups: np.ndarray):
        gpo_cfg = fed_cfg.resolve_gpo(gpo_cfg)  # runtime attention override
        self.gpo_cfg, self.fed_cfg, self.data = gpo_cfg, fed_cfg, data
        self.train_groups = jnp.asarray(train_groups, jnp.int32)
        self.eval_groups = jnp.asarray(eval_groups, jnp.int32)
        self.opt = adam(fed_cfg.lr)

        key = jax.random.PRNGKey(fed_cfg.seed)
        self.params = init_gpo_params(gpo_cfg, key)
        self.opt_state = self.opt.init(self.params)
        eval_group = _make_eval_group(gpo_cfg, fed_cfg, data)

        @jax.jit
        def epoch_fn(params, opt_state, key):
            """One epoch: sequential gradient steps, one per group."""

            def group_step(carry, inp):
                params, opt_state = carry
                k, gid = inp
                batch = sample_icl_batch(k, data, gid, fed_cfg.num_context,
                                         fed_cfg.num_target)
                loss, grads = jax.value_and_grad(gpo_loss)(
                    params, gpo_cfg, batch.ctx_x, batch.ctx_y, batch.tgt_x,
                    batch.tgt_y)
                params, opt_state = self.opt.update(grads, opt_state, params)
                return (params, opt_state), loss

            n = len(train_groups)
            k_perm, k_steps = jax.random.split(key)
            order = jax.random.permutation(k_perm, self.train_groups)
            keys = jax.random.split(k_steps, n)
            (params, opt_state), losses = jax.lax.scan(
                group_step, (params, opt_state), (keys, order))
            return params, opt_state, jnp.mean(losses)

        @jax.jit
        def eval_fn(params, key):
            keys = jax.random.split(key, len(eval_groups))
            return jax.vmap(eval_group, in_axes=(None, 0, 0))(
                params, keys, self.eval_groups)

        self._epoch = epoch_fn
        self._eval = eval_fn

    def run(self, epochs: int | None = None, log_every: int = 0) -> History:
        fed = self.fed_cfg
        epochs = epochs or fed.rounds
        hist = History()
        key = jax.random.PRNGKey(fed.seed + 2)
        for e in range(epochs):
            key, k_epoch, k_eval = jax.random.split(key, 3)
            self.params, self.opt_state, loss = self._epoch(
                self.params, self.opt_state, k_epoch)
            hist.round_loss.append(float(loss))
            if e % fed.eval_every == 0 or e == epochs - 1:
                scores = np.asarray(self._eval(self.params, k_eval))
                hist.eval_rounds.append(e)
                hist.eval_scores.append(scores)
                hist.eval_mean_as.append(float(scores.mean()))
                hist.eval_fi.append(float(fairness.fairness_index(scores)))
                hist.eval_cov.append(
                    float(fairness.coefficient_of_variation(scores)))
                if log_every and e % log_every == 0:
                    print(f"[cen] epoch {e:5d} loss={hist.round_loss[-1]:.4f} "
                          f"AS={hist.eval_mean_as[-1]:.4f} "
                          f"FI={hist.eval_fi[-1]:.4f}")
        return hist
