"""Communication-efficient client-delta transport (DESIGN.md §10).

The compression stage sits on the client→server path BETWEEN the privacy
pipeline and the ``ServerAggregator``: each client's flat delta d_c is
released by the DP pipeline (clip + noise, ``core/privacy.py``), the
EF residual is folded in, the result is compressed and immediately
decompressed (the server consumes the "transmitted" values t_c), and the
aggregator reduces the t_c:

    d̃_c = privacy_release(d_c)          (unchanged — ε is unaffected,
                                          compression is post-processing)
    u_c  = d̃_c + e_c                     (EF21-style residual carry-in)
    t_c  = D(Q(u_c))                     (codec round trip)
    e'_c = u_c − t_c                     (residual carry-out)
    Δ    = aggregate_c(w_c, t_c)

Codecs (``CompressionConfig.kind``):

* ``int8`` — per-client symmetric quantization to 127 levels, scale
  s_c = max|u_c| / 127. Stochastic rounding q = ⌊u/s + υ⌋ with
  υ ~ U[0,1) is unbiased (E[t] = u); υ is PRESAMPLED outside any kernel
  from keys folded out of the per-client TRAINING keys (tag
  ``_QUANT_TAG``), exactly the noise-key scheme of §9 — so both
  ``FederatedGPO`` drivers and ``make_sharded_round`` draw bit-identical
  rounding randomness from the same round keys, and the fused Pallas
  kernel reproduces the jnp path / ``ref.py`` oracle exactly.
* ``topk`` — magnitude sparsification: entries below the per-client
  ⌈topk_frac·P⌉-th largest |u_c| are zeroed (threshold ties kept). The
  threshold is a global selection (``lax.top_k``) and cannot stream; the
  Pallas ``topk_reduce`` kernel fuses the mask/scatter + weighted reduce
  (+ residual write) that follows it.

On the wire: the sharded engine's robust-aggregator family all-gathers
the int8 payload + f32 per-client scales instead of f32 vectors — P + 4
bytes per client instead of 4P, ~4× fewer bytes on the round's dominant
collective. The linear family dequantizes shard-locally and keeps its
single f32 psum (the psum models the server's reduction, not the
client upload; the byte accounting lives in DESIGN.md §10 and
``bench_round.py --compress`` → BENCH_comm.json).
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import CompressionConfig, PrivacyConfig
from repro.core import privacy as dp
from repro.kernels import agg_quant_clip_reduce, agg_topk_reduce
# shared contract constants (see the _NORM_FLOOR note in core/privacy.py:
# imported so the jnp path and the kernels cannot drift; the ref.py
# oracles restate the literals by design)
from repro.kernels.agg_reduce import INT8_LEVELS, _SCALE_FLOOR

PyTree = Any

# fold_in tag deriving a client's stochastic-rounding key from its local
# training key; distinct from privacy's _NOISE_TAG so the rounding
# uniforms are independent of the DP noise.
_QUANT_TAG = 0x0C0DEC


def client_quant_keys(keys: jnp.ndarray) -> jnp.ndarray:
    """Per-client rounding keys derived from the per-client training
    keys (the §9 noise-key scheme with a different tag)."""
    return jax.vmap(lambda k: jax.random.fold_in(k, _QUANT_TAG))(keys)


def client_uniform(keys: jnp.ndarray, shape: tuple) -> jnp.ndarray:
    """Presampled U[0,1) stochastic-rounding tile (C, P); ``keys`` are
    the per-client TRAINING keys (rounding keys are folded from them)."""
    qkeys = client_quant_keys(keys)
    return jax.vmap(
        lambda k: jax.random.uniform(k, shape[1:], jnp.float32))(qkeys)


# ---------------------------------------------------------------------------
# codec primitives on the flat (C, P) matrix
# ---------------------------------------------------------------------------
def quantize_int8(vecs: jnp.ndarray, *,
                  uniform: Optional[jnp.ndarray] = None
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(C, P) f32 -> (q int8 (C, P), scales f32 (C,)). Symmetric
    127-level grid; stochastic rounding when a presampled ``uniform``
    tile is given, round-to-nearest otherwise. The scale floor keeps
    all-zero clients at exact zeros."""
    x = vecs.astype(jnp.float32)
    scales = jnp.maximum(jnp.max(jnp.abs(x), axis=1) / INT8_LEVELS,
                         _SCALE_FLOOR)
    z = x / scales[:, None]
    q = (jnp.floor(z + uniform.astype(jnp.float32)) if uniform is not None
         else jnp.round(z))
    q = jnp.clip(q, -INT8_LEVELS, INT8_LEVELS)
    return q.astype(jnp.int8), scales


def dequantize_int8(q: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """(C, P) int8 + (C,) scales -> (C, P) f32 transmitted values."""
    return q.astype(jnp.float32) * scales[:, None]


def topk_count(p: int, frac: float) -> int:
    """Entries kept per client: ⌈frac·P⌉, at least 1."""
    return max(1, int(math.ceil(frac * p)))


def topk_thresholds(vecs: jnp.ndarray, frac: float) -> jnp.ndarray:
    """(C,) per-client magnitude threshold: the k-th largest |value|."""
    k = topk_count(vecs.shape[1], frac)
    mags = jnp.abs(vecs.astype(jnp.float32))
    return jax.lax.top_k(mags, k)[0][:, -1]


def sparsify_topk(vecs: jnp.ndarray, frac: float
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(C, P) -> (sparsified (C, P) f32, thresholds (C,)): zero every
    entry whose magnitude sits below the top-k threshold (ties kept)."""
    x = vecs.astype(jnp.float32)
    tau = topk_thresholds(x, frac)
    return jnp.where(jnp.abs(x) >= tau[:, None], x, 0.0), tau


def compress_flat(vecs: jnp.ndarray, keys: Optional[jnp.ndarray],
                  comp: CompressionConfig) -> jnp.ndarray:
    """Codec round trip D(Q(·)) on the (C, P) matrix — the transmitted
    values the server consumes (jnp reference path; oracles in
    kernels/ref.py restate the same math)."""
    if comp.kind == "int8":
        uniform = (client_uniform(keys, vecs.shape) if comp.stochastic
                   else None)
        return dequantize_int8(*quantize_int8(vecs, uniform=uniform))
    if comp.kind == "topk":
        return sparsify_topk(vecs, comp.topk_frac)[0]
    return vecs.astype(jnp.float32)


def ef_compress_flat(vecs: jnp.ndarray, keys: Optional[jnp.ndarray],
                     comp: CompressionConfig,
                     resid: Optional[jnp.ndarray]
                     ) -> tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """EF21-style wrapper: compress(d̃ + e), e' = (d̃ + e) − t.
    ``resid=None`` (error feedback off) is a plain codec round trip."""
    u = vecs.astype(jnp.float32)
    if resid is not None:
        u = u + resid
    t = compress_flat(u, keys, comp)
    return t, (u - t if resid is not None else None)


def release_flat(vecs: jnp.ndarray, keys: Optional[jnp.ndarray],
                 privacy, comp: CompressionConfig,
                 resid: Optional[jnp.ndarray]
                 ) -> tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Per-client released values WITHOUT the client-axis reduction:
    DP release (if enabled) then the EF/codec round trip, returning the
    (C, P) transmitted matrix and the carry-out residual. The fault-
    aware round (DESIGN.md §11) needs each client's wire value
    individually — straggler payloads are buffered whole and lost
    clients are masked after the fact — so the fused reduce-style
    kernels don't apply here; the rows are bit-identical to the jnp
    path of ``transport_delta_flat``."""
    x = vecs.astype(jnp.float32)
    if privacy.enabled:
        x = dp.privatize_flat(x, keys, privacy)
    if not comp.enabled:
        return x, resid
    return ef_compress_flat(x, keys, comp, resid)


# ---------------------------------------------------------------------------
# the full transport for client-stacked engines
# ---------------------------------------------------------------------------
def transport_delta_flat(vecs: jnp.ndarray, weights: jnp.ndarray,
                         keys: Optional[jnp.ndarray],
                         privacy: PrivacyConfig, comp: CompressionConfig,
                         agg, resid: Optional[jnp.ndarray], *,
                         use_pallas: bool = False
                         ) -> tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """DP release → EF/compress → client-axis reduction on the raw flat
    (C, P) delta matrix. Returns (delta_vec (P,), new residual | None).

    Engines that hold every client locally (the stacked GPO drivers and
    the backbone/LoRA trainers) call this whole chain; the sharded
    engine calls it per shard for the linear family (its psum rides
    after) and inlines the codec around its all-gather for the robust
    family (the int8 payload is what crosses the wire there).

    ``use_pallas`` routes the linear family through ONE fused kernel:
    ``agg_quant_clip_reduce`` for int8 (clip/noise/EF/quantize/reduce in
    a single launch, no (C, P) intermediate in HBM) or the top-k
    threshold/scatter kernel after the jnp threshold selection. The
    robust family privatizes + compresses in jnp and reduces through
    ``agg.reduce_flat`` (which is the rank-trim kernel under the same
    flag).
    """
    x = vecs.astype(jnp.float32)
    w = weights.astype(jnp.float32)
    if comp.kind == "int8":
        uniform = (client_uniform(keys, x.shape) if comp.stochastic
                   else None)
        if use_pallas and agg.linear:
            noise = (dp.client_noise(keys, x.shape, privacy.sigma)
                     if privacy.enabled and privacy.noise_multiplier > 0.0
                     else None)
            clip = privacy.clip_norm if privacy.enabled else 0.0
            return agg_quant_clip_reduce(x, w, clip=clip, noise=noise,
                                         uniform=uniform, resid=resid)
        if privacy.enabled:
            x = dp.privatize_flat(x, keys, privacy)
        u = x + resid if resid is not None else x
        t = dequantize_int8(*quantize_int8(u, uniform=uniform))
    elif comp.kind == "topk":
        if privacy.enabled:
            x = dp.privatize_flat(x, keys, privacy)
        u = x + resid if resid is not None else x
        if use_pallas and agg.linear:
            tau = topk_thresholds(u, comp.topk_frac)
            return agg_topk_reduce(u, w, tau,
                                   with_residual=resid is not None)
        t = jnp.where(
            jnp.abs(u) >= topk_thresholds(u, comp.topk_frac)[:, None],
            u, 0.0)
    else:
        raise ValueError(f"transport called with kind={comp.kind!r} "
                         "(callers must gate on CompressionConfig.enabled)")
    new_resid = u - t if resid is not None else None
    # registry reduce: the linear family's weighted flat mean or the
    # robust family's rank trim (kernel-backed under use_pallas — the
    # fused-transport kernels intercepted the linear+pallas paths above)
    return agg.reduce_flat(t, w), new_resid
