"""Alignment & fairness metrics (paper §4.4).

* Alignment Score AS(P1, P2; Q) — Eq. 4. The paper writes the mean JSD;
  its figures treat AS as higher-is-better (GPO's convention is
  1 - JSD), so we implement AS = mean_q (1 - JSD(P1(q), P2(q))) and note
  the sign convention here. JSD is the Jensen-Shannon *distance*
  (sqrt of base-2 divergence, bounded [0, 1]).
* CoV (Eq. 5) and Fairness Index FI = 1/(1+CoV^2) (Eq. 6).
* Convergence round: first round reaching 95% of the total loss descent
  (paper §4.4 "95% of its final loss value").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_EPS = 1e-12


def kl_divergence(p: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """KL(p || q) in bits, last axis, safe for zeros."""
    p = jnp.clip(p, _EPS, 1.0)
    q = jnp.clip(q, _EPS, 1.0)
    return jnp.sum(p * (jnp.log2(p) - jnp.log2(q)), axis=-1)


def js_distance(p: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Jensen-Shannon distance in [0, 1] (sqrt of base-2 JS divergence)."""
    m = 0.5 * (p + q)
    div = 0.5 * kl_divergence(p, m) + 0.5 * kl_divergence(q, m)
    return jnp.sqrt(jnp.clip(div, 0.0, 1.0))


def alignment_score(p1: jnp.ndarray, p2: jnp.ndarray) -> jnp.ndarray:
    """Eq. 4 over a set of questions: p1, p2 (Q, A) -> scalar in [0, 1]."""
    return jnp.mean(1.0 - js_distance(p1, p2))


def coefficient_of_variation(scores: jnp.ndarray) -> jnp.ndarray:
    """Eq. 5 over per-group alignment scores (K,)."""
    mu = jnp.mean(scores)
    sigma = jnp.sqrt(jnp.mean(jnp.square(scores - mu)))
    return sigma / jnp.maximum(mu, _EPS)


def fairness_index(scores: jnp.ndarray) -> jnp.ndarray:
    """Eq. 6: FI = 1 / (1 + CoV^2); 1 == perfect equal opportunity."""
    cov = coefficient_of_variation(scores)
    return 1.0 / (1.0 + jnp.square(cov))


def convergence_round(losses: np.ndarray, frac: float = 0.95) -> int:
    """First index where 95% of the total descent (loss_0 -> loss_final)
    has been achieved. Returns len(losses)-1 if never."""
    losses = np.asarray(losses, np.float64)
    if losses.size == 0:
        return 0
    start, final = losses[0], losses[-1]
    if final > start:
        # diverging curve: there IS no 95%-descent round — the threshold
        # would sit above the starting loss, which round 0 satisfies
        # vacuously. Report "never converged" (the last round), matching
        # the no-crossing branch below. Constant curves (final == start)
        # keep returning 0: zero descent is trivially achieved.
        return len(losses) - 1
    threshold = start - frac * (start - final)
    idx = np.nonzero(losses <= threshold)[0]
    return int(idx[0]) if idx.size else len(losses) - 1
