"""FedAvg aggregation (paper Eq. 2-3) in three equivalent forms.

1. ``fedavg_stacked`` — single-process simulation: client trees stacked on
   a leading C axis, weighted sum along it. The paper-faithful CPU path.
2. ``fedavg_allreduce`` — the TPU-native form used inside ``shard_map``:
   each client shard scales its params by p_g and one weighted
   ``lax.psum`` over the client mesh axis *is* the aggregation server
   (DESIGN.md §3). Hierarchical (multi-pod) FedAvg is the same psum over
   ('pod', 'data').
3. ``fedavg_flat`` — flattened-vector form matching the ``fedavg_reduce``
   Pallas kernel contract (used by kernel tests and benchmarks).

These are the Eq. 2-3 *primitives*; the pluggable server-aggregation
subsystem that generalizes them (delta contract, FedAvgM/FedAdam/
FedYogi, robust trims, adaptive weights) lives in ``core/aggregation.py``
(DESIGN.md §7).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.utils.pytree import (
    tree_index,
    tree_ravel_clients,
    tree_unflatten_from_vector,
)

PyTree = Any


def normalize_weights(sizes: jnp.ndarray) -> jnp.ndarray:
    """p_g = |D_g| / sum_g' |D_g'|  (Eq. 2).

    The denominator is clamped so an all-zero size vector (the
    empty-survivor round the §11 availability simulator can produce)
    yields all-zero weights instead of NaNs; any real population
    (sum >= 1 sample) is bit-unaffected by the clamp.
    """
    sizes = jnp.asarray(sizes, jnp.float32)
    return sizes / jnp.maximum(jnp.sum(sizes), jnp.float32(1e-12))


def fedavg_stacked(stacked_params: PyTree, weights: jnp.ndarray) -> PyTree:
    """Eq. 3 for client-stacked trees: leaves (C, ...) -> (...)."""
    w = jnp.asarray(weights, jnp.float32)

    def agg(leaf):
        wf = w.reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.sum(leaf.astype(jnp.float32) * wf, axis=0).astype(leaf.dtype)

    return jax.tree.map(agg, stacked_params)


def broadcast_to_clients(params: PyTree, num_clients: int) -> PyTree:
    """Redistribute the global model to every client (server -> clients)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (num_clients,) + x.shape), params)


def fedavg_allreduce(local_params: PyTree, weight: jnp.ndarray,
                     axis_names: Sequence[str] | str) -> PyTree:
    """Inside shard_map: weighted psum over the client axis/axes.

    ``weight`` is this client's p_g (already normalized across the axis).
    The psum plays the aggregation server; the result is already
    'redistributed' because every shard holds it.
    """
    return jax.tree.map(
        lambda x: jax.lax.psum(x.astype(jnp.float32) * weight, axis_names)
        .astype(x.dtype),
        local_params)


def fedavg_flat(stacked_params: PyTree, weights: jnp.ndarray) -> PyTree:
    """Flattened-vector FedAvg (the Pallas `fedavg_reduce` contract),
    routed through the aggregation registry: the ``fedavg`` strategy's
    ``reduce_flat`` is the single implementation of the weighted flat
    mean (this helper predates the PR 2 registry and used to duplicate
    it). The imports stay lazy to keep the module graph acyclic —
    ``core.aggregation`` imports this module at top level — but the
    aggregator is built PER CALL: a module-level cache here once leaked
    stale strategy state across configs and test runs (built once with
    num_clients=0, never invalidated). The fedavg builder is closure
    assembly only — no tracing — so per-call construction is free."""
    from repro.configs.base import AggConfig
    from repro.core.aggregation import make_aggregator

    like = tree_index(stacked_params, 0)
    vecs = tree_ravel_clients(stacked_params)  # (C, P)
    agg = make_aggregator(AggConfig(), num_clients=int(vecs.shape[0]))
    avg = agg.reduce_flat(vecs, jnp.asarray(weights, jnp.float32))
    return tree_unflatten_from_vector(avg, like)
