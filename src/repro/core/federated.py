"""PluralLLM federated runtime (paper §3, §4.3).

Round structure (faithful to the paper):
  1. server broadcasts global GPO params to all training clients (groups);
  2. every client runs ``local_epochs`` Adam steps; each step samples
     context questions + target questions from the client's private
     preference data (in-context objective, Eq. 1; with
     ``AggConfig.prox_mu > 0`` a FedProx proximal term anchors the local
     model to the round's broadcast global);
  3. clients transmit parameter *deltas*; with ``FedConfig.privacy``
     enabled each flat delta is L2-clipped and Gaussian-noised BEFORE it
     leaves the client (DESIGN.md §9, ``core/privacy.py`` — the Rényi
     accountant folds the per-round ε into ``History.round_eps``); with
     ``FedConfig.compression`` enabled the released delta is then int8-
     quantized or top-k-sparsified with an EF21 error-feedback residual
     (DESIGN.md §10, ``core/compression.py``); the
     server reduces the (privatized) deltas and applies the configured
     ``ServerAggregator`` update (DESIGN.md §7 — the paper's Eq. 2-3
     FedAvg is the default strategy) and redistributes.

Two execution engines expose the same round semantics:

* ``FederatedGPO`` — clients vmapped on one device. This is the
  paper-faithful simulation used for the CPU experiments (benchmarks
  reproduce Figs. 2-5 with it).
* ``make_sharded_round`` — clients laid out on the mesh `data` axis via
  ``shard_map``; local epochs run without any cross-client collective and
  the round ends in ONE weighted psum (+ the hierarchical `pod` axis on
  multi-pod meshes). This is the TPU-production engine the dry-run lowers.

``FederatedGPO`` itself has two round *drivers* (DESIGN.md §3):

* ``engine="scan"`` (default) — the fused multi-round driver: the whole
  requested block of rounds is ONE jitted ``lax.scan`` (or blocks of
  ``log_every`` rounds when live logging is requested). Per-round losses
  and the eval-cadence alignment scores accumulate on device and transfer
  to host once per block; the per-client optimizer buffers are donated
  into the call. Zero per-round Python dispatch or device→host sync.
* ``engine="loop"`` — one jitted call per round with a host sync on the
  loss (the original dispatch pattern), kept for A/B benchmarking
  (``benchmarks/bench_round.py``) and equivalence tests.

Both drivers derive per-round RNG keys identically, so they produce the
same ``History`` up to float reassociation.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig, GPOConfig
from repro.core import adversary as byz
from repro.core import availability as av
from repro.core import compression as cx, fairness, privacy as dp
from repro.core.aggregation import ServerAggregator, make_aggregator
from repro.core.pipeline import make_pipeline
from repro.core.fedavg import (
    broadcast_to_clients,
    fedavg_allreduce,
    normalize_weights,
)
from repro.core.gpo import gpo_loss, init_gpo_params, predict_preferences
from repro.data.surveys import SurveyData, sample_icl_batch
from repro.kernels import fedavg_reduce
from repro.optim import adam
from repro.utils.pytree import (
    tree_count_params,
    tree_index,
    tree_ravel_clients,
    tree_sq_norm,
    tree_sub,
    tree_unflatten_from_vector,
)

PyTree = Any


# ---------------------------------------------------------------------------
# Local training (one client, `local_epochs` steps) — shared by both engines
# ---------------------------------------------------------------------------
def _make_local_train(gpo_cfg: GPOConfig, fed_cfg: FedConfig,
                      data: SurveyData, opt):
    """Local client objective. With ``AggConfig.prox_mu > 0`` the FedProx
    proximal term (mu/2)*||theta - theta_global||^2 anchors each local
    step to the round's broadcast global (= the entry params); the
    reported loss stays the task loss so strategies compare on Eq. 1.
    The mu == 0 path traces byte-identical to the seed objective.

    With a data-level adversary configured (``kind="label_flip"``,
    DESIGN.md §13) the returned function gains a trailing per-client
    ``attacked`` flag and poisons the attacked clients' sampled
    preference rows — context AND target, the Byzantine client poisons
    everything it feeds the optimizer — via ``byz.flip_preferences``.
    The attack-off signature and trace are unchanged (static branch)."""
    mu = fed_cfg.agg.prox_mu
    flip = fed_cfg.adversary.enabled and fed_cfg.adversary.data_level

    def local_body(params, opt_state, key, group_id, attacked):
        anchor = params  # the round's broadcast global model

        def epoch_step(carry, k):
            params, opt_state = carry
            batch = sample_icl_batch(k, data, group_id,
                                     fed_cfg.num_context, fed_cfg.num_target)
            if flip:
                def poison(y):
                    y = y.astype(jnp.float32)
                    return jnp.where(
                        attacked,
                        byz.flip_preferences(y, data.num_options), y)

                batch = batch._replace(ctx_y=poison(batch.ctx_y),
                                       tgt_y=poison(batch.tgt_y))
            if mu > 0.0:
                def objective(p):
                    task = gpo_loss(p, gpo_cfg, batch.ctx_x, batch.ctx_y,
                                    batch.tgt_x, batch.tgt_y)
                    prox = 0.5 * mu * tree_sq_norm(tree_sub(p, anchor))
                    return task + prox, task

                (_, loss), grads = jax.value_and_grad(
                    objective, has_aux=True)(params)
            else:
                loss, grads = jax.value_and_grad(gpo_loss)(
                    params, gpo_cfg, batch.ctx_x, batch.ctx_y, batch.tgt_x,
                    batch.tgt_y)
            params, opt_state = opt.update(grads, opt_state, params)
            return (params, opt_state), loss

        keys = jax.random.split(key, fed_cfg.local_epochs)
        (params, opt_state), losses = jax.lax.scan(
            epoch_step, (params, opt_state), keys)
        return params, opt_state, jnp.mean(losses)

    if flip:
        def local_train(params, opt_state, key, group_id, attacked):
            return local_body(params, opt_state, key, group_id, attacked)
    else:
        def local_train(params, opt_state, key, group_id):
            return local_body(params, opt_state, key, group_id, None)

    return local_train


def _make_eval_group(gpo_cfg: GPOConfig, fed_cfg: FedConfig, data: SurveyData):
    """AS of the global model on one (unseen) group — Eq. 4."""

    def eval_group(params, key, group_id):
        batch = sample_icl_batch(key, data, group_id,
                                 fed_cfg.num_context, fed_cfg.num_target)
        pred = predict_preferences(params, gpo_cfg, batch.ctx_x, batch.ctx_y,
                                   batch.tgt_x, data.num_options)
        truth = batch.tgt_y.reshape(-1, data.num_options)
        return fairness.alignment_score(pred, truth)

    return eval_group


# ---------------------------------------------------------------------------
# Engine 1: vmapped clients (paper-faithful CPU simulation)
# ---------------------------------------------------------------------------
@dataclass
class History:
    round_loss: list = field(default_factory=list)  # mean client loss / round
    eval_rounds: list = field(default_factory=list)
    eval_scores: list = field(default_factory=list)  # (K,) per eval round
    eval_mean_as: list = field(default_factory=list)
    eval_fi: list = field(default_factory=list)
    eval_cov: list = field(default_factory=list)
    # DP accounting (DESIGN.md §9): cumulative ε at PrivacyConfig.
    # target_delta AFTER each round, counted across every `run` call on
    # the trainer. Empty when the privacy pipeline is disabled; inf per
    # round for clip-only runs (clipping alone carries no DP guarantee).
    round_eps: list = field(default_factory=list)
    # fault injection (DESIGN.md §11): per-round count of updates the
    # server actually absorbed (fresh releases + buffered arrivals).
    # Empty when AvailabilityConfig is disabled.
    round_survivors: list = field(default_factory=list)


class FederatedGPO:
    def __init__(self, gpo_cfg: GPOConfig, fed_cfg: FedConfig,
                 data: SurveyData, train_groups: np.ndarray,
                 eval_groups: np.ndarray):
        gpo_cfg = fed_cfg.resolve_gpo(gpo_cfg)  # runtime attention override
        assert gpo_cfg.d_embed == data.phi.shape[-1]
        fed_cfg.privacy.validate()
        fed_cfg.compression.validate()
        fed_cfg.avail.validate()
        fed_cfg.adversary.validate()
        # §14 edge topology: validated against the per-round participant
        # count (edges partition the PARTICIPANTS, contiguous + equal
        # size). The fault-aware round bypasses the hierarchy — buffered
        # arrivals break the static edge assignment — so the two stay
        # mutually exclusive rather than silently degrading.
        m_part = min(fed_cfg.batch_groups or len(train_groups),
                     len(train_groups))
        fed_cfg.hierarchy.validate(m_part)
        if fed_cfg.hierarchy.enabled and fed_cfg.avail.enabled:
            raise ValueError(
                "hierarchy.num_edges > 1 does not compose with the §11 "
                "fault simulator: the buffered/masked reduce aggregates "
                "flat (edge assignment is static per round)")
        dp.check_adaptive_privacy(fed_cfg)
        byz.check_defense_composition(fed_cfg)
        self.gpo_cfg, self.fed_cfg, self.data = gpo_cfg, fed_cfg, data
        self.train_groups = jnp.asarray(train_groups, jnp.int32)
        self.eval_groups = jnp.asarray(eval_groups, jnp.int32)
        self.weights = normalize_weights(data.sizes[self.train_groups])
        self.opt = adam(fed_cfg.lr)
        self.agg = make_aggregator(
            fed_cfg.agg, num_clients=len(train_groups),
            use_pallas=fed_cfg.use_pallas_aggregation)

        key = jax.random.PRNGKey(fed_cfg.seed)
        self.global_params = init_gpo_params(gpo_cfg, key)
        self.server_state = self.agg.init(self.global_params)
        # EF21-style compression residual (DESIGN.md §10): one flat f32
        # row per client, carried across rounds next to the server state
        # (None keeps the pre-compression trace byte-identical).
        comp = fed_cfg.compression
        if comp.enabled and comp.error_feedback:
            self.ef_resid = jnp.zeros(
                (len(train_groups), tree_count_params(self.global_params)),
                jnp.float32)
        else:
            self.ef_resid = None
        # fault injection (DESIGN.md §11): availability/failure state —
        # crash-rejoin traces plus the straggler in-flight buffer — rides
        # next to the server state; None keeps the fault-free trace
        # byte-identical (the disabled default compiles the exact
        # pre-feature round functions below).
        self._faults = fed_cfg.avail.enabled
        if self._faults:
            self.fault_state = av.init_fault_state(
                len(train_groups), tree_count_params(self.global_params))
        else:
            self.fault_state = None
        per_client = broadcast_to_clients(self.global_params,
                                          len(train_groups))
        self.opt_states = jax.vmap(self.opt.init)(per_client)

        local_train = _make_local_train(gpo_cfg, fed_cfg, data, self.opt)
        eval_group = _make_eval_group(gpo_cfg, fed_cfg, data)
        num_clients = len(train_groups)
        # partial participation (beyond-paper ablation): sample
        # batch_groups clients per round; weights renormalize over the
        # participants (paper §4.3 assumes full participation).
        m = fed_cfg.batch_groups or num_clients
        m = min(m, num_clients)

        # DP accounting (DESIGN.md §9, §11): one sampled Gaussian
        # mechanism per round at the REALIZED participation rate
        # q = (m/C) · release_rate — a client releases a delta only when
        # it is sampled AND online AND does not crash, so the effective
        # per-round inclusion probability shrinks under faults (the
        # availability draws are independent of the data, making this the
        # standard amplification-by-subsampling composition; stragglers
        # still release — late — and are counted). release_rate is 1.0
        # with faults disabled, keeping the pre-§11 epsilon exactly.
        self._accountant = dp.make_accountant(
            fed_cfg.privacy,
            (m / num_clients) * fed_cfg.avail.release_rate())
        self._rounds_elapsed = 0

        agg = self.agg
        priv = fed_cfg.privacy
        ef = comp.enabled and comp.error_feedback
        # round-stage pipeline (DESIGN.md §13): the [local_train, attack,
        # privacy, codec, aggregate] sequence assembles ONCE here; both
        # stacked round bodies below delegate the stage dispatch to it
        # (the attack-off pipeline traces the exact pre-§13 computation).
        pipe = make_pipeline(fed_cfg, agg=agg, num_clients=num_clients)
        adv_on = fed_cfg.adversary.enabled

        def round_step(global_params, opt_states, server_state, resid, key):
            k_sub, k_train = jax.random.split(key)
            if m < num_clients:
                idx = jax.random.choice(k_sub, num_clients, (m,),
                                        replace=False)
            else:
                idx = jnp.arange(num_clients)
            groups = self.train_groups[idx]
            sizes = data.sizes[groups].astype(jnp.float32)
            w = sizes / jnp.sum(sizes)
            client_params = broadcast_to_clients(global_params, m)
            if fed_cfg.reset_opt_each_round:
                opt_sub = jax.vmap(self.opt.init)(client_params)
            else:
                opt_sub = jax.tree.map(lambda x: x[idx], opt_states)
            keys = jax.random.split(k_train, m)
            # the Byzantine key folds out of the ROUND key (like the §11
            # fault key, its own tag) — None when the adversary is off,
            # so the benign trace never folds it
            bk = pipe.fold_key(key)
            train_args = (client_params, opt_sub, keys, groups)
            if pipe.flip_data:
                train_args += (pipe.attacked_flags(bk, idx),)
            new_client_params, opt_sub, losses = jax.vmap(local_train)(
                *train_args)
            opt_states = jax.tree.map(
                lambda full, sub: full.at[idx].set(sub), opt_states,
                opt_sub)
            # delta contract (DESIGN.md §7): clients ship theta_g - theta;
            # the server runs the pipeline's [attack →] privacy → codec →
            # aggregate tail (Eq. 3 FedAvg being the default strategy;
            # the EF residual rows of this round's participants update in
            # place, non-sampled clients keep theirs).
            deltas = tree_sub(new_client_params, client_params)
            new_global, server_state, new_r = pipe.reduce_apply(
                server_state, global_params, deltas, w, keys,
                losses=losses, idx=idx,
                resid=resid[idx] if ef else None, byz_key=bk)
            if ef:
                resid = resid.at[idx].set(new_r)
            return new_global, opt_states, server_state, resid, losses

        def eval_fn(global_params, key):
            keys = jax.random.split(key, len(eval_groups))
            return jax.vmap(eval_group, in_axes=(None, 0, 0))(
                global_params, keys, self.eval_groups)

        num_eval = len(eval_groups)

        # Fused multi-round driver: a whole block of rounds is one jitted
        # lax.scan. ``eval_mask`` (bool per round, known on the host) picks
        # the rounds that also run the Eq. 4 evaluation; skipped rounds
        # emit zeros that the host discards, so metric accumulation stays
        # on device and the block performs exactly one host transfer.
        # Only the per-client optimizer buffers and the EF compression
        # residual are donated: callers (and the seed tests)
        # legitimately hold references to the previous global model
        # across ``run`` calls. The server-aggregator state (momentum /
        # moments / adaptive scores) and the residual ride in the scan
        # carry so stateful strategies and compressed transport fuse
        # exactly like stateless FedAvg.
        @functools.partial(jax.jit, donate_argnums=(1, 2))
        def block_fn(global_params, opt_states, resid, server_state, key,
                     eval_mask):
            def body(carry, do_eval):
                g, opt_s, r, srv, k = carry
                k, k_round, k_eval = jax.random.split(k, 3)
                g, opt_s, srv, r, losses = round_step(g, opt_s, srv, r,
                                                      k_round)
                scores = jax.lax.cond(
                    do_eval,
                    lambda gp, ke: eval_fn(gp, ke).astype(jnp.float32),
                    lambda gp, ke: jnp.zeros((num_eval,), jnp.float32),
                    g, k_eval)
                return (g, opt_s, r, srv, k), (jnp.mean(losses), scores)

            ((global_params, opt_states, resid, server_state, key),
             (losses, scores)) = jax.lax.scan(
                body, (global_params, opt_states, resid, server_state, key),
                eval_mask, unroll=fed_cfg.scan_unroll)
            return (global_params, opt_states, resid, server_state, key,
                    losses, scores)

        # ------------------------------------------------------------------
        # Fault-aware round (DESIGN.md §11). A STATIC Python branch: with
        # AvailabilityConfig disabled (the default) the round/block
        # functions above compile exactly as before — the bit-equal pin
        # in tests/test_availability.py rides on this. The fault round
        # trades the fused reduce kernels for a per-client release
        # (payloads must be individually maskable/bufferable) and keeps
        # every failure decision inside the trace as masks: no Python
        # branching on schedule values.
        avail = fed_cfg.avail

        def fault_round_step(global_params, opt_states, server_state,
                             resid, fault, key):
            k_sub, k_train = jax.random.split(key)
            if m < num_clients:
                idx = jax.random.choice(k_sub, num_clients, (m,),
                                        replace=False)
            else:
                idx = jnp.arange(num_clients)
            groups = self.train_groups[idx]
            sizes = data.sizes[groups].astype(jnp.float32)
            w = sizes / jnp.sum(sizes)
            w_eff = agg.weigh(server_state, w, idx)
            # the failure schedule: a pure function of (round key, client
            # index, carried fault state) — replicated-computable, so the
            # sharded engine replays it bit-identically (fold_fault_key).
            fault_key = av.fold_fault_key(key)
            sched = av.round_schedule(fault_key, fault, avail, num_clients)
            # sampling is oblivious to availability (the coordinator
            # cannot know who will fail); realized participation is
            # sampled ∧ available. Draws of non-sampled clients are
            # discarded — only their in-flight arrivals act this round.
            sampled = jnp.zeros((num_clients,), bool).at[idx].set(True)
            sched = sched._replace(
                available=sched.available & sampled,
                fresh=sched.fresh & sampled,
                crashed=sched.crashed & sampled,
                straggle=sched.straggle & sampled)
            client_params = broadcast_to_clients(global_params, m)
            if fed_cfg.reset_opt_each_round:
                opt_sub = jax.vmap(self.opt.init)(client_params)
            else:
                opt_sub = jax.tree.map(lambda x: x[idx], opt_states)
            keys = jax.random.split(k_train, m)
            bk = pipe.fold_key(key)
            train_args = (client_params, opt_sub, keys, groups)
            if pipe.flip_data:
                train_args += (pipe.attacked_flags(bk, idx),)
            new_client_params, opt_sub, losses = jax.vmap(local_train)(
                *train_args)
            # opt states advance only where the round's local work
            # survived: offline clients never trained, crashed clients
            # lost theirs with the crash
            keep = (sched.fresh | sched.straggle)[idx]

            def merge(full, sub):
                k_ = keep.reshape((-1,) + (1,) * (sub.ndim - 1))
                return full.at[idx].set(jnp.where(k_, sub, full[idx]))

            opt_states = jax.tree.map(merge, opt_states, opt_sub)
            # per-client release (pipeline stages 2-4: attack, DP, then
            # EF/codec — NO reduction): a Byzantine row that straggles is
            # buffered CORRUPTED, the §11 ∘ §13 composition. The EF21
            # residual rows advance exactly for releasing clients
            # (fresh + stragglers — they do transmit, just late);
            # crashed/offline rows are untouched (delta never released).
            deltas = tree_sub(new_client_params, client_params)
            r_sub = resid[idx] if ef else None
            rel_sub, new_r = pipe.release_rows(
                tree_ravel_clients(deltas), keys, r_sub, byz_key=bk,
                gids=idx)
            if ef:
                resid = resid.at[idx].set(
                    jnp.where(keep[:, None], new_r, resid[idx]))
            rel_full = jnp.zeros(
                (num_clients, rel_sub.shape[1]),
                jnp.float32).at[idx].set(rel_sub)
            w_full = jnp.zeros((num_clients,), jnp.float32).at[idx].set(
                w_eff.astype(jnp.float32))
            # this round's contributions: fresh releases at full weight +
            # buffered arrivals discounted by realized staleness. A
            # client that is both (its stale upload lands while it also
            # trains fresh) contributes the weight-averaged row.
            disc = av.staleness_discount(sched.staleness,
                                         fed_cfg.agg.staleness_power)
            w_fresh = jnp.where(sched.fresh, w_full, 0.0)
            w_arr = jnp.where(sched.arrive,
                              fault.pending_weight * disc, 0.0)
            w_c = w_fresh + w_arr
            mask_c = w_c > 0.0
            contrib = jnp.where(
                mask_c[:, None],
                (w_fresh[:, None] * rel_full
                 + w_arr[:, None] * fault.pending)
                / jnp.maximum(w_c, 1e-12)[:, None], 0.0)
            n_released = (jnp.sum(sched.fresh.astype(jnp.int32))
                          + jnp.sum(sched.arrive.astype(jnp.int32)))
            any_surv = n_released > 0
            # degraded-mode reduce (pipeline stage 5 under fault masking):
            # linear renormalizes over survivors; robust shrinks its trim
            # depth with the survivor count; defenses drop weight-0 rows
            delta_vec = pipe.masked_reduce(
                contrib, w_c, mask_c, trim_frac=fed_cfg.agg.trim_frac)
            delta = tree_unflatten_from_vector(delta_vec, global_params)
            kw = {}
            if agg.buffered:
                kw = dict(mass=jnp.sum(w_c),
                          released=n_released.astype(jnp.float32))
            if agg.needs_losses:
                # adaptive: the server only observed losses that arrived
                # with a fresh release
                kw["mask"] = sched.fresh[idx]
            new_global, new_state = agg.apply(
                server_state, global_params, delta, losses=losses,
                idx=idx, **kw)
            # zero-survivor round: verified no-op on params AND AggState
            new_global = av.tree_where(any_surv, new_global, global_params)
            server_state = av.tree_where(any_surv, new_state, server_state)
            fault = av.advance_fault_state(fault, sched, rel_full, w_full,
                                           avail.rejoin_rounds)
            # mean loss over clients whose local round survived
            n_train = jnp.sum(keep.astype(jnp.float32))
            loss_mean = (jnp.sum(jnp.where(keep, losses, 0.0))
                         / jnp.maximum(n_train, 1.0))
            return (new_global, opt_states, server_state, resid, fault,
                    loss_mean, n_released)

        @functools.partial(jax.jit, donate_argnums=(1, 2, 3))
        def fault_block_fn(global_params, opt_states, resid, fault,
                           server_state, key, eval_mask):
            def body(carry, do_eval):
                g, opt_s, r, f, srv, k = carry
                k, k_round, k_eval = jax.random.split(k, 3)
                (g, opt_s, srv, r, f, loss,
                 n_rel) = fault_round_step(g, opt_s, srv, r, f, k_round)
                scores = jax.lax.cond(
                    do_eval,
                    lambda gp, ke: eval_fn(gp, ke).astype(jnp.float32),
                    lambda gp, ke: jnp.zeros((num_eval,), jnp.float32),
                    g, k_eval)
                return (g, opt_s, r, f, srv, k), (loss, scores, n_rel)

            ((global_params, opt_states, resid, fault, server_state, key),
             (losses, scores, n_rel)) = jax.lax.scan(
                body,
                (global_params, opt_states, resid, fault, server_state,
                 key), eval_mask, unroll=fed_cfg.scan_unroll)
            return (global_params, opt_states, resid, fault, server_state,
                    key, losses, scores, n_rel)

        if self._faults:
            self._round = jax.jit(fault_round_step)
            self._block = fault_block_fn
        else:
            self._round = jax.jit(round_step)
            self._block = block_fn
        self._eval = jax.jit(eval_fn)

    def _eval_mask(self, rounds: int) -> np.ndarray:
        """Rounds that evaluate: every ``eval_every``-th and the last."""
        mask = np.zeros(rounds, np.bool_)
        mask[:: self.fed_cfg.eval_every] = True
        mask[rounds - 1] = True
        return mask

    def _note_privacy(self, hist: History, n: int) -> None:
        """Record cumulative ε after each of ``n`` newly-finished rounds
        (host-side; the accountant composes RDP linearly per round)."""
        self._rounds_elapsed += n
        if not self.fed_cfg.privacy.enabled:
            return
        for r in range(self._rounds_elapsed - n + 1,
                       self._rounds_elapsed + 1):
            hist.round_eps.append(
                self._accountant.epsilon(r) if self._accountant
                else float("inf"))

    def _append_eval(self, hist: History, r: int, scores: np.ndarray,
                     log_every: int) -> None:
        hist.eval_rounds.append(r)
        hist.eval_scores.append(scores)
        hist.eval_mean_as.append(float(scores.mean()))
        hist.eval_fi.append(float(fairness.fairness_index(scores)))
        hist.eval_cov.append(
            float(fairness.coefficient_of_variation(scores)))
        if log_every and r % log_every == 0:
            print(f"[fed] round {r:5d} loss={hist.round_loss[r]:.4f} "
                  f"AS={hist.eval_mean_as[-1]:.4f} "
                  f"FI={hist.eval_fi[-1]:.4f}")

    def run(self, rounds: int | None = None, log_every: int = 0,
            engine: str | None = None) -> History:
        """Run ``rounds`` FedAvg rounds and return the metric ``History``.

        ``engine`` overrides ``FedConfig.engine``: "scan" executes the
        block as one fused jitted scan (default), "loop" dispatches one
        jitted round at a time.
        """
        rounds = rounds or self.fed_cfg.rounds
        engine = engine or self.fed_cfg.engine
        if rounds <= 0:
            return History()
        if engine == "scan":
            return self._run_scan(rounds, log_every)
        if engine == "loop":
            return self._run_loop(rounds, log_every)
        raise ValueError(f"unknown engine {engine!r} (want 'scan'|'loop')")

    def _run_scan(self, rounds: int, log_every: int) -> History:
        fed = self.fed_cfg
        eval_mask = self._eval_mask(rounds)
        key = jax.random.PRNGKey(fed.seed + 1)
        hist = History()
        # one fused block normally; with log_every, blocks of log_every
        # rounds so progress still reaches the console while training
        # (the RNG chain threads through the carried key, so chunking
        # does not change any per-round key).
        chunk = min(log_every, rounds) if log_every else rounds
        full_end = (rounds // chunk) * chunk
        for start in range(0, full_end, chunk):
            mask = eval_mask[start:start + chunk]
            try:
                if self._faults:
                    (self.global_params, self.opt_states, self.ef_resid,
                     self.fault_state, self.server_state, key, losses,
                     scores, n_rel) = self._block(
                        self.global_params, self.opt_states, self.ef_resid,
                        self.fault_state, self.server_state, key,
                        jnp.asarray(mask))
                    hist.round_survivors.extend(
                        int(x) for x in np.asarray(n_rel))
                else:
                    (self.global_params, self.opt_states, self.ef_resid,
                     self.server_state, key, losses, scores) = self._block(
                        self.global_params, self.opt_states, self.ef_resid,
                        self.server_state, key, jnp.asarray(mask))
            except BaseException:
                self._recover_donated_opt_states()
                raise
            base = len(hist.round_loss)
            hist.round_loss.extend(float(x) for x in np.asarray(losses))
            self._note_privacy(hist, len(mask))
            scores = np.asarray(scores)  # (chunk, K); valid where mask
            for r in np.nonzero(mask)[0]:
                self._append_eval(hist, base + int(r), scores[r], log_every)
        # remainder shorter than a chunk: run per-round (same key chain)
        # rather than compiling the fused block a second time for a tail
        for r in range(full_end, rounds):
            key = self._dispatch_round(hist, key, r, eval_mask, log_every)
        return hist

    def _dispatch_round(self, hist: History, key, r: int, eval_mask,
                        log_every: int):
        """One per-round dispatch + metric append; shared by the loop
        driver and the scan driver's sub-chunk tail. Returns the carried
        key (chain identical to one scan step)."""
        key, k_round, k_eval = jax.random.split(key, 3)
        if self._faults:
            (self.global_params, self.opt_states, self.server_state,
             self.ef_resid, self.fault_state, loss, n_rel) = self._round(
                self.global_params, self.opt_states, self.server_state,
                self.ef_resid, self.fault_state, k_round)
            hist.round_loss.append(float(loss))
            hist.round_survivors.append(int(n_rel))
        else:
            (self.global_params, self.opt_states, self.server_state,
             self.ef_resid, losses) = self._round(
                self.global_params, self.opt_states, self.server_state,
                self.ef_resid, k_round)
            hist.round_loss.append(float(jnp.mean(losses)))
        self._note_privacy(hist, 1)
        if eval_mask[r]:
            scores = np.asarray(self._eval(self.global_params, k_eval))
            self._append_eval(hist, r, scores, log_every)
        return key

    def _recover_donated_opt_states(self) -> None:
        """After an interrupted block call the donated opt buffers may be
        consumed; rebuild them from the still-valid global params so the
        trainer stays usable (Adam moments reset, training state kept).
        Buffers that were never actually donated (e.g. interrupt during
        tracing, or a backend that ignores donation) are left alone.
        The donated EF residual recovers to zeros the same way (error
        feedback restarts; the global model is untouched)."""
        leaves = jax.tree.leaves(self.opt_states)
        deleted = any(getattr(x, "is_deleted", lambda: False)()
                      for x in leaves)
        if deleted:
            per_client = broadcast_to_clients(self.global_params,
                                              len(self.train_groups))
            self.opt_states = jax.vmap(self.opt.init)(per_client)
        if self.ef_resid is not None and getattr(
                self.ef_resid, "is_deleted", lambda: False)():
            self.ef_resid = jnp.zeros(self.ef_resid.shape, jnp.float32)
        if self.fault_state is not None and any(
                getattr(x, "is_deleted", lambda: False)()
                for x in jax.tree.leaves(self.fault_state)):
            # the in-flight buffer is lost with the interrupt; restart
            # the schedule from an empty fault state (deterministic
            # replay resumes from the carried round key)
            self.fault_state = av.init_fault_state(
                len(self.train_groups),
                tree_count_params(self.global_params))

    def _run_loop(self, rounds: int, log_every: int) -> History:
        hist = History()
        key = jax.random.PRNGKey(self.fed_cfg.seed + 1)
        eval_mask = self._eval_mask(rounds)  # shared cadence, both drivers
        for r in range(rounds):
            key = self._dispatch_round(hist, key, r, eval_mask, log_every)
        return hist


# ---------------------------------------------------------------------------
# Engine 2: shard_map over the mesh client axis (TPU production / dry-run)
# ---------------------------------------------------------------------------
def make_sharded_round(gpo_cfg: GPOConfig, fed_cfg: FedConfig,
                       data: SurveyData, mesh, client_axes=("data",),
                       opt=None, agg: ServerAggregator | None = None
                       ) -> Callable:
    """Returns round_fn(client_params, opt_states, keys, group_ids,
    weights, server_state) -> (client_params, opt_states, losses,
    server_state).

    Client-carrying arguments have a leading *global* client axis sharded
    over ``client_axes``; ``server_state`` is replicated (every shard
    applies the same deterministic server update, DESIGN.md §7).
    Linear strategies reduce the client deltas with ONE weighted psum
    over those axes — the virtualized server; robust strategies
    all-gather the flattened delta shard and rank-trim locally (order
    statistics do not decompose into a psum). Multi-pod:
    client_axes=("pod", "data") gives hierarchical aggregation.
    With ``FedConfig.privacy`` enabled (DESIGN.md §9) each shard clips
    and noises its own clients' flat deltas LOCALLY — the per-client L2
    norm lives entirely within the client's shard, so no collective
    moves before the release point — and the round's single psum then
    carries the already-noised weighted sum (the robust family gathers
    the privatized matrix instead). Noise keys fold out of the
    per-client training ``keys``, so the round is bit-reproducible
    against the stacked engine given the same keys.
    For ``adaptive``, effective per-group weights are formed OUTSIDE the
    shard_map from the replicated scores (they need a normalization over
    all clients), so the mapped body stays collective-minimal.

    With ``FedConfig.compression`` enabled (DESIGN.md §10) each shard
    compresses its own clients' (privatized) flat deltas LOCALLY, after
    the DP release point: the linear family dequantizes shard-locally
    and keeps its ONE weighted psum; the robust family all-gathers the
    int8 payload + f32 per-client scales instead of f32 vectors (~4×
    fewer bytes on the round's dominant collective; ``dryrun.py
    --gpo-fed --compress int8`` prints the compiled byte counts). With
    ``error_feedback`` the round gains a trailing sharded
    ``resid (C_local, P)`` argument/result carrying the EF21 residual.
    Rounding uniforms fold out of the per-client training ``keys`` (the
    §9 noise-key scheme), so the round stays bit-reproducible against
    the stacked engine given the same keys.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    gpo_cfg = fed_cfg.resolve_gpo(gpo_cfg)  # runtime attention override
    fed_cfg.privacy.validate()
    fed_cfg.compression.validate()
    fed_cfg.adversary.validate()
    fed_cfg.hierarchy.validate(fed_cfg.num_clients)
    if fed_cfg.hierarchy.enabled:
        # the two-hop schedule (§14) needs a leading 'edge' mesh axis of
        # exactly num_edges shards in front of the intra-edge client
        # axes — build the mesh with launch.mesh.make_edge_mesh
        if (len(client_axes) < 2
                or mesh.shape[client_axes[0]] != fed_cfg.hierarchy.num_edges):
            raise ValueError(
                f"hierarchy.num_edges={fed_cfg.hierarchy.num_edges} "
                f"requires client_axes=('edge', ...) with a leading axis "
                f"of that size; got {tuple(client_axes)} on mesh "
                f"{dict(mesh.shape)}")
    byz.check_defense_composition(fed_cfg)
    priv = fed_cfg.privacy
    comp = fed_cfg.compression
    ef = comp.enabled and comp.error_feedback
    opt = opt or adam(fed_cfg.lr)
    if agg is None:
        agg = make_aggregator(fed_cfg.agg, num_clients=fed_cfg.num_clients,
                              use_pallas=fed_cfg.use_pallas_aggregation)
    local_train = _make_local_train(gpo_cfg, fed_cfg, data, opt)
    # the same declared stage pipeline as the stacked engine (DESIGN.md
    # §13): this body keeps the client layout and collective placement,
    # the pipeline owns the stage dispatch. With the adversary enabled
    # the round gains a trailing REPLICATED ``byz_key`` argument (the
    # launcher folds it from the round key) — the attack-off signature,
    # trace, and collective schedule are unchanged.
    pipe = make_pipeline(fed_cfg, agg=agg, num_clients=fed_cfg.num_clients)
    adv_on = fed_cfg.adversary.enabled
    axes = tuple(client_axes)
    spec = P(axes)
    repl = P()

    def _shard_gids(c_local):
        """This shard's global client ids, from the static mesh shape —
        no collective."""
        shard = 0
        for a in axes:
            shard = shard * mesh.shape[a] + jax.lax.axis_index(a)
        return shard * c_local + jnp.arange(c_local, dtype=jnp.int32)

    def round_body(client_params, opt_states, keys, group_ids, weights,
                   server_state, resid=None, byz_key=None):
        # local shard: (C_local, ...) clients; train without collectives
        gids = _shard_gids(keys.shape[0]) if adv_on else None
        train_args = (client_params, opt_states, keys, group_ids)
        if pipe.flip_data:
            train_args += (pipe.attacked_flags(byz_key, gids),)
        new_params, new_opt, losses = jax.vmap(local_train)(*train_args)
        # delta contract: entry params ARE the replicated global model
        deltas = tree_sub(new_params, client_params)
        global_prev = tree_index(client_params, 0)
        # pipeline stages 2-5 head: [attack →] privacy → codec → reduce
        # collective (ONE weighted psum for the linear family, an
        # all-gather of rows for the robust one — see
        # RoundPipeline.sharded_delta for the full dispatch).
        delta, new_resid = pipe.sharded_delta(
            deltas, weights, keys, global_prev, resid, axes,
            byz_key=byz_key, gids=gids)
        all_losses = (jax.lax.all_gather(losses, axes, axis=0, tiled=True)
                      if agg.needs_losses else None)
        # replicated server update: same inputs on every shard -> same
        # global model and state, no second parameter-sized collective.
        global_params, server_state = agg.apply(
            server_state, global_prev, delta, losses=all_losses, idx=None)
        # redistribute: every client's next-round start is the global model
        c_local = keys.shape[0]
        client_params = broadcast_to_clients(global_params, c_local)
        return client_params, new_opt, losses, server_state, new_resid

    # ----------------------------------------------------------------------
    # Fault-aware sharded round (DESIGN.md §11). The schedule is derived
    # REPLICATED on every shard from the replicated ``fault_key`` + the
    # static client count — no collective is spent agreeing on who
    # failed — and ``weights`` arrive replicated (full (C,)) so the
    # survivor-mass renormalization is also computed redundantly per
    # shard. Only the in-flight straggler payloads (``FaultState.
    # pending``, the one parameter-sized leaf) are sharded with their
    # clients. Net effect: the linear family keeps its ONE psum with
    # byte-identical shape (survivor weights are zeroed, lost rows
    # contribute 0·row); the robust family keeps its single (C, P) f32
    # all-gather of the combined contribution rows (under compression
    # this forgoes the int8 wire layout — buffered arrivals are stored
    # decompressed, so the fault path gathers f32; dryrun --faults
    # reports the realized bytes).
    avail = fed_cfg.avail

    def fault_round_body(client_params, opt_states, keys, group_ids,
                         weights, server_state, fault, fault_key,
                         resid=None, byz_key=None):
        c_local = keys.shape[0]
        num_clients = weights.shape[0]  # replicated full population
        gids = _shard_gids(c_local)
        sched = av.round_schedule(fault_key, fault, avail, num_clients)
        train_args = (client_params, opt_states, keys, group_ids)
        if pipe.flip_data:
            train_args += (pipe.attacked_flags(byz_key, gids),)
        new_params, new_opt, losses = jax.vmap(local_train)(*train_args)
        deltas = tree_sub(new_params, client_params)
        global_prev = tree_index(client_params, 0)
        fresh_l = sched.fresh[gids]
        keep_l = fresh_l | sched.straggle[gids]
        new_opt = jax.tree.map(
            lambda n, o: jnp.where(
                keep_l.reshape((-1,) + (1,) * (n.ndim - 1)), n, o),
            new_opt, opt_states)
        # shard-local per-client [attack →] privacy → codec release; EF
        # rows advance only where the client actually released (fresh or
        # straggler-sent). A Byzantine straggler's BUFFERED payload is
        # already corrupted — the attack rides §11's replay semantics.
        rel_l, new_r = pipe.release_rows(
            tree_ravel_clients(deltas), keys, resid,
            byz_key=byz_key, gids=gids, axes=axes)
        new_resid = (jnp.where(keep_l[:, None], new_r, resid)
                     if ef else None)
        # contribution weights: replicated-computable from the schedule
        w_eff = weights.astype(jnp.float32)
        disc = av.staleness_discount(sched.staleness,
                                     fed_cfg.agg.staleness_power)
        w_fresh = jnp.where(sched.fresh, w_eff, 0.0)
        w_arr = jnp.where(sched.arrive, fault.pending_weight * disc, 0.0)
        w_c = w_fresh + w_arr
        mask_c = w_c > 0.0
        n_released = (jnp.sum(sched.fresh.astype(jnp.int32))
                      + jnp.sum(sched.arrive.astype(jnp.int32)))
        any_surv = n_released > 0
        mass = jnp.sum(w_c)
        # local combined contribution rows (same float ops as the
        # stacked engine, sliced at this shard's global client ids)
        wf_l, wa_l, wc_l = w_fresh[gids], w_arr[gids], w_c[gids]
        contrib_l = jnp.where(
            (wc_l > 0.0)[:, None],
            (wf_l[:, None] * rel_l + wa_l[:, None] * fault.pending)
            / jnp.maximum(wc_l, 1e-12)[:, None], 0.0)
        # pipeline aggregate stage, degraded mode: norm bound clips the
        # blended rows, then linear keeps the shard-local partial sum +
        # ONE psum while robust/defense families all-gather the rows.
        delta = tree_unflatten_from_vector(
            pipe.masked_reduce_sharded(
                contrib_l, w_c, mask_c, gids, axes,
                trim_frac=fed_cfg.agg.trim_frac), global_prev)
        all_losses = (jax.lax.all_gather(losses, axes, axis=0, tiled=True)
                      if agg.needs_losses else None)
        kw = {}
        if agg.buffered:
            kw = dict(mass=mass, released=n_released.astype(jnp.float32))
        if agg.needs_losses:
            kw["mask"] = sched.fresh
        new_global, new_state = agg.apply(
            server_state, global_prev, delta, losses=all_losses, idx=None,
            **kw)
        new_global = av.tree_where(any_surv, new_global, global_prev)
        server_state = av.tree_where(any_surv, new_state, server_state)
        # advance the fault state: metadata replicated, payloads local
        r = fault.round
        strag_l, arr_l = sched.straggle[gids], sched.arrive[gids]
        pending_l = jnp.where(strag_l[:, None], rel_l,
                              jnp.where(arr_l[:, None], 0.0,
                                        fault.pending))
        fault = av.FaultState(
            round=r + 1,
            offline_until=jnp.where(
                sched.crashed, r + 1 + int(avail.rejoin_rounds),
                fault.offline_until),
            pending=pending_l,
            pending_due=jnp.where(
                sched.straggle, r + sched.delay,
                jnp.where(sched.arrive, av.NO_PENDING,
                          fault.pending_due)),
            pending_weight=jnp.where(
                sched.straggle, w_eff,
                jnp.where(sched.arrive, 0.0, fault.pending_weight)),
            pending_birth=jnp.where(sched.straggle, r,
                                    fault.pending_birth))
        client_params = broadcast_to_clients(new_global, c_local)
        return (client_params, new_opt, losses, server_state, fault,
                new_resid)

    faults = avail.enabled
    # positional spec assembly: the base signature per engine, then the
    # optional trailing args in fixed order — EF residual shard (spec),
    # then replicated Byzantine key. Attack-off keeps the exact pre-§13
    # tuples (and traces), so the lowered round is byte-identical.
    if faults:
        fault_spec = av.FaultState(
            round=repl, offline_until=repl, pending=spec,
            pending_due=repl, pending_weight=repl, pending_birth=repl)
        # weights replicated: every shard renormalizes the survivor mass
        # redundantly instead of spending a collective on it
        in_specs = [spec, spec, spec, spec, repl, repl, fault_spec, repl]
        out_specs = [spec, spec, spec, repl, fault_spec]
        inner, n_out = fault_round_body, 5
    else:
        in_specs = [spec, spec, spec, spec, spec, repl]
        out_specs = [spec, spec, spec, repl]
        inner, n_out = round_body, 4
    if ef:
        in_specs.append(spec)
        out_specs.append(spec)
    if adv_on:
        in_specs.append(repl)

    def body(*args):
        base, rest = args[:len(in_specs) - ef - adv_on], \
            args[len(in_specs) - ef - adv_on:]
        resid = rest[0] if ef else None
        bk = rest[-1] if adv_on else None
        out = inner(*base, resid=resid, byz_key=bk)
        return out if ef else out[:n_out]

    sharded = shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                        out_specs=tuple(out_specs), check_rep=False)

    def round_fn(client_params, opt_states, keys, group_ids, weights,
                 server_state, *rest):
        weights = agg.weigh(server_state, weights, None)
        return sharded(client_params, opt_states, keys, group_ids, weights,
                       server_state, *rest)

    return round_fn
