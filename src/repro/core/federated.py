"""PluralLLM federated runtime (paper §3, §4.3).

Round structure (faithful to the paper):
  1. server broadcasts global GPO params to all training clients (groups);
  2. every client runs ``local_epochs`` Adam steps; each step samples
     context questions + target questions from the client's private
     preference data (in-context objective, Eq. 1);
  3. clients transmit parameters; the server aggregates with
     dataset-size weights p_g (Eq. 2-3) and redistributes.

Two execution engines expose the same round semantics:

* ``FederatedGPO`` — clients vmapped on one device. This is the
  paper-faithful simulation used for the CPU experiments (benchmarks
  reproduce Figs. 2-5 with it).
* ``make_sharded_round`` — clients laid out on the mesh `data` axis via
  ``shard_map``; local epochs run without any cross-client collective and
  the round ends in ONE weighted psum (+ the hierarchical `pod` axis on
  multi-pod meshes). This is the TPU-production engine the dry-run lowers.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig, GPOConfig
from repro.core import fairness
from repro.core.fedavg import (
    broadcast_to_clients,
    fedavg_allreduce,
    fedavg_stacked,
    normalize_weights,
)
from repro.core.gpo import gpo_loss, init_gpo_params, predict_preferences
from repro.data.surveys import SurveyData, sample_icl_batch
from repro.optim import adam

PyTree = Any


# ---------------------------------------------------------------------------
# Local training (one client, `local_epochs` steps) — shared by both engines
# ---------------------------------------------------------------------------
def _make_local_train(gpo_cfg: GPOConfig, fed_cfg: FedConfig,
                      data: SurveyData, opt):
    def local_train(params, opt_state, key, group_id):
        def epoch_step(carry, k):
            params, opt_state = carry
            batch = sample_icl_batch(k, data, group_id,
                                     fed_cfg.num_context, fed_cfg.num_target)
            loss, grads = jax.value_and_grad(gpo_loss)(
                params, gpo_cfg, batch.ctx_x, batch.ctx_y, batch.tgt_x,
                batch.tgt_y)
            params, opt_state = opt.update(grads, opt_state, params)
            return (params, opt_state), loss

        keys = jax.random.split(key, fed_cfg.local_epochs)
        (params, opt_state), losses = jax.lax.scan(
            epoch_step, (params, opt_state), keys)
        return params, opt_state, jnp.mean(losses)

    return local_train


def _make_eval_group(gpo_cfg: GPOConfig, fed_cfg: FedConfig, data: SurveyData):
    """AS of the global model on one (unseen) group — Eq. 4."""

    def eval_group(params, key, group_id):
        batch = sample_icl_batch(key, data, group_id,
                                 fed_cfg.num_context, fed_cfg.num_target)
        pred = predict_preferences(params, gpo_cfg, batch.ctx_x, batch.ctx_y,
                                   batch.tgt_x, data.num_options)
        truth = batch.tgt_y.reshape(-1, data.num_options)
        return fairness.alignment_score(pred, truth)

    return eval_group


# ---------------------------------------------------------------------------
# Engine 1: vmapped clients (paper-faithful CPU simulation)
# ---------------------------------------------------------------------------
@dataclass
class History:
    round_loss: list = field(default_factory=list)  # mean client loss / round
    eval_rounds: list = field(default_factory=list)
    eval_scores: list = field(default_factory=list)  # (K,) per eval round
    eval_mean_as: list = field(default_factory=list)
    eval_fi: list = field(default_factory=list)
    eval_cov: list = field(default_factory=list)


class FederatedGPO:
    def __init__(self, gpo_cfg: GPOConfig, fed_cfg: FedConfig,
                 data: SurveyData, train_groups: np.ndarray,
                 eval_groups: np.ndarray):
        assert gpo_cfg.d_embed == data.phi.shape[-1]
        self.gpo_cfg, self.fed_cfg, self.data = gpo_cfg, fed_cfg, data
        self.train_groups = jnp.asarray(train_groups, jnp.int32)
        self.eval_groups = jnp.asarray(eval_groups, jnp.int32)
        self.weights = normalize_weights(data.sizes[self.train_groups])
        self.opt = adam(fed_cfg.lr)

        key = jax.random.PRNGKey(fed_cfg.seed)
        self.global_params = init_gpo_params(gpo_cfg, key)
        per_client = broadcast_to_clients(self.global_params,
                                          len(train_groups))
        self.opt_states = jax.vmap(self.opt.init)(per_client)

        local_train = _make_local_train(gpo_cfg, fed_cfg, data, self.opt)
        eval_group = _make_eval_group(gpo_cfg, fed_cfg, data)
        num_clients = len(train_groups)
        # partial participation (beyond-paper ablation): sample
        # batch_groups clients per round; weights renormalize over the
        # participants (paper §4.3 assumes full participation).
        m = fed_cfg.batch_groups or num_clients
        m = min(m, num_clients)

        @jax.jit
        def round_fn(global_params, opt_states, key):
            k_sub, k_train = jax.random.split(key)
            if m < num_clients:
                idx = jax.random.choice(k_sub, num_clients, (m,),
                                        replace=False)
            else:
                idx = jnp.arange(num_clients)
            groups = self.train_groups[idx]
            sizes = data.sizes[groups].astype(jnp.float32)
            w = sizes / jnp.sum(sizes)
            client_params = broadcast_to_clients(global_params, m)
            if fed_cfg.reset_opt_each_round:
                opt_sub = jax.vmap(self.opt.init)(client_params)
            else:
                opt_sub = jax.tree.map(lambda x: x[idx], opt_states)
            keys = jax.random.split(k_train, m)
            client_params, opt_sub, losses = jax.vmap(local_train)(
                client_params, opt_sub, keys, groups)
            opt_states = jax.tree.map(
                lambda full, sub: full.at[idx].set(sub), opt_states,
                opt_sub)
            new_global = fedavg_stacked(client_params, w)
            return new_global, opt_states, losses

        @jax.jit
        def eval_fn(global_params, key):
            keys = jax.random.split(key, len(eval_groups))
            return jax.vmap(eval_group, in_axes=(None, 0, 0))(
                global_params, keys, self.eval_groups)

        self._round = round_fn
        self._eval = eval_fn

    def run(self, rounds: int | None = None,
            log_every: int = 0) -> History:
        fed = self.fed_cfg
        rounds = rounds or fed.rounds
        hist = History()
        key = jax.random.PRNGKey(fed.seed + 1)
        for r in range(rounds):
            key, k_round, k_eval = jax.random.split(key, 3)
            self.global_params, self.opt_states, losses = self._round(
                self.global_params, self.opt_states, k_round)
            hist.round_loss.append(float(jnp.mean(losses)))
            if r % fed.eval_every == 0 or r == rounds - 1:
                scores = np.asarray(self._eval(self.global_params, k_eval))
                hist.eval_rounds.append(r)
                hist.eval_scores.append(scores)
                hist.eval_mean_as.append(float(scores.mean()))
                hist.eval_fi.append(float(fairness.fairness_index(scores)))
                hist.eval_cov.append(
                    float(fairness.coefficient_of_variation(scores)))
                if log_every and r % log_every == 0:
                    print(f"[fed] round {r:5d} loss={hist.round_loss[-1]:.4f} "
                          f"AS={hist.eval_mean_as[-1]:.4f} "
                          f"FI={hist.eval_fi[-1]:.4f}")
        return hist


# ---------------------------------------------------------------------------
# Engine 2: shard_map over the mesh client axis (TPU production / dry-run)
# ---------------------------------------------------------------------------
def make_sharded_round(gpo_cfg: GPOConfig, fed_cfg: FedConfig,
                       data: SurveyData, mesh, client_axes=("data",),
                       opt=None) -> Callable:
    """Returns round_fn(client_params, opt_states, keys, group_ids, weights)
    with every argument carrying a leading *global* client axis sharded over
    ``client_axes``. Aggregation = ONE weighted psum over those axes —
    the virtualized server. Multi-pod: client_axes=("pod", "data") gives
    hierarchical FedAvg.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    opt = opt or adam(fed_cfg.lr)
    local_train = _make_local_train(gpo_cfg, fed_cfg, data, opt)
    axes = tuple(client_axes)
    spec = P(axes)

    def round_body(client_params, opt_states, keys, group_ids, weights):
        # local shard: (C_local, ...) clients; train without collectives
        new_params, new_opt, losses = jax.vmap(local_train)(
            client_params, opt_states, keys, group_ids)
        # Eq. 3: weighted psum over the client axes == aggregation server.
        local_weighted = jax.tree.map(
            lambda x: jnp.sum(
                x.astype(jnp.float32)
                * weights.reshape((-1,) + (1,) * (x.ndim - 1)), axis=0),
            new_params)
        global_params = fedavg_allreduce(
            local_weighted, jnp.asarray(1.0, jnp.float32), axes)
        # redistribute: every client's next-round start is the global model
        c_local = keys.shape[0]
        client_params = broadcast_to_clients(global_params, c_local)
        return client_params, new_opt, losses

    in_specs = (spec, spec, spec, spec, spec)
    out_specs = (spec, spec, spec)
    return shard_map(round_body, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)
