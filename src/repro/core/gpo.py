"""GPO: the transformer-based group preference predictor (Zhao et al. 2023),
the module PluralLLM trains federatedly.

A transformer neural process (TNP-style):

* every (embedding x, preference y) pair becomes one token [x ; y ; is_ctx];
  target tokens carry y = 0 and is_ctx = 0;
* NO positional encoding — the predictor must be permutation-invariant in
  the context set (property-tested in tests/test_property.py);
* the neural-process mask: context tokens attend to context tokens;
  target tokens attend to context tokens and themselves, never to other
  targets (no information leaks between targets — Eq. 1's conditional
  independence);
* the head reads target tokens and emits the predicted preference
  (Gaussian mean; optional learned sigma), trained with Eq. 1's NLL,
  which for fixed sigma is MSE — GPO's practice.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import GPOConfig
from repro.kernels.quant_matmul import QuantizedLinear
from repro.models.layers import dense_init, rms_norm

NEG_INF = -1e30


def _mm(x, w):
    """Dense-layer matmul with static weight-format dispatch: plain f32
    arrays multiply directly; ``QuantizedLinear`` leaves (the serving
    engine's load-time int8 weights, DESIGN.md §12) route through the
    fused int8 kernel. The pytree structure is static under jit, so the
    training path traces exactly as before."""
    if isinstance(w, QuantizedLinear):
        from repro.kernels import int8_matmul

        return int8_matmul(x, w.q, w.scale)
    return x @ w


class GPOLayer(NamedTuple):
    ln1: jnp.ndarray
    wq: jnp.ndarray
    wk: jnp.ndarray
    wv: jnp.ndarray
    wo: jnp.ndarray
    ln2: jnp.ndarray
    w1: jnp.ndarray
    w2: jnp.ndarray


def init_gpo_params(cfg: GPOConfig, key) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 4)
    d = cfg.d_model

    def init_layer(k):
        ks = jax.random.split(k, 6)
        return GPOLayer(
            ln1=jnp.zeros((d,), dtype),
            wq=dense_init(ks[0], (d, d), dtype=dtype),
            wk=dense_init(ks[1], (d, d), dtype=dtype),
            wv=dense_init(ks[2], (d, d), dtype=dtype),
            wo=dense_init(ks[3], (d, d), dtype=dtype),
            ln2=jnp.zeros((d,), dtype),
            w1=dense_init(ks[4], (d, cfg.d_ff), dtype=dtype),
            w2=dense_init(ks[5], (cfg.d_ff, d), dtype=dtype),
        )

    layer_keys = jax.random.split(keys[0], cfg.num_layers)
    out_dim = 2 if cfg.learn_sigma else 1
    return {
        # token = [x ; y ; is_context] -> d_model
        "in_proj": dense_init(keys[1], (cfg.d_embed + 2, d), dtype=dtype),
        "layers": jax.vmap(init_layer)(layer_keys),
        "final_norm": jnp.zeros((d,), dtype),
        "head": dense_init(keys[2], (d, out_dim), dtype=dtype),
    }


def _np_mask(num_ctx: int, num_tgt: int) -> jnp.ndarray:
    """Neural-process attention mask (S, S), S = m + t.

    allowed[i, j] = True iff token i may attend token j:
      * j < m (context): always allowed,
      * j >= m: only if i == j (target self-attention).
    """
    s = num_ctx + num_tgt
    is_ctx_col = jnp.arange(s) < num_ctx
    eye = jnp.eye(s, dtype=bool)
    return jnp.broadcast_to(is_ctx_col[None, :], (s, s)) | eye


def gpo_apply(params: dict, cfg: GPOConfig, ctx_x, ctx_y, tgt_x):
    """Predict target preferences.

    ctx_x (m, d_embed), ctx_y (m,), tgt_x (t, d_embed)
    -> (mu (t,), log_sigma (t,) or None)
    Batch with vmap for multiple groups.
    """
    m, t = ctx_x.shape[0], tgt_x.shape[0]
    ctx_tok = jnp.concatenate(
        [ctx_x, ctx_y[:, None], jnp.ones((m, 1), ctx_x.dtype)], axis=-1)
    tgt_tok = jnp.concatenate(
        [tgt_x, jnp.zeros((t, 2), tgt_x.dtype)], axis=-1)
    tokens = jnp.concatenate([ctx_tok, tgt_tok], axis=0)  # (S, d_embed+2)

    x = _mm(tokens, params["in_proj"])  # (S, d)
    h_dim = cfg.head_dim
    nh = cfg.num_heads

    def body(x, layer: GPOLayer):
        layer = GPOLayer(*layer)
        h = rms_norm(x, layer.ln1, cfg.norm_eps)
        s = h.shape[0]
        q = _mm(h, layer.wq).reshape(s, nh, h_dim)
        k = _mm(h, layer.wk).reshape(s, nh, h_dim)
        v = _mm(h, layer.wv).reshape(s, nh, h_dim)
        if cfg.use_pallas_attention:
            # banded flash kernel with a custom VJP (DESIGN.md §4, §8):
            # valid under jax.grad, so training (gpo_loss) and inference
            # share the same tiled path — the dense (heads, S, S) score
            # tensor below is never materialized.
            from repro.kernels import gpo_attention

            att = gpo_attention(q, k, v, num_ctx=m).reshape(s, -1)
        else:
            scores = jnp.einsum("ihd,jhd->hij", q, k) / jnp.sqrt(
                jnp.asarray(h_dim, jnp.float32))
            scores = jnp.where(_np_mask(m, t)[None], scores, NEG_INF)
            probs = jax.nn.softmax(scores.astype(jnp.float32),
                                   axis=-1).astype(v.dtype)
            att = jnp.einsum("hij,jhd->ihd", probs, v).reshape(s, -1)
        x = x + _mm(att, layer.wo)
        h2 = rms_norm(x, layer.ln2, cfg.norm_eps)
        x = x + _mm(jax.nn.gelu(_mm(h2, layer.w1)), layer.w2)
        return x, None

    x, _ = jax.lax.scan(body, x, params["layers"],
                        unroll=min(cfg.layer_unroll, cfg.num_layers))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    out = _mm(x[m:], params["head"])  # (t, 1 or 2)
    mu = out[:, 0]
    log_sigma = out[:, 1] if cfg.learn_sigma else None
    return mu, log_sigma


class GPOPrefix(NamedTuple):
    """Per-layer context K/V from ``gpo_prefill`` — the reusable half of
    a GPO forward pass (DESIGN.md §12).

    The neural-process mask makes the split exact, not approximate:
    context tokens attend ONLY to context tokens, so their hidden states
    — and therefore every layer's context keys/values — are independent
    of whatever targets are later decoded against them. ``k``/``v`` are
    (L, M, nh, hd); rows at positions >= the ``ctx_len`` the prefix was
    built with are padding and must be masked by the consumer."""

    k: jnp.ndarray
    v: jnp.ndarray

    @property
    def num_ctx(self) -> int:
        return self.k.shape[1]


def _key_mask(num_keys: int, ctx_len) -> Optional[jnp.ndarray]:
    """(num_keys,) bool — True for real context positions. ``ctx_len``
    may be a traced scalar (the serving engine batches ragged requests
    padded to a shared bucket); None means every position is real."""
    if ctx_len is None:
        return None
    return jnp.arange(num_keys) < ctx_len


def gpo_prefill(params: dict, cfg: GPOConfig, ctx_x, ctx_y,
                ctx_len=None) -> GPOPrefix:
    """Run the context block alone and cache per-layer K/V.

    ctx_x (M, d_embed), ctx_y (M,) — M may include padding rows, with
    ``ctx_len`` (static or traced scalar) giving the real count; padded
    rows are excluded as attention *keys*, so their (garbage, finite)
    hidden states never influence real rows. Batch with vmap.
    """
    m = ctx_x.shape[0]
    tokens = jnp.concatenate(
        [ctx_x, ctx_y[:, None], jnp.ones((m, 1), ctx_x.dtype)], axis=-1)
    x = _mm(tokens, params["in_proj"])  # (M, d)
    h_dim, nh = cfg.head_dim, cfg.num_heads
    mask = _key_mask(m, ctx_len)

    def body(x, layer: GPOLayer):
        layer = GPOLayer(*layer)
        h = rms_norm(x, layer.ln1, cfg.norm_eps)
        q = _mm(h, layer.wq).reshape(m, nh, h_dim)
        k = _mm(h, layer.wk).reshape(m, nh, h_dim)
        v = _mm(h, layer.wv).reshape(m, nh, h_dim)
        scores = jnp.einsum("ihd,jhd->hij", q, k) / jnp.sqrt(
            jnp.asarray(h_dim, jnp.float32))
        if mask is not None:
            scores = jnp.where(mask[None, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores.astype(jnp.float32),
                               axis=-1).astype(v.dtype)
        att = jnp.einsum("hij,jhd->ihd", probs, v).reshape(m, -1)
        x = x + _mm(att, layer.wo)
        h2 = rms_norm(x, layer.ln2, cfg.norm_eps)
        x = x + _mm(jax.nn.gelu(_mm(h2, layer.w1)), layer.w2)
        return x, (k, v)

    _, (ks, vs) = jax.lax.scan(body, x, params["layers"],
                               unroll=min(cfg.layer_unroll, cfg.num_layers))
    return GPOPrefix(k=ks, v=vs)


def gpo_decode(params: dict, cfg: GPOConfig, prefix: GPOPrefix, tgt_x,
               ctx_len=None):
    """Decode targets against a cached context prefix.

    tgt_x (T, d_embed) -> (mu (T,), log_sigma (T,) or None). Each target
    token attends to the prefix keys (masked to ``ctx_len``) plus
    itself — an (nh, T, M+1) score tensor instead of the monolithic
    (nh, S, S): prefill work is never repeated, which is the whole
    point of the prefix cache. Padded target rows produce finite
    garbage and must be sliced off by the caller (targets never attend
    to each other, so they cannot perturb real rows). Batch with vmap.
    """
    t = tgt_x.shape[0]
    mctx = prefix.num_ctx
    tokens = jnp.concatenate(
        [tgt_x, jnp.zeros((t, 2), tgt_x.dtype)], axis=-1)
    x = _mm(tokens, params["in_proj"])  # (T, d)
    h_dim, nh = cfg.head_dim, cfg.num_heads
    mask = _key_mask(mctx, ctx_len)

    def body(x, layer_kv):
        layer, kc, vc = layer_kv  # kc/vc (M, nh, hd)
        layer = GPOLayer(*layer)
        h = rms_norm(x, layer.ln1, cfg.norm_eps)
        q = _mm(h, layer.wq).reshape(t, nh, h_dim)
        k_self = _mm(h, layer.wk).reshape(t, nh, h_dim)
        v_self = _mm(h, layer.wv).reshape(t, nh, h_dim)
        inv_sqrt = 1.0 / jnp.sqrt(jnp.asarray(h_dim, jnp.float32))
        sc_ctx = jnp.einsum("ihd,jhd->hij", q, kc) * inv_sqrt  # (h, T, M)
        sc_self = jnp.sum(q * k_self, axis=-1).T[:, :, None] * inv_sqrt
        scores = jnp.concatenate([sc_ctx, sc_self], axis=-1)  # (h, T, M+1)
        if mask is not None:
            full = jnp.concatenate(
                [mask, jnp.ones((1,), bool)])  # self always attends
            scores = jnp.where(full[None, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores.astype(jnp.float32),
                               axis=-1).astype(v_self.dtype)
        att = (jnp.einsum("hij,jhd->ihd", probs[..., :mctx], vc)
               + probs[..., mctx:].transpose(1, 0, 2) * v_self)
        x = x + _mm(att.reshape(t, -1), layer.wo)
        h2 = rms_norm(x, layer.ln2, cfg.norm_eps)
        x = x + _mm(jax.nn.gelu(_mm(h2, layer.w1)), layer.w2)
        return x, None

    x, _ = jax.lax.scan(body, x, (params["layers"], prefix.k, prefix.v),
                        unroll=min(cfg.layer_unroll, cfg.num_layers))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    out = _mm(x, params["head"])  # (T, 1 or 2)
    mu = out[:, 0]
    log_sigma = out[:, 1] if cfg.learn_sigma else None
    return mu, log_sigma


def gpo_loss(params: dict, cfg: GPOConfig, ctx_x, ctx_y, tgt_x, tgt_y):
    """Eq. 1: NLL of target preferences given context (Gaussian p_theta)."""
    mu, log_sigma = gpo_apply(params, cfg, ctx_x, ctx_y, tgt_x)
    if log_sigma is None:
        return jnp.mean(jnp.square(mu - tgt_y))
    inv_var = jnp.exp(-2.0 * log_sigma)
    return jnp.mean(0.5 * inv_var * jnp.square(mu - tgt_y) + log_sigma)


def predict_preferences(params: dict, cfg: GPOConfig, ctx_x, ctx_y, tgt_x,
                        num_options: int) -> jnp.ndarray:
    """Predicted preference distributions per target question.

    tgt_x is (t*A, d_embed) grouped by question (A consecutive options).
    Returns (t, A) rows on the simplex (clip-and-normalize, GPO's eval).
    """
    mu, _ = gpo_apply(params, cfg, ctx_x, ctx_y, tgt_x)
    scores = mu.reshape(-1, num_options)
    scores = jnp.clip(scores, 1e-4, None)
    return scores / scores.sum(axis=-1, keepdims=True)
