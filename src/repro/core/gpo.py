"""GPO: the transformer-based group preference predictor (Zhao et al. 2023),
the module PluralLLM trains federatedly.

A transformer neural process (TNP-style):

* every (embedding x, preference y) pair becomes one token [x ; y ; is_ctx];
  target tokens carry y = 0 and is_ctx = 0;
* NO positional encoding — the predictor must be permutation-invariant in
  the context set (property-tested in tests/test_property.py);
* the neural-process mask: context tokens attend to context tokens;
  target tokens attend to context tokens and themselves, never to other
  targets (no information leaks between targets — Eq. 1's conditional
  independence);
* the head reads target tokens and emits the predicted preference
  (Gaussian mean; optional learned sigma), trained with Eq. 1's NLL,
  which for fixed sigma is MSE — GPO's practice.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import GPOConfig
from repro.models.layers import dense_init, rms_norm

NEG_INF = -1e30


class GPOLayer(NamedTuple):
    ln1: jnp.ndarray
    wq: jnp.ndarray
    wk: jnp.ndarray
    wv: jnp.ndarray
    wo: jnp.ndarray
    ln2: jnp.ndarray
    w1: jnp.ndarray
    w2: jnp.ndarray


def init_gpo_params(cfg: GPOConfig, key) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 4)
    d = cfg.d_model

    def init_layer(k):
        ks = jax.random.split(k, 6)
        return GPOLayer(
            ln1=jnp.zeros((d,), dtype),
            wq=dense_init(ks[0], (d, d), dtype=dtype),
            wk=dense_init(ks[1], (d, d), dtype=dtype),
            wv=dense_init(ks[2], (d, d), dtype=dtype),
            wo=dense_init(ks[3], (d, d), dtype=dtype),
            ln2=jnp.zeros((d,), dtype),
            w1=dense_init(ks[4], (d, cfg.d_ff), dtype=dtype),
            w2=dense_init(ks[5], (cfg.d_ff, d), dtype=dtype),
        )

    layer_keys = jax.random.split(keys[0], cfg.num_layers)
    out_dim = 2 if cfg.learn_sigma else 1
    return {
        # token = [x ; y ; is_context] -> d_model
        "in_proj": dense_init(keys[1], (cfg.d_embed + 2, d), dtype=dtype),
        "layers": jax.vmap(init_layer)(layer_keys),
        "final_norm": jnp.zeros((d,), dtype),
        "head": dense_init(keys[2], (d, out_dim), dtype=dtype),
    }


def _np_mask(num_ctx: int, num_tgt: int) -> jnp.ndarray:
    """Neural-process attention mask (S, S), S = m + t.

    allowed[i, j] = True iff token i may attend token j:
      * j < m (context): always allowed,
      * j >= m: only if i == j (target self-attention).
    """
    s = num_ctx + num_tgt
    is_ctx_col = jnp.arange(s) < num_ctx
    eye = jnp.eye(s, dtype=bool)
    return jnp.broadcast_to(is_ctx_col[None, :], (s, s)) | eye


def gpo_apply(params: dict, cfg: GPOConfig, ctx_x, ctx_y, tgt_x):
    """Predict target preferences.

    ctx_x (m, d_embed), ctx_y (m,), tgt_x (t, d_embed)
    -> (mu (t,), log_sigma (t,) or None)
    Batch with vmap for multiple groups.
    """
    m, t = ctx_x.shape[0], tgt_x.shape[0]
    ctx_tok = jnp.concatenate(
        [ctx_x, ctx_y[:, None], jnp.ones((m, 1), ctx_x.dtype)], axis=-1)
    tgt_tok = jnp.concatenate(
        [tgt_x, jnp.zeros((t, 2), tgt_x.dtype)], axis=-1)
    tokens = jnp.concatenate([ctx_tok, tgt_tok], axis=0)  # (S, d_embed+2)

    x = tokens @ params["in_proj"]  # (S, d)
    h_dim = cfg.head_dim
    nh = cfg.num_heads

    def body(x, layer: GPOLayer):
        layer = GPOLayer(*layer)
        h = rms_norm(x, layer.ln1, cfg.norm_eps)
        s = h.shape[0]
        q = (h @ layer.wq).reshape(s, nh, h_dim)
        k = (h @ layer.wk).reshape(s, nh, h_dim)
        v = (h @ layer.wv).reshape(s, nh, h_dim)
        if cfg.use_pallas_attention:
            # banded flash kernel with a custom VJP (DESIGN.md §4, §8):
            # valid under jax.grad, so training (gpo_loss) and inference
            # share the same tiled path — the dense (heads, S, S) score
            # tensor below is never materialized.
            from repro.kernels import gpo_attention

            att = gpo_attention(q, k, v, num_ctx=m).reshape(s, -1)
        else:
            scores = jnp.einsum("ihd,jhd->hij", q, k) / jnp.sqrt(
                jnp.asarray(h_dim, jnp.float32))
            scores = jnp.where(_np_mask(m, t)[None], scores, NEG_INF)
            probs = jax.nn.softmax(scores.astype(jnp.float32),
                                   axis=-1).astype(v.dtype)
            att = jnp.einsum("hij,jhd->ihd", probs, v).reshape(s, -1)
        x = x + att @ layer.wo
        h2 = rms_norm(x, layer.ln2, cfg.norm_eps)
        x = x + jax.nn.gelu(h2 @ layer.w1) @ layer.w2
        return x, None

    x, _ = jax.lax.scan(body, x, params["layers"],
                        unroll=min(cfg.layer_unroll, cfg.num_layers))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    out = x[m:] @ params["head"]  # (t, 1 or 2)
    mu = out[:, 0]
    log_sigma = out[:, 1] if cfg.learn_sigma else None
    return mu, log_sigma


def gpo_loss(params: dict, cfg: GPOConfig, ctx_x, ctx_y, tgt_x, tgt_y):
    """Eq. 1: NLL of target preferences given context (Gaussian p_theta)."""
    mu, log_sigma = gpo_apply(params, cfg, ctx_x, ctx_y, tgt_x)
    if log_sigma is None:
        return jnp.mean(jnp.square(mu - tgt_y))
    inv_var = jnp.exp(-2.0 * log_sigma)
    return jnp.mean(0.5 * inv_var * jnp.square(mu - tgt_y) + log_sigma)


def predict_preferences(params: dict, cfg: GPOConfig, ctx_x, ctx_y, tgt_x,
                        num_options: int) -> jnp.ndarray:
    """Predicted preference distributions per target question.

    tgt_x is (t*A, d_embed) grouped by question (A consecutive options).
    Returns (t, A) rows on the simplex (clip-and-normalize, GPO's eval).
    """
    mu, _ = gpo_apply(params, cfg, ctx_x, ctx_y, tgt_x)
    scores = mu.reshape(-1, num_options)
    scores = jnp.clip(scores, 1e-4, None)
    return scores / scores.sum(axis=-1, keepdims=True)
