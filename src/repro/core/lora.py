"""LoRA adapters — the federated payload for backbones too large for
full-parameter FedAvg (DESIGN.md §3, "FedLoRA").

The frozen backbone is sharded FSDP-style (identical across clients, so it
may shard over the client axis); only the adapter tree diverges per client
and is FedAvg-aggregated. This matches the paper's own frozen-embedder
design and its FederatedScope-LLM / FedBiot citations.

Adapters are keyed by flat-leaf index (``{"17": {"a": ..., "b": ...}}``) so
the adapter tree is a plain pytree: it stacks per-client, vmaps, psums, and
checkpoints exactly like any parameter tree.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any

# parameter-path substrings that receive adapters (attention + mlp mats)
DEFAULT_TARGETS = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
                   "in_proj", "out_proj")


def init_lora(params: PyTree, key, rank: int = 8, alpha: float = 16.0,
              targets=DEFAULT_TARGETS) -> dict:
    """A/B factors for every targeted 2-D (or stacked 3-D) leaf.

    Stacked per-layer leaves (L, d, f) get per-layer adapters (L, d, r) /
    (L, r, f) so the layer scan stays intact.
    """
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    adapters: dict[str, dict] = {}
    for i, (path, leaf) in enumerate(flat):
        p = jax.tree_util.keystr(path)
        if not any(t in p for t in targets):
            continue
        if leaf.ndim == 2:
            d, f = leaf.shape
            batch = ()
        elif leaf.ndim == 3:  # stacked over layers
            _, d, f = leaf.shape
            batch = (leaf.shape[0],)
        else:
            continue
        k = jax.random.fold_in(key, i)
        a = (jax.random.normal(k, batch + (d, rank))
             / jnp.sqrt(d)).astype(leaf.dtype)
        b = jnp.zeros(batch + (rank, f), leaf.dtype)
        adapters[str(i)] = {"a": a, "b": b}
    return {"adapters": adapters,
            "scale": jnp.asarray(alpha / rank, jnp.float32)}


def apply_lora(params: PyTree, lora: dict) -> PyTree:
    """Effective params: W + scale * A @ B where an adapter exists."""
    flat, treedef = jax.tree.flatten(params)
    scale = lora["scale"]
    out = list(flat)
    for idx_str, ad in lora["adapters"].items():
        i = int(idx_str)
        w = flat[i]
        delta = jnp.einsum("...dr,...rf->...df",
                           ad["a"].astype(jnp.float32),
                           ad["b"].astype(jnp.float32))
        out[i] = (w.astype(jnp.float32) + scale * delta).astype(w.dtype)
    return jax.tree.unflatten(treedef, out)


def lora_param_count(lora: dict) -> int:
    return int(sum(x.size for ad in lora["adapters"].values()
                   for x in (ad["a"], ad["b"])))


def make_lora_forward(forward_fn: Callable, params: PyTree) -> Callable:
    """forward(lora, *args) with the frozen backbone closed over — the
    trainable tree (and thus the FedAvg payload) is only the adapters."""

    def fn(lora, *args, **kwargs):
        return forward_fn(apply_lora(params, lora), *args, **kwargs)

    return fn
