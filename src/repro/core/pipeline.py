"""The composable round-stage pipeline (DESIGN.md §13).

Every federated round in this repo is the same five declared stages:

    [local_train, attack, privacy, codec, aggregate]

Historically each engine hand-wired its own copy of that sequence —
``FederatedGPO.round_step`` (stacked, subsampled), its fault-aware
sibling, ``make_sharded_round``'s two bodies (shard_map), and the
backbone/LoRA trainers' three ``round_fn`` variants in
``core/trainer.py``. ``RoundPipeline`` is the one assembly point: the
engines keep what is genuinely theirs (client layout, subsampling,
fault masking, collectives placement) and delegate the stage sequence —
including every enable/disable branch — to the methods here.

Stage contract:

* **local_train** stays in the engine (it owns vmap/shard_map layout
  and the optimizer carry). The pipeline's contribution is
  ``attacked_flags`` — the per-row poison mask a data-level attack
  (``kind="label_flip"``) feeds into ``_make_local_train``.
* **attack** (``attack_rows``) corrupts Byzantine rows of the raw flat
  (rows, P) delta matrix — before the privacy release, because a
  malicious client controls what it ships, not what the server does
  with it. Benign default: the stage is the Python-level identity.
* **privacy** then **codec** (``release_rows`` and the fused forms
  inside ``reduce_apply``/``sharded_delta``): DP clip+noise is the
  release point, the int8/top-k codec is post-processing of the
  released value (ε untouched), EF residual is carry state owned by
  the engine.
* **aggregate**: server-side ``norm_bound`` row clipping (the defense
  composable with every linear strategy) followed by the configured
  ``ServerAggregator`` reduce + apply. The fault-aware engines blend
  fresh/buffered rows first and call ``masked_reduce``.

Carry ownership: the pipeline is STATELESS config. Engines own and
thread every carry (opt states, server state, EF residual, fault
state); pipeline methods take them as explicit arguments and return the
updated values, which is what lets the same object serve a
``lax.scan`` body, a per-round jit, and a shard_map body.

Bit-equality discipline: with the attack stage off and
``norm_bound == 0`` every method below reproduces the pre-§13 engines'
dispatch VERBATIM (same ops, same order, same collectives) — the
attack-off traces are byte-pinned by tests/test_adversary.py and the
§9/§10/§11 pins keep riding. Enabling an attack or a norm bound
switches (statically) to a row-structured path that materializes the
per-client released rows between the stages.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (
    AdversaryConfig,
    CompressionConfig,
    HierarchyConfig,
    PrivacyConfig,
)
from repro.core import adversary as byz
from repro.core import availability as av
from repro.core import compression as cx
from repro.core import privacy as dp
from repro.core.aggregation import ServerAggregator
from repro.core.fedavg import fedavg_allreduce
from repro.kernels import fedavg_reduce
from repro.utils.pytree import (
    tree_ravel_clients,
    tree_unflatten_from_vector,
)

PyTree = Any

# the declared stage sequence every engine assembles (DESIGN.md §13)
STAGE_NAMES = ("local_train", "attack", "privacy", "codec", "aggregate")


@dataclass(frozen=True)
class RoundPipeline:
    """Stateless assembly of the five round stages for one FedConfig.

    ``num_clients`` is the FULL training population (attacker schedules
    draw over it; subsampled/sharded rows index into it via ``gids``).
    ``None`` means "rows are the population" — the backbone/LoRA
    trainers, which run full participation with no global id space.
    """

    adversary: AdversaryConfig
    privacy: PrivacyConfig
    compression: CompressionConfig
    agg: ServerAggregator
    num_clients: Optional[int] = None
    use_pallas: bool = False
    # two-level client→edge→server topology for the aggregate stage
    # (DESIGN.md §14); the default (num_edges=1) is statically disabled
    hierarchy: HierarchyConfig = HierarchyConfig()

    # -- static structure --------------------------------------------------
    @property
    def attack_delta(self) -> bool:
        """Delta-level attack configured (stage 2 active on the wire)."""
        return self.adversary.enabled and not self.adversary.data_level

    @property
    def flip_data(self) -> bool:
        """Data-level poisoning configured (stage 2 rides local_train)."""
        return self.adversary.enabled and self.adversary.data_level

    @property
    def norm_bound(self) -> float:
        return self.agg.cfg.norm_bound

    @property
    def restructured(self) -> bool:
        """True when the round must materialize per-client released rows
        (an active delta attack, server-side norm bounding, or the §14
        edge hierarchy — whose edge pre-reduce needs the rows); False
        keeps the pre-§13 fused dispatch byte-for-byte."""
        return (self.attack_delta or self.norm_bound > 0.0
                or self.hierarchy.enabled)

    def stages(self) -> tuple:
        """The declared ``[local_train, attack, privacy, codec,
        aggregate]`` list as (name, enabled) pairs — what every engine
        assembles (tests assert the three engines agree)."""
        return (
            ("local_train", True),
            ("attack", self.adversary.enabled),
            ("privacy", self.privacy.enabled),
            ("codec", self.compression.enabled),
            ("aggregate", True),
        )

    # -- attack stage ------------------------------------------------------
    def fold_key(self, round_key):
        """Round's Byzantine key (None when the adversary is off, so the
        benign trace never folds an extra key)."""
        if not self.adversary.enabled:
            return None
        return byz.fold_byz_key(round_key)

    def _mask(self, byz_key, rows: int):
        pop = self.num_clients if self.num_clients else rows
        return byz.attacker_mask(byz_key, pop,
                                 self.adversary.num_attackers)

    def attacked_flags(self, byz_key, gids=None, *, rows: int = 0):
        """(rows,) bool poison mask for the data-level attack, sliced to
        this engine's rows; None when no label flip is configured (the
        local_train signature stays 4-ary and traces unchanged)."""
        if not self.flip_data:
            return None
        mask = self._mask(byz_key, rows if gids is None else 0)
        if gids is None:
            return mask
        return mask[gids]

    def attack_rows(self, vecs, byz_key, gids=None, *, axes=None):
        """Stage 2 on a flat (rows, P) delta matrix. ``gids`` maps rows
        to global client ids (None: rows ARE the population). ``axes``:
        client mesh axes when the rows are a shard — ALIE's honest
        moments then psum across shards so colluding attackers agree."""
        if not self.attack_delta:
            return vecs
        mask_full = self._mask(byz_key, vecs.shape[0])
        if gids is None:
            gids = jnp.arange(vecs.shape[0], dtype=jnp.int32)
            mask = mask_full
        else:
            mask = mask_full[gids]
        stats = None
        if axes is not None and self.adversary.kind == "alie":
            stats = byz.honest_stats_sharded(vecs, mask, axes)
        return byz.apply_attack(vecs, mask, self.adversary, byz_key,
                                gids, stats=stats)

    # -- privacy + codec (per-row release, fault engines) ------------------
    def release_rows(self, vecs, keys, resid, *, byz_key=None, gids=None,
                     axes=None):
        """attack → privacy → codec on per-client rows, NO reduction:
        the fault-aware engines buffer/mask individual wire values, so
        a Byzantine row that also straggles is buffered CORRUPTED —
        exactly the §11 composition. Attack-off: verbatim
        ``cx.release_flat``."""
        vecs = self.attack_rows(vecs, byz_key, gids, axes=axes)
        return cx.release_flat(vecs, keys, self.privacy, self.compression,
                               resid)

    # -- aggregate stage helpers -------------------------------------------
    def _bound_rows(self, rel):
        """Server-side norm bounding (AggConfig.norm_bound): clip what
        the server RECEIVED, row by row, before any reduction. Static
        no-op at 0.0."""
        if self.norm_bound > 0.0:
            return byz.norm_clip_rows(rel, self.norm_bound)
        return rel

    def hier_reduce_flat(self, rel, weights):
        """Aggregate-stage reduce on materialized (rows, P) released
        rows: the flat ``agg.reduce_flat`` at E=1, the two-level
        client→edge→server reduce otherwise (DESIGN.md §14). Edge e owns
        the contiguous row block [e·C/E, (e+1)·C/E); each edge runs the
        configured rule over its OWN rows (the robust rules' trim depth
        shrinks with the C/E edge population — their ``reduce_flat``
        derives k from the input shape), then the linear family sums the
        edge partials (the same weighted moment, reassociated) while the
        robust family re-runs the rule over the E candidates weighted by
        edge mass."""
        E = self.hierarchy.num_edges
        if E <= 1:
            return self.agg.reduce_flat(rel, weights)
        c = rel.shape[0]
        v = rel.reshape(E, c // E, rel.shape[1])
        w = weights.astype(jnp.float32).reshape(E, c // E)
        if self.agg.linear:
            # linear reduce_flat is the weighted flat sum, so the edge
            # partials (computed against the globally-normalized
            # weights) just add up to the server update
            return jnp.sum(jnp.stack(
                [self.agg.reduce_flat(v[e], w[e]) for e in range(E)]),
                axis=0)
        # robust rules with a surviving-weight renormalization are
        # scale-invariant in the weights, but the k=0 trimmed-mean
        # degenerate case is a plain weighted sum that assumes its
        # weights total 1 — so each edge reduces against WITHIN-edge
        # normalized weights (a proper edge mean either way) and the
        # server rule weighs the candidates by edge mass
        mass = jnp.sum(w, axis=1)  # (E,)
        wn = w / jnp.maximum(mass, 1e-12)[:, None]
        edge_rows = jnp.stack(
            [self.agg.reduce_flat(v[e], wn[e]) for e in range(E)])
        return self.agg.reduce_flat(edge_rows, mass)

    def _two_hop_reduce(self, rel, weights, axes):
        """§14 robust reduce for the sharded engine on an ('edge', …)
        mesh: hop 1 all-gathers released rows WITHIN the edge
        (``axes[1:]``) and every edge pre-reduces its own C/E rows to one
        candidate (replicated in-edge); hop 2 all-gathers only the E
        candidate rows across the edge axis (``axes[0]``) — carrying the
        §10 int8 wire layout when the codec is on, with deterministic
        round-to-nearest (the candidate is an edge-level value with no
        per-client rounding key; it is identical on every in-edge
        device) — and the server rule runs replicated over (E, P). The
        dominant collective shrinks from O(C·P) cross-fleet to O(E·P)
        cross-edge."""
        agg, comp = self.agg, self.compression
        edge_ax, intra = axes[0], axes[1:]
        edge_vecs = jax.lax.all_gather(rel, intra, axis=0, tiled=True)
        edge_w = jax.lax.all_gather(weights, intra, axis=0, tiled=True)
        # within-edge normalized, as in hier_reduce_flat: the k=0
        # trimmed-mean degenerate case is a weights-sum-to-1 linear sum
        mass = jnp.sum(edge_w)
        cand = agg.reduce_flat(
            edge_vecs, edge_w / jnp.maximum(mass, 1e-12))[None, :]
        mass = mass[None]  # (1,)
        if comp.enabled and comp.kind == "int8":
            q, scales = cx.quantize_int8(cand, uniform=None)
            all_q = jax.lax.all_gather(q, edge_ax, axis=0, tiled=True)
            all_s = jax.lax.all_gather(scales, edge_ax, axis=0,
                                       tiled=True)
            all_cand = cx.dequantize_int8(all_q, all_s)
        else:
            all_cand = jax.lax.all_gather(cand, edge_ax, axis=0,
                                          tiled=True)
        all_mass = jax.lax.all_gather(mass, edge_ax, axis=0, tiled=True)
        return agg.reduce_flat(all_cand, all_mass)

    # -- full stacked tail: [attack →] privacy → codec → aggregate ---------
    def reduce_apply(self, server_state, global_params, deltas, weights,
                     keys, *, losses, idx, resid, byz_key=None):
        """Round tail for client-stacked engines (the vmapped GPO round
        and the backbone/LoRA trainers): takes the raw local-train delta
        trees, returns (new_global, new_server_state, new_resid).
        ``idx`` are the participants' global ids (None = full
        participation); ``resid`` is the participants' EF residual slice
        (None without error feedback)."""
        agg, priv, comp = self.agg, self.privacy, self.compression
        if not self.restructured:
            # pre-§13 dispatch, byte-for-byte (the §9/§10 pins ride it)
            if comp.enabled:
                w_eff = agg.weigh(server_state, weights, idx)
                delta_vec, new_r = cx.transport_delta_flat(
                    tree_ravel_clients(deltas), w_eff, keys, priv, comp,
                    agg, resid, use_pallas=self.use_pallas)
                delta = tree_unflatten_from_vector(delta_vec,
                                                   global_params)
                new_global, server_state = agg.apply(
                    server_state, global_params, delta, losses=losses,
                    idx=idx)
                return new_global, server_state, new_r
            if priv.enabled:
                w_eff = agg.weigh(server_state, weights, idx)
                delta_vec = dp.private_delta_flat(
                    tree_ravel_clients(deltas), w_eff, keys, priv, agg,
                    use_pallas=self.use_pallas)
                delta = tree_unflatten_from_vector(delta_vec,
                                                   global_params)
                new_global, server_state = agg.apply(
                    server_state, global_params, delta, losses=losses,
                    idx=idx)
                return new_global, server_state, resid
            new_global, server_state = agg.step(
                server_state, global_params, deltas, weights,
                losses=losses, idx=idx)
            return new_global, server_state, resid
        # restructured: materialize attacked/released rows, bound, reduce
        w_eff = agg.weigh(server_state, weights, idx)
        vecs = self.attack_rows(tree_ravel_clients(deltas), byz_key, idx)
        rel, new_r = cx.release_flat(vecs, keys, priv, comp, resid)
        rel = self._bound_rows(rel)
        delta = tree_unflatten_from_vector(
            self.hier_reduce_flat(rel, w_eff), global_params)
        new_global, server_state = agg.apply(
            server_state, global_params, delta, losses=losses, idx=idx)
        return new_global, server_state, new_r

    # -- sharded middle: [attack →] privacy → codec → reduce collective ----
    def sharded_delta(self, deltas, weights, keys, global_prev, resid,
                      axes, *, byz_key=None, gids=None):
        """Round middle for the shard_map engine: local (C_local, …)
        delta trees in, (reduced delta tree, new shard-local residual)
        out. Linear family ends in ONE weighted psum; robust family
        all-gathers rows. Attack-off + norm_bound 0: verbatim pre-§13
        branches (collective schedule byte-identical — dryrun/hlo_cost
        verified)."""
        agg, priv, comp = self.agg, self.privacy, self.compression
        ef = comp.enabled and comp.error_feedback
        if not self.restructured:
            new_resid = None
            if comp.enabled:
                vecs = tree_ravel_clients(deltas)
                if agg.linear:
                    local_vec, new_resid = cx.transport_delta_flat(
                        vecs, weights, keys, priv, comp, agg, resid,
                        use_pallas=self.use_pallas)
                    delta = tree_unflatten_from_vector(
                        jax.lax.psum(local_vec, axes), global_prev)
                else:
                    x = (dp.privatize_flat(vecs, keys, priv)
                         if priv.enabled else vecs.astype(jnp.float32))
                    u = x + resid if ef else x
                    if comp.kind == "int8":
                        uniform = (cx.client_uniform(keys, u.shape)
                                   if comp.stochastic else None)
                        q, scales = cx.quantize_int8(u, uniform=uniform)
                        t_local = cx.dequantize_int8(q, scales)
                        all_q = jax.lax.all_gather(q, axes, axis=0,
                                                   tiled=True)
                        all_s = jax.lax.all_gather(scales, axes, axis=0,
                                                   tiled=True)
                        all_vecs = cx.dequantize_int8(all_q, all_s)
                    else:  # topk: dense f32 layout of the sparse shard
                        t_local, _ = cx.sparsify_topk(u, comp.topk_frac)
                        all_vecs = jax.lax.all_gather(t_local, axes,
                                                      axis=0, tiled=True)
                    new_resid = u - t_local if ef else None
                    all_w = jax.lax.all_gather(weights, axes, axis=0,
                                               tiled=True)
                    delta = tree_unflatten_from_vector(
                        agg.reduce_flat(all_vecs, all_w), global_prev)
            elif priv.enabled:
                vecs = tree_ravel_clients(deltas)
                if agg.linear:
                    local_vec = dp.clip_noise_reduce(
                        vecs, weights, keys, priv,
                        use_pallas=self.use_pallas)
                    delta = tree_unflatten_from_vector(
                        jax.lax.psum(local_vec, axes), global_prev)
                else:
                    pvecs = dp.privatize_flat(vecs, keys, priv)
                    all_vecs = jax.lax.all_gather(pvecs, axes, axis=0,
                                                  tiled=True)
                    all_w = jax.lax.all_gather(weights, axes, axis=0,
                                               tiled=True)
                    delta = tree_unflatten_from_vector(
                        agg.reduce_flat(all_vecs, all_w), global_prev)
            elif agg.linear:
                if self.use_pallas:
                    vecs = tree_ravel_clients(deltas)
                    local_vec = fedavg_reduce(
                        vecs, weights.astype(jnp.float32))
                    delta = tree_unflatten_from_vector(
                        jax.lax.psum(local_vec, axes), global_prev)
                else:
                    local_weighted = jax.tree.map(
                        lambda x: jnp.sum(
                            x.astype(jnp.float32)
                            * weights.reshape(
                                (-1,) + (1,) * (x.ndim - 1)),
                            axis=0),
                        deltas)
                    delta = fedavg_allreduce(
                        local_weighted, jnp.asarray(1.0, jnp.float32),
                        axes)
            else:
                vecs = tree_ravel_clients(deltas)
                all_vecs = jax.lax.all_gather(vecs, axes, axis=0,
                                              tiled=True)
                all_w = jax.lax.all_gather(weights, axes, axis=0,
                                           tiled=True)
                delta = tree_unflatten_from_vector(
                    agg.reduce_flat(all_vecs, all_w), global_prev)
            return delta, new_resid
        # restructured: attack + release stay shard-local (the corrupt
        # rows cross the wire like honest ones); the norm bound clips
        # rows BEFORE the reduce, so the linear family keeps its ONE
        # (P,) f32 psum — byte-identical collective schedule even with
        # the defense engaged (the robust family gathers f32 rows,
        # forgoing the int8 wire layout under an active attack).
        vecs = self.attack_rows(tree_ravel_clients(deltas), byz_key,
                                gids, axes=axes)
        rel, new_resid = cx.release_flat(vecs, keys, priv, comp, resid)
        rel = self._bound_rows(rel)
        if agg.linear:
            # ONE weighted psum over ALL client axes — on an ('edge',
            # 'data') mesh this IS the composed two-hop partial-sum
            # schedule (§14: the linear family's bytes are unchanged by
            # the hierarchy)
            delta_vec = jax.lax.psum(agg.reduce_flat(rel, weights), axes)
        elif self.hierarchy.enabled and len(axes) > 1:
            delta_vec = self._two_hop_reduce(rel, weights, axes)
        else:
            all_vecs = jax.lax.all_gather(rel, axes, axis=0, tiled=True)
            all_w = jax.lax.all_gather(weights, axes, axis=0, tiled=True)
            delta_vec = agg.reduce_flat(all_vecs, all_w)
        return (tree_unflatten_from_vector(delta_vec, global_prev),
                new_resid if ef else None)

    # -- aggregate under fault masking (§11 ∘ §13) -------------------------
    def masked_reduce(self, contrib, w_c, mask_c, *, trim_frac):
        """Degraded-mode reduce on the FULL (C, P) blended contribution
        matrix (fresh + buffered rows): linear renormalizes over
        survivors; median/trimmed_mean shrink their trim depth with the
        survivor count; the §13 defenses are mask-tolerant through their
        weights (weight-0 rows are excluded from selection). The norm
        bound clips the blended rows — what the server is about to
        absorb — first."""
        agg = self.agg
        contrib = self._bound_rows(contrib)
        if agg.linear:
            wn = av.masked_mean_weights(w_c, mask_c)
            return agg.reduce_flat(contrib, wn)
        if agg.name in ("median", "trimmed_mean"):
            return av.masked_robust_reduce_flat(
                contrib, w_c, mask_c, name=agg.name, trim_frac=trim_frac)
        return agg.reduce_flat(contrib, jnp.where(mask_c, w_c, 0.0))

    def masked_reduce_sharded(self, contrib_l, w_c, mask_c, gids, axes, *,
                              trim_frac):
        """``masked_reduce`` for the sharded fault round: linear keeps
        the shard-local partial sum + ONE psum; robust/defense families
        all-gather the blended rows and reduce replicated."""
        agg = self.agg
        contrib_l = self._bound_rows(contrib_l)
        if agg.linear:
            wn_l = av.masked_mean_weights(w_c, mask_c)[gids]
            if self.use_pallas:
                local_vec = fedavg_reduce(contrib_l, wn_l)
            else:
                local_vec = jnp.einsum("c,cp->p", wn_l, contrib_l)
            return jax.lax.psum(local_vec, axes)
        all_vecs = jax.lax.all_gather(contrib_l, axes, axis=0, tiled=True)
        if agg.name in ("median", "trimmed_mean"):
            return av.masked_robust_reduce_flat(
                all_vecs, w_c, mask_c, name=agg.name, trim_frac=trim_frac)
        return agg.reduce_flat(all_vecs, jnp.where(mask_c, w_c, 0.0))


def make_pipeline(fed_cfg, *, agg: ServerAggregator,
                  num_clients: Optional[int] = None) -> RoundPipeline:
    """Assemble the round pipeline from a FedConfig + built aggregator
    (the one call every engine makes)."""
    return RoundPipeline(
        adversary=fed_cfg.adversary, privacy=fed_cfg.privacy,
        compression=fed_cfg.compression, agg=agg,
        num_clients=num_clients,
        use_pallas=fed_cfg.use_pallas_aggregation,
        hierarchy=fed_cfg.hierarchy)
