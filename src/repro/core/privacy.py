"""Differentially-private client-delta pipeline (DESIGN.md §9).

The pipeline sits on the client→server transport, between local training
and the ``ServerAggregator``: every client's flattened parameter delta
d_g is (1) L2-clipped to the sensitivity bound S = ``clip_norm`` and
(2) perturbed with per-client Gaussian noise of std σ = z·S
(z = ``noise_multiplier``):

    d̃_g = d_g · min(1, S / ‖d_g‖₂) + σ·ε_g,   ε_g ~ N(0, I)

Because the privatized (C, P) matrix — not any reduction of it — is what
reaches the aggregator, the pipeline composes with every registry
strategy: the linear family weighted-sums the d̃_g (fused with the clip
in the Pallas ``agg_clip_reduce`` kernel under
``use_pallas_aggregation``), and the robust family rank-trims them.
Per-client noising is the local/distributed-DP release model, which is
exactly what makes the guarantee aggregator-agnostic: whatever the
server computes downstream is post-processing.

**Noise keys.** Each client's noise key is derived by folding a fixed
tag into the SAME per-client key its local training consumed
(``client_noise_keys``). Both ``FederatedGPO`` drivers and
``make_sharded_round`` therefore produce bit-identical noise for the
same round keys — the scan carry already threads the round RNG, so no
second RNG chain exists to fall out of sync, and determinism under
subsampling + noise is pinned by tests/test_privacy.py.

**Accounting.** ``RdpAccountant`` tracks the sampled Gaussian mechanism
in Rényi DP at integer orders (Mironov et al. 2019): per round the RDP
at order α is log A(α)/(α−1) with

    A(α) = Σ_{i=0..α} C(α,i) qⁱ (1−q)^{α−i} exp((i²−i)/(2z²))

(q the client sampling rate; q = 1 collapses to the Gaussian-mechanism
α/(2z²)). RDP composes additively over rounds and converts to (ε, δ)
via ε = min_α [ α-RDP·rounds + log(1/δ)/(α−1) ]. Fixed-size subsampling
without replacement (``FedConfig.batch_groups``) is accounted with the
Poisson-sampling bound at the same rate — the standard moments-
accountant approximation. Per-round local losses shipped to ``adaptive``
aggregation are NOT privatized (noted in DESIGN.md §9).
"""
from __future__ import annotations

import math
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PrivacyConfig
from repro.kernels import agg_clip_reduce
# the kernel's norm floor is the contract constant: a zero delta gets
# scale min(1, S/1e-12) = 1 (clipping never manufactures a direction).
# Imported, not redefined, so jnp path and kernel cannot drift; the
# ref.py oracle spells out the same literal by design (oracles stay
# import-independent from the optimized paths).
from repro.kernels.agg_reduce import _NORM_FLOOR

PyTree = Any

# fold_in tag deriving a client's noise key from its local-training key;
# any fixed constant works — it only has to differ from the fold_in /
# split indices the training path consumes.
_NOISE_TAG = 0x5A11CE


# ---------------------------------------------------------------------------
# clip + noise on the flattened (C, P) client-delta matrix
# ---------------------------------------------------------------------------
def clip_scales(vecs: jnp.ndarray, clip_norm: float) -> jnp.ndarray:
    """(C, P) -> (C,) per-client scale min(1, S/‖d_c‖₂)."""
    x = vecs.astype(jnp.float32)
    norms = jnp.sqrt(jnp.sum(jnp.square(x), axis=1))
    return jnp.minimum(1.0, clip_norm / jnp.maximum(norms, _NORM_FLOOR))


def client_noise_keys(keys: jnp.ndarray) -> jnp.ndarray:
    """Per-client noise keys derived from the per-client training keys."""
    return jax.vmap(lambda k: jax.random.fold_in(k, _NOISE_TAG))(keys)


def client_noise(keys: jnp.ndarray, shape: tuple, sigma: float
                 ) -> jnp.ndarray:
    """σ-scaled per-client Gaussian noise matrix (C, P); ``keys`` are the
    per-client TRAINING keys (the noise keys are folded from them)."""
    nkeys = client_noise_keys(keys)
    return sigma * jax.vmap(
        lambda k: jax.random.normal(k, shape[1:], jnp.float32))(nkeys)


def privatize_flat(vecs: jnp.ndarray, keys: jnp.ndarray,
                   privacy: PrivacyConfig) -> jnp.ndarray:
    """Clip + noise the flat (C, P) delta matrix — the aggregator-
    agnostic release; the robust strategies rank-trim this output."""
    x = vecs.astype(jnp.float32)
    x = x * clip_scales(x, privacy.clip_norm)[:, None]
    if privacy.noise_multiplier > 0.0:
        x = x + client_noise(keys, x.shape, privacy.sigma)
    return x


def clip_noise_reduce(vecs: jnp.ndarray, weights: jnp.ndarray,
                      keys: jnp.ndarray, privacy: PrivacyConfig, *,
                      use_pallas: bool = False) -> jnp.ndarray:
    """clip → noise → weighted sum over the client axis: the linear-
    strategy hot path. With ``use_pallas`` the per-client norms, the
    scale-to-clip, the noise add and the weighted accumulate run in ONE
    fused kernel launch (``agg_clip_reduce``); the jnp path is the same
    math through ``privatize_flat`` (oracle: kernels/ref.py)."""
    if use_pallas:
        noise = (client_noise(keys, vecs.shape, privacy.sigma)
                 if privacy.noise_multiplier > 0.0 else None)
        return agg_clip_reduce(vecs, weights.astype(jnp.float32),
                               clip=privacy.clip_norm, noise=noise)
    pvecs = privatize_flat(vecs, keys, privacy)
    return jnp.einsum("c,cp->p", weights.astype(jnp.float32), pvecs)


def private_delta_flat(vecs: jnp.ndarray, weights: jnp.ndarray,
                       keys: jnp.ndarray, privacy: PrivacyConfig, agg, *,
                       use_pallas: bool = False) -> jnp.ndarray:
    """The full DP release + client-axis reduction for engines that hold
    every client locally (the stacked GPO drivers and the backbone/LoRA
    trainers): linear strategies fuse clip/noise into the weighted sum,
    robust strategies rank-trim the privatized matrix. The sharded
    engine interleaves its collectives with these same two pieces
    (clip_noise_reduce before the psum / privatize_flat before the
    all-gather) and so cannot call this helper."""
    if agg.linear:
        return clip_noise_reduce(vecs, weights, keys, privacy,
                                 use_pallas=use_pallas)
    return agg.reduce_flat(privatize_flat(vecs, keys, privacy), weights)


# ---------------------------------------------------------------------------
# Rényi-DP moments accountant (host-side; pure numpy/math)
# ---------------------------------------------------------------------------
def _log_binom(n: int, k: int) -> float:
    return (math.lgamma(n + 1) - math.lgamma(k + 1)
            - math.lgamma(n - k + 1))


def rdp_sampled_gaussian(q: float, noise_multiplier: float,
                         orders: Sequence[int]) -> np.ndarray:
    """Per-step RDP of the sampled Gaussian mechanism at integer orders
    (Mironov et al. 2019, Thm. 5 / the tensorflow-privacy integer-α sum).
    ``q`` is the sampling rate, ``noise_multiplier`` the ratio z = σ/S.
    """
    z = float(noise_multiplier)
    if z <= 0.0:
        return np.full(len(orders), np.inf)
    if not 0.0 < q <= 1.0:
        raise ValueError(f"sampling rate q={q} must lie in (0, 1]")
    out = np.empty(len(orders), np.float64)
    for j, alpha in enumerate(orders):
        alpha = int(alpha)
        if alpha < 2:
            raise ValueError(f"RDP orders must be integers >= 2: {alpha}")
        if q == 1.0:
            out[j] = alpha / (2.0 * z * z)
            continue
        # log A(alpha) = logsumexp_i [ log C(a,i) + i log q
        #   + (a-i) log(1-q) + (i^2 - i) / (2 z^2) ]
        terms = [
            _log_binom(alpha, i) + i * math.log(q)
            + (alpha - i) * math.log1p(-q)
            + (i * i - i) / (2.0 * z * z)
            for i in range(alpha + 1)
        ]
        out[j] = np.logaddexp.reduce(terms) / (alpha - 1)
    return out


def eps_from_rdp(rdp: np.ndarray, orders: Sequence[int],
                 delta: float) -> float:
    """Classic RDP→(ε, δ) conversion: min_α [ RDP(α) + log(1/δ)/(α−1) ]."""
    orders = np.asarray(orders, np.float64)
    eps = np.asarray(rdp, np.float64) + math.log(1.0 / delta) / (orders - 1)
    return float(np.min(eps))


class RdpAccountant:
    """Moments accountant for the per-round sampled Gaussian mechanism.

    The per-step RDP vector is constant (fixed q and z), so composition
    over ``steps`` rounds is a scalar multiply and ``epsilon`` is O(|α|)
    on the host — cheap enough to record into ``History.round_eps``
    every round.
    """

    def __init__(self, noise_multiplier: float, sampling_rate: float,
                 target_delta: float = 1e-5,
                 orders: Optional[Sequence[int]] = None):
        self.orders = tuple(orders or PrivacyConfig().accountant_orders)
        self.noise_multiplier = float(noise_multiplier)
        self.sampling_rate = float(sampling_rate)
        self.target_delta = float(target_delta)
        self._per_step = rdp_sampled_gaussian(
            self.sampling_rate, self.noise_multiplier, self.orders)

    def epsilon(self, steps: int) -> float:
        """(ε at ``target_delta``) after ``steps`` composed rounds."""
        if steps <= 0:
            return 0.0
        if not np.all(np.isfinite(self._per_step)):
            return float("inf")
        return eps_from_rdp(steps * self._per_step, self.orders,
                            self.target_delta)


def make_accountant(privacy: PrivacyConfig,
                    sampling_rate: float) -> Optional[RdpAccountant]:
    """Accountant for an enabled, noised config; None otherwise (clip-
    only runs carry no finite ε — callers report inf)."""
    if not privacy.enabled or privacy.noise_multiplier <= 0.0:
        return None
    return RdpAccountant(privacy.noise_multiplier, sampling_rate,
                         privacy.target_delta, privacy.accountant_orders)


_ADAPTIVE_PRIVACY_MSG = (
    "agg.name='adaptive' reweighs groups by their RAW per-round local "
    "losses, which are shipped to the server UN-privatized (DESIGN.md "
    "§9): with noise_multiplier={z} > 0 the reported RDP epsilon does "
    "NOT cover the loss side-channel. Use a non-adaptive strategy for "
    "a DP run, or set FedConfig.strict_privacy=False to proceed with "
    "this warning.")


def check_adaptive_privacy(fed_cfg) -> None:
    """Guard the adaptive-aggregation + DP-noise foot-gun: the loss EMAs
    that drive the adaptive weights leak un-noised training losses, so a
    run claiming an (ε, δ) from the accountant would over-claim. Warns
    loudly by default; ``FedConfig.strict_privacy=True`` hard-errors."""
    if (fed_cfg.agg.name == "adaptive" and fed_cfg.privacy.enabled
            and fed_cfg.privacy.noise_multiplier > 0.0):
        msg = _ADAPTIVE_PRIVACY_MSG.format(
            z=fed_cfg.privacy.noise_multiplier)
        if fed_cfg.strict_privacy:
            raise ValueError(msg)
        import warnings
        warnings.warn(msg, UserWarning, stacklevel=2)
