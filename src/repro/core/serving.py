"""Multi-tenant serving engine for the GPO preference predictor
(DESIGN.md §12).

The trained predictor is the paper's product: a group-conditioned reward
model answering "what would group g answer to question q?" under real
query load. This module turns the single-tenant, synchronous
``predict_preferences`` call into a serving engine:

* **Queue + admission** — ``submit`` appends to a FIFO queue bounded by
  ``ServeConfig.max_queue``; over-capacity submissions are *rejected*
  (backpressure) instead of growing tail latency without bound.
* **Continuous batching over ragged lengths** — each engine ``step``
  fuses up to ``max_batch`` head-of-line requests into one decode
  dispatch. Requests carry ragged (context, target) lengths; the batcher
  pads them to a small static *bucket* set (``ctx_buckets`` /
  ``tgt_buckets`` / ``batch_buckets``) so the jitted shape family stays
  compile-cached — the scheduler never reorders (FIFO preserves
  arrival-order fairness and makes batch composition a pure function of
  the queue contents, which is what the determinism test pins).
  Newly-arrived requests join the next dispatch as soon as the current
  one retires — continuous batching degenerate to the one-shot case of
  a model whose whole decode is a single forward pass.
* **Prefix cache** — ``gpo_prefill`` output (per-layer context K/V) is
  cached under the request's ``prefix_key`` in an LRU of
  ``cache_entries`` entries. Repeated ICL prefixes across requests —
  the common serving shape: many queries conditioned on the same
  group's survey context — skip prefill entirely. The neural-process
  mask makes the context encoding exactly independent of targets, so a
  hit is *bit-equal* to the cold path (same cached arrays in, same
  jitted decode) and strictly cheaper: prefill is the O(M²) half.
* **int8 inference** — ``quantize_gpo_params`` rewrites the dense
  weights as ``QuantizedLinear`` leaves at load time (per-output-channel
  symmetric scales, the §10 contract) and ``core/gpo.py::_mm`` routes
  them through the fused int8 matmul kernel.

Everything timing-related is measurement only: scheduling decisions
depend exclusively on queue order, so a fixed arrival trace yields a
fixed batch composition on any machine.
"""
from __future__ import annotations

import functools
import time
from collections import OrderedDict, deque
from dataclasses import dataclass
from typing import Any, Hashable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GPOConfig, ServeConfig
from repro.core.gpo import GPOLayer, GPOPrefix, gpo_decode, gpo_prefill
from repro.kernels import quantize_linear

PyTree = Any

# GPOLayer fields that are dense matmul weights (quantized for int8
# serving); the ln1/ln2 RMS-norm scales stay f32.
_QUANT_FIELDS = ("wq", "wk", "wv", "wo", "w1", "w2")


def quantize_gpo_params(params: PyTree) -> PyTree:
    """Load-time int8 quantization of the GPO predictor's dense weights
    (DESIGN.md §12): ``in_proj``, ``head``, and every per-layer matmul
    become ``QuantizedLinear`` leaves (the stacked-layer leading axis is
    carried into per-layer scales); norm scales stay f32. The returned
    tree feeds every ``gpo_*`` entry point unchanged — ``_mm`` dispatches
    on the leaf type."""
    layers = params["layers"]
    qlayers = GPOLayer(**{
        f: (quantize_linear(getattr(layers, f)) if f in _QUANT_FIELDS
            else getattr(layers, f))
        for f in GPOLayer._fields})
    return {
        "in_proj": quantize_linear(params["in_proj"]),
        "layers": qlayers,
        "final_norm": params["final_norm"],
        "head": quantize_linear(params["head"]),
    }


# ---------------------------------------------------------------------------
# request / result / batch-record types
# ---------------------------------------------------------------------------
@dataclass
class Request:
    """One preference query: predict a group's answer distributions for
    ``tgt_x`` given the (ctx_x, ctx_y) in-context examples.
    ``prefix_key`` identifies the shared context for prefix caching —
    two requests with the same key MUST carry identical (ctx_x, ctx_y);
    None disables caching for this request. ``arrival`` is seconds on
    the engine clock (load-generation metadata, not a scheduling
    input). ``deadline`` is an absolute engine-clock time past which the
    result is worthless to the caller (an RLHF scorer that already timed
    out): the scheduler drops the request instead of spending a decode
    slot on it, counted in ``ServeStats.expired``. None means no
    deadline."""

    rid: int
    ctx_x: np.ndarray  # (m*A, d_embed)
    ctx_y: np.ndarray  # (m*A,)
    tgt_x: np.ndarray  # (t*A, d_embed)
    prefix_key: Optional[Hashable] = None
    arrival: float = 0.0
    deadline: Optional[float] = None  # absolute engine-clock seconds
    meta: Optional[dict] = None  # caller-owned (e.g. group/question ids)


@dataclass
class Completed:
    rid: int
    pred: np.ndarray  # (t, A) rows on the simplex
    cache_hit: bool
    arrival: float
    finished: float
    batch_index: int

    @property
    def latency(self) -> float:
        return self.finished - self.arrival


@dataclass(frozen=True)
class BatchRecord:
    """Composition of one decode dispatch — the deterministic-scheduler
    contract surface (tests pin these for a fixed arrival trace)."""

    rids: Tuple[int, ...]
    batch_pad: int  # padded batch size (a batch_buckets entry)
    ctx_bucket: int
    tgt_bucket: int
    hits: Tuple[bool, ...]


@dataclass
class ServeStats:
    submitted: int = 0
    rejected: int = 0
    completed: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    prefills: int = 0  # unique contexts actually prefilled
    evictions: int = 0
    expired: int = 0  # dropped unserved: deadline passed while queued


# ---------------------------------------------------------------------------
# jitted batch kernels (params passed positionally: jit caches per shape)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("cfg",))
def _prefill_batch(params, cfg: GPOConfig, ctx_x, ctx_y, ctx_len):
    """(B, M, d), (B, M), (B,) -> stacked GPOPrefix with (B, L, M, nh, hd)
    K/V."""
    return jax.vmap(
        lambda cx, cy, cl: gpo_prefill(params, cfg, cx, cy, ctx_len=cl)
    )(ctx_x, ctx_y, ctx_len)


@functools.partial(jax.jit, static_argnames=("cfg", "num_options"))
def _decode_batch(params, cfg: GPOConfig, num_options: int,
                  pk, pv, ctx_len, tgt_x):
    """(B, L, M, nh, hd) x2, (B,), (B, T, d) -> (B, T/A, A) normalized
    preference rows (the ``predict_preferences`` clip-and-normalize)."""

    def one(k, v, cl, tx):
        mu, _ = gpo_decode(params, cfg, GPOPrefix(k=k, v=v), tx, ctx_len=cl)
        scores = jnp.clip(mu.reshape(-1, num_options), 1e-4, None)
        return scores / scores.sum(axis=-1, keepdims=True)

    return jax.vmap(one)(pk, pv, ctx_len, tgt_x)


def _bucket_of(n: int, buckets: Sequence[int], what: str) -> int:
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"{what} length {n} exceeds the largest bucket "
                     f"{buckets[-1]}; grow ServeConfig.{what}_buckets")


class PreferenceServer:
    """The multi-tenant serving engine (module docstring; DESIGN.md §12).

    ``submit`` enqueues (or rejects), ``step`` retires one fused batch,
    ``run_trace`` drives a full arrival trace open-loop and returns the
    completed results with per-request latencies.
    """

    def __init__(self, params: PyTree, gpo_cfg: GPOConfig,
                 serve_cfg: ServeConfig = ServeConfig(), *,
                 num_options: int):
        serve_cfg.validate()
        for b in serve_cfg.tgt_buckets:
            if b % num_options:
                raise ValueError(
                    f"tgt bucket {b} is not a multiple of "
                    f"num_options={num_options}: padded target rows must "
                    "reshape into whole questions")
        self.gcfg = gpo_cfg
        self.scfg = serve_cfg
        self.num_options = num_options
        self.params = (quantize_gpo_params(params)
                       if serve_cfg.int8_weights else params)
        self._queue: deque[Request] = deque()
        # prefix_key -> (k (L, Mb, nh, hd), v, ctx_len) at the request's
        # own ctx bucket Mb
        self._cache: OrderedDict[Hashable, tuple] = OrderedDict()
        self.batches: List[BatchRecord] = []
        self.stats = ServeStats()
        self._clock_start = time.perf_counter()

    # -- clock ----------------------------------------------------------
    def now(self) -> float:
        return time.perf_counter() - self._clock_start

    def reset(self, *, clear_cache: bool = True) -> None:
        """Drop queued work, stats, and the batch log (and optionally the
        prefix cache) — between benchmark phases."""
        self._queue.clear()
        self.batches = []
        self.stats = ServeStats()
        if clear_cache:
            self._cache.clear()
        self._clock_start = time.perf_counter()

    # -- admission ------------------------------------------------------
    def submit(self, req: Request) -> bool:
        self.stats.submitted += 1
        if self.scfg.max_queue and len(self._queue) >= self.scfg.max_queue:
            self.stats.rejected += 1
            return False
        self._queue.append(req)
        return True

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    # -- prefix cache ---------------------------------------------------
    def _cache_get(self, key: Hashable):
        if key is None or self.scfg.cache_entries == 0:
            return None
        entry = self._cache.get(key)
        if entry is not None:
            self._cache.move_to_end(key)
        return entry

    def _cache_put(self, key: Hashable, entry) -> None:
        if key is None or self.scfg.cache_entries == 0:
            return
        self._cache[key] = entry
        self._cache.move_to_end(key)
        while len(self._cache) > self.scfg.cache_entries:
            self._cache.popitem(last=False)
            self.stats.evictions += 1

    # -- one engine step ------------------------------------------------
    def step(self) -> List[Completed]:
        """Retire one fused batch: pop up to ``max_batch`` head-of-line
        requests, prefill the cache misses (batched, at each request's
        own ctx bucket so cache entries are batch-composition-independent
        and hits stay bit-equal), gather everyone's prefix K/V, decode
        once, complete. Requests whose ``deadline`` already passed are
        dropped during batch assembly wherever they sit in the queue —
        not just at the head — (counted ``expired``, never decoded),
        while live requests keep strict FIFO order (the no-reorder
        determinism contract): under overload this sheds exactly the
        work nobody is waiting for instead of letting it consume batch
        slots or return results after their deadline."""
        now = self.now()
        reqs: List[Request] = []
        while self._queue and len(reqs) < self.scfg.max_batch:
            r = self._queue.popleft()
            if r.deadline is not None and now >= r.deadline:
                self.stats.expired += 1
                continue
            reqs.append(r)
        if not reqs:
            return []
        take = len(reqs)
        ctx_b = _bucket_of(max(r.ctx_x.shape[0] for r in reqs),
                           self.scfg.ctx_buckets, "ctx")
        tgt_b = _bucket_of(max(r.tgt_x.shape[0] for r in reqs),
                           self.scfg.tgt_buckets, "tgt")
        batch_b = _bucket_of(take, self.scfg.batch_buckets, "batch")

        # cache lookups; a miss key shared within the batch prefills once
        entries: dict = {}
        hits: List[bool] = []
        misses: List[Request] = []
        seen_miss_keys: set = set()
        for r in reqs:
            entry = self._cache_get(r.prefix_key)
            if entry is not None:
                hits.append(True)
                entries[id(r)] = entry
                self.stats.cache_hits += 1
            else:
                hits.append(False)
                self.stats.cache_misses += 1
                if r.prefix_key is None or r.prefix_key not in seen_miss_keys:
                    misses.append(r)
                    if r.prefix_key is not None:
                        seen_miss_keys.add(r.prefix_key)

        # batched prefill of the misses, grouped by own ctx bucket
        by_bucket: dict[int, List[Request]] = {}
        for r in misses:
            b = _bucket_of(r.ctx_x.shape[0], self.scfg.ctx_buckets, "ctx")
            by_bucket.setdefault(b, []).append(r)
        fresh: dict = {}
        for b, group in sorted(by_bucket.items()):
            gb = _bucket_of(len(group), self.scfg.batch_buckets, "batch")
            cxs = np.zeros((gb, b, group[0].ctx_x.shape[1]), np.float32)
            cys = np.zeros((gb, b), np.float32)
            lens = np.zeros((gb,), np.int32)
            for i, r in enumerate(group):
                mlen = r.ctx_x.shape[0]
                cxs[i, :mlen] = r.ctx_x
                cys[i, :mlen] = r.ctx_y
                lens[i] = mlen
            pre = _prefill_batch(self.params, self.gcfg,
                                 jnp.asarray(cxs), jnp.asarray(cys),
                                 jnp.asarray(lens))
            self.stats.prefills += len(group)
            for i, r in enumerate(group):
                entry = (pre.k[i], pre.v[i], int(lens[i]))
                fresh[r.prefix_key] = entry
                self._cache_put(r.prefix_key, entry)
                if r.prefix_key is None:
                    entries[id(r)] = entry
        for r in reqs:
            if id(r) not in entries:
                entries[id(r)] = fresh[r.prefix_key]

        # gather + pad to the batch buckets, decode once
        ks, vs, lens, txs = [], [], [], []
        for r in reqs:
            k, v, mlen = entries[id(r)]
            pad_m = ctx_b - k.shape[1]
            if pad_m:
                widths = ((0, 0), (0, pad_m), (0, 0), (0, 0))
                k, v = jnp.pad(k, widths), jnp.pad(v, widths)
            ks.append(k)
            vs.append(v)
            lens.append(mlen)
            tx = np.zeros((tgt_b, r.tgt_x.shape[1]), np.float32)
            tx[:r.tgt_x.shape[0]] = r.tgt_x
            txs.append(tx)
        pad_rows = batch_b - take
        if pad_rows:
            ks.extend([jnp.zeros_like(ks[0])] * pad_rows)
            vs.extend([jnp.zeros_like(vs[0])] * pad_rows)
            lens.extend([0] * pad_rows)
            txs.extend([np.zeros_like(txs[0])] * pad_rows)
        preds = _decode_batch(
            self.params, self.gcfg, self.num_options,
            jnp.stack(ks), jnp.stack(vs),
            jnp.asarray(lens, jnp.int32), jnp.asarray(np.stack(txs)))
        preds = np.asarray(jax.block_until_ready(preds))

        finished = self.now()
        batch_index = len(self.batches)
        self.batches.append(BatchRecord(
            rids=tuple(r.rid for r in reqs), batch_pad=batch_b,
            ctx_bucket=ctx_b, tgt_bucket=tgt_b, hits=tuple(hits)))
        out = []
        for i, r in enumerate(reqs):
            rows = r.tgt_x.shape[0] // self.num_options
            out.append(Completed(
                rid=r.rid, pred=preds[i, :rows], cache_hit=hits[i],
                arrival=r.arrival, finished=finished,
                batch_index=batch_index))
            self.stats.completed += 1
        return out

    # -- open-loop trace driver ----------------------------------------
    def run_trace(self, requests: Sequence[Request],
                  *, reset: bool = True,
                  clear_cache: bool = False) -> List[Completed]:
        """Drive a full arrival trace: requests are admitted when the
        engine clock passes their ``arrival`` (open loop — a slow engine
        builds queue depth and, past ``max_queue``, rejections), and the
        engine steps whenever work is queued. Returns completions in
        retirement order; rejected rids are in ``stats.rejected``."""
        if reset:
            self.reset(clear_cache=clear_cache)
        trace = sorted(requests, key=lambda r: r.arrival)
        results: List[Completed] = []
        i = 0
        while i < len(trace) or self._queue:
            now = self.now()
            while i < len(trace) and trace[i].arrival <= now:
                self.submit(trace[i])
                i += 1
            if not self._queue:
                if i >= len(trace):
                    break
                time.sleep(min(5e-4, max(0.0, trace[i].arrival - now)))
                continue
            results.extend(self.step())
        return results


# ---------------------------------------------------------------------------
# synthetic load generation + latency summaries (shared by the serve CLI,
# bench_serve.py, and the tests)
# ---------------------------------------------------------------------------
def make_request_trace(data, groups, *, num_requests: int,
                       hit_ratio: float = 0.0,
                       num_context: Tuple[int, int] = (6, 16),
                       num_target: Tuple[int, int] = (2, 8),
                       rate: Optional[float] = None,
                       seed: int = 0) -> List[Request]:
    """Build a request trace against a ``SurveyData`` population.

    ``hit_ratio`` controls prefix-cache pressure: the trace draws
    ``ceil((1 - hit_ratio) * N)`` unique (group, context) prefixes and
    spreads the remaining requests across them (fresh targets each), so
    the realized steady-state hit rate is ``hit_ratio`` regardless of
    arrival order. ``num_context``/``num_target`` are inclusive ranges
    of QUESTIONS (points are questions x num_options) sampled per
    prefix / per request — the ragged-length workload the bucketed
    batcher exists for. ``rate`` (requests/sec) spaces arrivals
    uniformly; None means all arrive at t=0 (saturation)."""
    rng = np.random.default_rng(seed)
    phi = np.asarray(data.phi)
    prefs = np.asarray(data.prefs)
    mask = np.asarray(data.mask)
    a = data.num_options
    d = phi.shape[-1]

    n_unique = max(1, int(np.ceil((1.0 - hit_ratio) * num_requests)))
    prefixes = []
    for u in range(n_unique):
        g = int(groups[rng.integers(len(groups))])
        answered = np.flatnonzero(mask[g])
        m = int(rng.integers(num_context[0], num_context[1] + 1))
        m = min(m, max(1, len(answered) - num_target[1]))
        ctx_q = rng.choice(answered, size=m, replace=False)
        ctx_x = phi[ctx_q].reshape(-1, d)
        ctx_y = prefs[g, ctx_q].reshape(-1)
        rest = np.setdiff1d(answered, ctx_q)
        prefixes.append((g, ctx_x, ctx_y, rest, u))

    assign = np.concatenate([
        np.arange(n_unique),
        rng.integers(0, n_unique, size=num_requests - n_unique)])
    rng.shuffle(assign)
    out = []
    for rid in range(num_requests):
        g, ctx_x, ctx_y, rest, u = prefixes[int(assign[rid])]
        t = int(rng.integers(num_target[0], num_target[1] + 1))
        tgt_q = rng.choice(rest, size=min(t, len(rest)), replace=False)
        tgt_x = phi[tgt_q].reshape(-1, d)
        arrival = 0.0 if rate is None else rid / rate
        out.append(Request(
            rid=rid, ctx_x=ctx_x.astype(np.float32),
            ctx_y=ctx_y.astype(np.float32),
            tgt_x=tgt_x.astype(np.float32),
            prefix_key=("ctx", g, u), arrival=arrival,
            meta={"group": g, "tgt_q": tgt_q}))
    return out


def latency_summary(results: Sequence[Completed],
                    wall_seconds: float) -> dict:
    """p50/p99 latency (ms) + throughput over a completed trace."""
    if not results:
        return {"completed": 0}
    lat = np.asarray([r.latency for r in results]) * 1e3
    return {
        "completed": len(results),
        "p50_ms": float(np.percentile(lat, 50)),
        "p95_ms": float(np.percentile(lat, 95)),
        "p99_ms": float(np.percentile(lat, 99)),
        "mean_ms": float(lat.mean()),
        "max_ms": float(lat.max()),
        "qps": float(len(results) / max(wall_seconds, 1e-9)),
        "hit_rate": float(np.mean([r.cache_hit for r in results])),
    }
