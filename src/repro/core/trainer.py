"""Backbone training / serving steps + federated backbone trainers.

These step functions are what the launcher jits and the dry-run lowers:

* ``make_train_step``   — LM loss (+ MoE aux), grad, optimizer update,
                          optional microbatch gradient accumulation and
                          activation remat (both required to fit the
                          largest archs' train_4k on 16 GB/chip).
* ``make_prefill_step`` — full-sequence forward that materializes the
                          decode cache.
* ``make_serve_step``   — ONE token against the cache (the decode_32k /
                          long_500k shapes lower exactly this).
* ``make_backbone_fedavg_round`` / ``make_fedlora_round`` — the paper's
  technique applied to backbone training: clients run local steps, then
  Eq. 3 weighted-averages full params (small archs) or LoRA adapters
  (large archs).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (
    AdversaryConfig,
    CompressionConfig,
    ModelConfig,
    PrivacyConfig,
)
from repro.core.aggregation import ServerAggregator
from repro.core.fedavg import broadcast_to_clients, fedavg_stacked
from repro.core.lora import apply_lora
from repro.models import forward
from repro.models.layers import cross_entropy_loss
from repro.optim import Optimizer
from repro.utils.pytree import (
    tree_index,
    tree_sub,
    tree_zeros_like,
)

PyTree = Any


def lm_loss(params, cfg: ModelConfig, batch: dict) -> jnp.ndarray:
    logits, _, aux = forward(
        params, cfg,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        enc_embeds=batch.get("enc_embeds"),
        remat=batch.get("_remat", False))
    # final softcap is applied inside forward; plain CE here
    loss = cross_entropy_loss(logits, batch["labels"])
    return loss + aux


def make_train_step(cfg: ModelConfig, opt: Optimizer, *,
                    microbatch: int = 1, remat: bool = False) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        b = dict(batch)
        b["_remat"] = remat
        return lm_loss(params, cfg, b)

    def train_step(params, opt_state, batch):
        if microbatch <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split_mb(x):
                return x.reshape((microbatch, x.shape[0] // microbatch)
                                 + x.shape[1:])

            mb = jax.tree.map(split_mb, batch)

            def acc_step(carry, mb_batch):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb_batch)
                return (loss_acc + loss,
                        jax.tree.map(jnp.add, grad_acc, grads)), None

            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros((), jnp.float32),
                           tree_zeros_like(params)), mb)
            loss = loss / microbatch
            grads = jax.tree.map(lambda g: g / microbatch, grads)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss}

    return train_step


def make_prefill_step(cfg: ModelConfig, max_seq: int) -> Callable:
    """(params, batch) -> (last_logits, cache)."""

    def prefill_step(params, batch):
        logits, cache, _ = forward(
            params, cfg,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            enc_embeds=batch.get("enc_embeds"),
            prefill_len=max_seq)
        return logits[:, -1], cache

    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """(params, cache, tokens (B,1), cache_pos) -> (logits (B,V), cache).

    ONE new token against a ``seq_len`` KV cache / SSM state — the step the
    decode input-shapes lower.
    """

    def serve_step(params, cache, tokens, cache_pos):
        logits, cache, _ = forward(params, cfg, tokens=tokens, cache=cache,
                                   cache_pos=cache_pos)
        return logits[:, 0], cache

    return serve_step


def greedy_decode(cfg: ModelConfig, params, cache, first_token, start_pos,
                  num_steps: int):
    """Greedy generation loop (lax.scan) for the serving example."""
    serve = make_serve_step(cfg)

    def body(carry, _):
        tok, cache, pos = carry
        logits, cache = serve(params, cache, tok, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return (nxt, cache, pos + 1), nxt[:, 0]

    (_, cache, _), toks = jax.lax.scan(
        body, (first_token, cache, jnp.asarray(start_pos, jnp.int32)),
        None, length=num_steps)
    return toks.T, cache  # (B, num_steps)


# ---------------------------------------------------------------------------
# Federated backbone training (the paper's technique as a trainer feature)
# ---------------------------------------------------------------------------
def _aggregated_round(local_train: Callable,
                      agg: Optional[ServerAggregator],
                      privacy: Optional[PrivacyConfig] = None,
                      use_pallas_aggregation: bool = False,
                      compression: Optional[CompressionConfig] = None,
                      adversary: Optional[AdversaryConfig] = None
                      ) -> Callable:
    """Shared round tail for the backbone/LoRA federated trainers.

    ``agg=None`` keeps the seed contract: (client_payload, opt_states,
    batches, weights) -> (payload, opt_states, losses) with Eq. 3
    aggregation. With a ``ServerAggregator`` the delta contract of
    DESIGN.md §7 applies — the round takes/returns the server state:
    (payload, opt_states, batches, weights, server_state) ->
    (payload, opt_states, losses, server_state).
    With an *enabled* ``privacy`` config (DESIGN.md §9; requires
    ``agg``) each client's flat delta is clipped + noised before the
    aggregator, exactly as in the GPO engines
    (``use_pallas_aggregation`` routes the linear family through the
    fused ``agg_clip_reduce`` kernel, mirroring the GPO engines' flag).
    With an *enabled* ``compression`` config (DESIGN.md §10; requires
    ``agg``) the released deltas run through the int8/top-k codec before
    the aggregator. With an *enabled* ``adversary`` config (DESIGN.md
    §13; requires ``agg``; delta-level kinds only — ``label_flip``
    poisons survey preferences, which only the GPO engines hold)
    Byzantine rows are corrupted before the privacy/codec release, and
    ``agg.cfg.norm_bound > 0`` clips the received rows server-side.
    The round signature grows, in order, a trailing ``resid (C, P)``
    EF-residual argument/result when ``error_feedback`` is on, then the
    per-round ``round_key`` whenever any stage needs randomness (DP
    noise, stochastic rounding, or the Byzantine schedule/attack keys,
    which fold out of it):
    (payload, opt_states, batches, weights, server_state[, resid]
     [, round_key]) -> (payload, opt_states, losses, server_state
     [, resid]).

    All stage dispatch is delegated to ``RoundPipeline`` — this trainer
    assembles the same declared [local_train, attack, privacy, codec,
    aggregate] list as the GPO engines.
    """
    if privacy is not None:
        privacy.validate()
    if compression is not None:
        compression.validate()
    if adversary is not None:
        adversary.validate()
    private = privacy is not None and privacy.enabled
    compressed = compression is not None and compression.enabled
    adv_on = adversary is not None and adversary.enabled
    if (private or compressed or adv_on) and agg is None:
        raise ValueError("the DP delta pipeline, the compression stage,"
                         " and the Byzantine attack stage ride the delta"
                         " contract: pass a ServerAggregator (agg=) with"
                         " privacy, compression, or adversary")
    if adv_on and adversary.data_level:
        # preference label flipping rewrites survey ICL batches inside
        # federated._make_local_train; the backbone/LoRA local step is a
        # plain LM loss over opaque token batches — failing loudly beats
        # silently benchmarking an attack that never fired
        raise ValueError(
            "adversary.kind='label_flip' is only wired into the GPO "
            "engine's local data pipeline (federated._make_local_train); "
            "the backbone/LoRA trainers support the delta-level kinds "
            "(sign_flip/scaled/gaussian/alie)")
    if agg is None:
        def round_fn(client_payload, opt_states, batches, weights):
            client_payload, opt_states, losses = jax.vmap(local_train)(
                client_payload, opt_states, batches)
            global_payload = fedavg_stacked(client_payload, weights)
            num_clients = weights.shape[0]
            return (broadcast_to_clients(global_payload, num_clients),
                    opt_states, losses)

        return round_fn

    if agg.cfg.prox_mu > 0.0:
        # the proximal term lives in the local objective, which for the
        # backbone/LoRA trainers is the plain LM loss — failing loudly
        # beats silently benchmarking "FedProx" that is really FedAvg
        raise ValueError(
            "prox_mu > 0 is only wired into the GPO engine's local "
            "objective (federated._make_local_train); the backbone/LoRA "
            "trainers do not apply a proximal term")

    from repro.configs.base import CompressionConfig as _CC
    from repro.configs.base import PrivacyConfig as _PC
    from repro.core.pipeline import RoundPipeline

    pipe = RoundPipeline(
        adversary=adversary if adversary is not None else AdversaryConfig(),
        privacy=privacy if privacy is not None else _PC(),
        compression=compression if compression is not None else _CC(),
        agg=agg, num_clients=None, use_pallas=use_pallas_aggregation)
    ef = compressed and compression.error_feedback
    # the release stages need per-client keys (DP noise or stochastic
    # rounding); the Byzantine schedule folds its own key out of the
    # round key. Either demand puts round_key in the signature.
    release_needs_key = private or (compressed and compression.needs_rng)
    need_key = release_needs_key or adv_on

    def round_fn(client_payload, opt_states, batches, weights,
                 server_state, *extra):
        expect = int(ef) + int(need_key)
        if len(extra) != expect:
            raise TypeError(
                f"round expects {expect} trailing arg(s) "
                f"([resid]={ef}, [round_key]={need_key}); "
                f"got {len(extra)}")
        resid = extra[0] if ef else None
        round_key = extra[-1] if need_key else None
        new_payload, opt_states, losses = jax.vmap(local_train)(
            client_payload, opt_states, batches)
        # pipeline tail (DESIGN.md §13): [attack →] privacy → codec →
        # aggregate on the flat client deltas; full participation, so
        # rows ARE the population (idx=None).
        deltas = tree_sub(new_payload, client_payload)
        keys = (jax.random.split(round_key, weights.shape[0])
                if release_needs_key else None)
        bk = pipe.fold_key(round_key)
        global_payload, server_state, new_resid = pipe.reduce_apply(
            server_state, tree_index(client_payload, 0), deltas, weights,
            keys, losses=losses, idx=None, resid=resid, byz_key=bk)
        out = (broadcast_to_clients(global_payload, weights.shape[0]),
               opt_states, losses, server_state)
        return out + (new_resid,) if ef else out

    return round_fn


def make_backbone_fedavg_round(cfg: ModelConfig, opt: Optimizer,
                               local_steps: int,
                               agg: Optional[ServerAggregator] = None,
                               privacy: Optional[PrivacyConfig] = None,
                               use_pallas_aggregation: bool = False,
                               compression: Optional[CompressionConfig]
                               = None,
                               adversary: Optional[AdversaryConfig]
                               = None) -> Callable:
    """Full-parameter federated round over backbones (feasible <= few-B
    params).

    (client_params (C, ...), opt_states, batches (C, local_steps, ...),
     weights (C,)) -> (new client params, opt_states, mean loss per client).
    One round = local_steps LM steps per client + aggregation +
    redistribution (Eq. 3 FedAvg by default; any registry strategy via
    ``agg``, which adds a server_state argument/result — see
    ``_aggregated_round``). vmap engine (tests/CPU); the launcher swaps
    in the shard_map engine with the same body.
    """
    step = make_train_step(cfg, opt)

    def local_train(params, opt_state, batches):
        def body(carry, batch):
            params, opt_state = carry
            params, opt_state, m = step(params, opt_state, batch)
            return (params, opt_state), m["loss"]

        (params, opt_state), losses = jax.lax.scan(
            body, (params, opt_state), batches)
        return params, opt_state, jnp.mean(losses)

    return _aggregated_round(local_train, agg, privacy,
                             use_pallas_aggregation, compression,
                             adversary)


def make_fedlora_round(cfg: ModelConfig, frozen_params, opt: Optimizer,
                       local_steps: int,
                       agg: Optional[ServerAggregator] = None,
                       privacy: Optional[PrivacyConfig] = None,
                       use_pallas_aggregation: bool = False,
                       compression: Optional[CompressionConfig] = None,
                       adversary: Optional[AdversaryConfig] = None
                       ) -> Callable:
    """Federated LoRA adapters with a frozen (shared) backbone — the
    production recipe for grok-1-class archs (DESIGN.md §3). The adapter
    tree is a plain pytree, so every registry aggregation strategy
    applies to it unchanged (``agg``; see ``_aggregated_round``)."""

    def loss_fn(lora, batch):
        eff = apply_lora(frozen_params, lora)
        return lm_loss(eff, cfg, batch)

    def local_train(lora, opt_state, batches):
        def body(carry, batch):
            lora, opt_state = carry
            loss, grads = jax.value_and_grad(loss_fn)(lora, batch)
            lora, opt_state = opt.update(grads, opt_state, lora)
            return (lora, opt_state), loss

        (lora, opt_state), losses = jax.lax.scan(
            body, (lora, opt_state), batches)
        return lora, opt_state, jnp.mean(losses)

    return _aggregated_round(local_train, agg, privacy,
                             use_pallas_aggregation, compression,
                             adversary)
