from repro.data.surveys import (  # noqa: F401
    SurveyConfig,
    SurveyData,
    make_survey_data,
    sample_icl_batch,
    split_groups,
)
from repro.data.embeddings import StubEmbedder, BackboneEmbedder  # noqa: F401
from repro.data.lm_data import LMDataConfig, synthetic_lm_batches  # noqa: F401
