"""Frozen-LLM embedding frontends for the preference pipeline.

The paper embeds each concatenated (question, answer) text with Alpaca-7B
once per group before training (§4.3). Offline we provide:

* ``StubEmbedder`` — deterministic pseudo-embeddings (hash -> PRNG -> unit
  normal). This is the declared frontend stub: weak-type-correct, the right
  shape, zero model weights.
* ``BackboneEmbedder`` — runs any model-zoo backbone (mean-pooled final
  hidden state) so the full pipeline (backbone -> GPO -> FedAvg) is
  exercised end-to-end with real compute in examples/tests on reduced
  configs, and on TPU with the full assigned architectures.
"""
from __future__ import annotations

import hashlib
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


class StubEmbedder:
    """Deterministic stand-in for the frozen Alpaca-7B embedding function."""

    def __init__(self, d_embed: int, seed: int = 0):
        self.d_embed = d_embed
        self.seed = seed

    def _key_for(self, text: str) -> jax.Array:
        h = int.from_bytes(
            hashlib.sha256(text.encode()).digest()[:4], "little")
        return jax.random.fold_in(jax.random.PRNGKey(self.seed), h)

    def embed_text(self, text: str) -> jnp.ndarray:
        v = jax.random.normal(self._key_for(text), (self.d_embed,))
        return v / jnp.linalg.norm(v)

    def embed_qa(self, question: str, answer: str) -> jnp.ndarray:
        return self.embed_text(question + " [SEP] " + answer)

    def embed_batch(self, texts: list[str]) -> jnp.ndarray:
        return jnp.stack([self.embed_text(t) for t in texts])


class BackboneEmbedder:
    """Embed token sequences with a frozen model-zoo backbone.

    ``apply_fn(params, tokens) -> (B, S, d_model)`` is the backbone's hidden
    state function; embeddings are masked mean-pools projected to d_embed.
    """

    def __init__(self, apply_fn: Callable, params, d_model: int, d_embed: int,
                 seed: int = 0):
        self.apply_fn = apply_fn
        self.params = params
        proj_key = jax.random.PRNGKey(seed)
        self.proj = (jax.random.normal(proj_key, (d_model, d_embed))
                     / np.sqrt(d_model)) if d_model != d_embed else None
        self._jit_embed = jax.jit(self._embed)

    def _embed(self, tokens: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
        hidden = self.apply_fn(self.params, tokens)  # (B, S, d_model)
        denom = jnp.maximum(mask.sum(axis=1, keepdims=True), 1)
        pooled = (hidden * mask[..., None]).sum(axis=1) / denom
        if self.proj is not None:
            pooled = pooled @ self.proj
        return pooled / (jnp.linalg.norm(pooled, axis=-1, keepdims=True) + 1e-6)

    def embed_tokens(self, tokens: jnp.ndarray,
                     mask: jnp.ndarray | None = None) -> jnp.ndarray:
        if mask is None:
            mask = jnp.ones(tokens.shape[:2], jnp.float32)
        return self._jit_embed(tokens, mask)
