"""Token pipeline for backbone (LM-objective) training.

Synthetic corpus: a mixture of Zipf-distributed unigrams with Markov
bigram structure, so the LM loss actually decreases during the example
training runs (pure-uniform tokens would pin loss at log V).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class LMDataConfig:
    vocab_size: int = 1024
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 0
    zipf_a: float = 1.2
    markov_strength: float = 0.7  # prob of following the bigram chain


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-a)
    return (p / p.sum()).astype(np.float64)


def synthetic_lm_batches(cfg: LMDataConfig) -> Iterator[dict]:
    """Yields {'tokens': (B, S) int32, 'labels': (B, S) int32} forever.

    labels[t] = tokens[t+1]; final label is a wrap to BOS (=0).
    """
    rng = np.random.default_rng(cfg.seed)
    probs = _zipf_probs(cfg.vocab_size, cfg.zipf_a)
    # deterministic bigram successor table: token v prefers (v*7+3) % V
    succ = (np.arange(cfg.vocab_size) * 7 + 3) % cfg.vocab_size
    while True:
        b, s = cfg.global_batch, cfg.seq_len
        iid = rng.choice(cfg.vocab_size, size=(b, s + 1), p=probs)
        follow = rng.random((b, s + 1)) < cfg.markov_strength
        seq = iid.copy()
        for t in range(1, s + 1):
            seq[:, t] = np.where(follow[:, t], succ[seq[:, t - 1]], iid[:, t])
        yield {
            "tokens": jnp.asarray(seq[:, :-1], jnp.int32),
            "labels": jnp.asarray(seq[:, 1:], jnp.int32),
        }


def shard_batch(batch: dict, mesh, batch_axis: str = "data") -> dict:
    """Place a host batch onto the mesh, batch dim sharded over ``data``."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    spec = P(batch_axis)
    return {
        k: jax.device_put(v, NamedSharding(mesh, spec)) for k, v in batch.items()
    }
