"""Synthetic PewResearch-style global-opinion survey data.

The paper trains on Pew Global Attitudes Surveys (GlobalOpinionQA): each
*group* (country / demographic) answers multiple-choice opinion questions;
the label for (group, question) is the aggregated answer distribution over
the question's options.

That dataset is not redistributable offline, so we generate a synthetic
population with matched structure and controllable heterogeneity:

* every question q has ``num_options`` options with feature embeddings
  phi(q, a) — the stand-in for the frozen-LLM embedding of the
  concatenated (question, answer) text;
* every group g has a latent opinion vector w_g drawn from one of
  ``num_archetypes`` clusters plus Dirichlet-controlled idiosyncrasy;
* the group's answer distribution is softmax_a( phi(q,a) . w_g / temp ).

Because preferences are a *function of the embeddings*, an in-context
learner (GPO) can genuinely infer a group's latent w_g from context
questions and generalize to held-out questions and unseen groups — the same
structural property the real dataset has, which is what the paper's
experiments measure.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class SurveyConfig:
    num_groups: int = 17
    num_questions: int = 120
    num_options: int = 5
    d_embed: int = 64
    num_archetypes: int = 4
    idiosyncrasy: float = 0.35  # scale of per-group deviation from archetype
    temperature: float = 0.8  # sharpness of group answer distributions
    min_questions_frac: float = 0.6  # groups observe a random subset of Qs
    seed: int = 0


class SurveyData(NamedTuple):
    """Arrays describing the full synthetic survey population."""

    phi: jnp.ndarray  # (Q, A, d_embed) frozen-LLM embedding of (q, a) text
    prefs: jnp.ndarray  # (G, Q, A) per-group answer distributions (simplex)
    mask: jnp.ndarray  # (G, Q) bool: did group g answer question q
    sizes: jnp.ndarray  # (G,) |D_g| = number of answered questions
    group_w: jnp.ndarray  # (G, d_embed) latent opinion vectors (debug only)

    @property
    def num_groups(self) -> int:
        return self.prefs.shape[0]

    @property
    def num_questions(self) -> int:
        return self.prefs.shape[1]

    @property
    def num_options(self) -> int:
        return self.prefs.shape[2]


def make_survey_data(cfg: SurveyConfig) -> SurveyData:
    key = jax.random.PRNGKey(cfg.seed)
    k_phi, k_arch, k_assign, k_idio, k_mask = jax.random.split(key, 5)

    phi = jax.random.normal(k_phi, (cfg.num_questions, cfg.num_options, cfg.d_embed))
    phi = phi / jnp.linalg.norm(phi, axis=-1, keepdims=True)

    archetypes = jax.random.normal(k_arch, (cfg.num_archetypes, cfg.d_embed))
    assign = jax.random.randint(k_assign, (cfg.num_groups,), 0, cfg.num_archetypes)
    idio = cfg.idiosyncrasy * jax.random.normal(
        k_idio, (cfg.num_groups, cfg.d_embed))
    group_w = archetypes[assign] + idio  # (G, d)

    logits = jnp.einsum("qad,gd->gqa", phi, group_w) / cfg.temperature
    prefs = jax.nn.softmax(logits, axis=-1)

    # groups answer a random subset of questions -> unequal |D_g| so the
    # FedAvg weights p_g = |D_g| / sum |D_g'| are non-trivial (Eq. 2).
    frac = jax.random.uniform(
        k_mask, (cfg.num_groups, cfg.num_questions),
        minval=0.0, maxval=1.0)
    keep_prob = cfg.min_questions_frac + (1.0 - cfg.min_questions_frac) * (
        jax.random.uniform(jax.random.fold_in(k_mask, 1), (cfg.num_groups, 1)))
    mask = frac < keep_prob
    # guarantee a minimum so context/target sampling never starves
    min_q = max(8, int(cfg.min_questions_frac * cfg.num_questions) // 2)
    order = jnp.argsort(~mask, axis=1)  # answered first
    forced = jnp.zeros_like(mask).at[
        jnp.arange(cfg.num_groups)[:, None], order[:, :min_q]].set(True)
    mask = mask | forced
    sizes = mask.sum(axis=1)

    return SurveyData(phi=phi, prefs=prefs, mask=mask, sizes=sizes,
                      group_w=group_w)


def split_groups(data: SurveyData, train_frac: float = 0.6,
                 seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """60/40 train/eval group split as in the paper (§4.2)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(data.num_groups)
    n_train = max(1, int(round(train_frac * data.num_groups)))
    return perm[:n_train], perm[n_train:]


class ICLBatch(NamedTuple):
    """One in-context batch for the GPO predictor (flattened to points).

    A "point" is one (question, option) pair: x = phi(q, a), y = P_g(a | q).
    Context questions contribute all their options as observed points;
    target questions contribute all options with y to be predicted.
    """

    ctx_x: jnp.ndarray  # (m*A, d_embed)
    ctx_y: jnp.ndarray  # (m*A,)
    tgt_x: jnp.ndarray  # (t*A, d_embed)
    tgt_y: jnp.ndarray  # (t*A,) ground truth for the loss
    tgt_q: jnp.ndarray  # (t*A,) int32 question index of each target point
    num_options: int


def sample_icl_batch(key: jax.Array, data: SurveyData, group: int,
                     num_context: int, num_target: int) -> ICLBatch:
    """Sample context/target questions for one group (paper §3.1).

    Sampling is done over the group's *answered* questions. Runs under jit
    (group may be traced) — uses masked categorical sampling.
    """
    g_mask = data.mask[group]  # (Q,)
    logits = jnp.where(g_mask, 0.0, -1e9)
    qs = jax.random.choice(
        key, data.num_questions, shape=(num_context + num_target,),
        replace=False, p=jax.nn.softmax(logits))
    ctx_q, tgt_q = qs[:num_context], qs[num_context:]

    def gather(q_idx):
        x = data.phi[q_idx]  # (n, A, d)
        y = data.prefs[group, q_idx]  # (n, A)
        return (x.reshape(-1, x.shape[-1]), y.reshape(-1))

    ctx_x, ctx_y = gather(ctx_q)
    tgt_x, tgt_y = gather(tgt_q)
    tgt_qids = jnp.repeat(tgt_q, data.num_options)
    return ICLBatch(ctx_x=ctx_x, ctx_y=ctx_y, tgt_x=tgt_x, tgt_y=tgt_y,
                    tgt_q=tgt_qids, num_options=data.num_options)
