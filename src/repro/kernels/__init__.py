# Pallas TPU kernels for the compute hot spots: flash attention (backbone),
# GPO neural-process attention (the paper's module; differentiable via a
# flash-style custom VJP on the banded grid, DESIGN.md §8), Mamba2 SSD
# scan, and the server-aggregation reductions (Eq. 3 FedAvg plus the
# generalized delta-moment, rank-trim, DP-clip, and compressed-transport
# kernels, DESIGN.md §7, §9, §10).
# Load the deprecated re-export module FIRST so its one-time parent-
# attribute binding happens now; the ops import below then rebinds the
# ``fedavg_reduce`` package attribute to the jit'd wrapper FUNCTION (the
# public API), and later `import repro.kernels.fedavg_reduce` hits
# sys.modules without re-shadowing it.
from repro.kernels import fedavg_reduce as _fedavg_reduce_module  # noqa: F401,E501
from repro.kernels.ops import (  # noqa: F401
    agg_clip_reduce,
    agg_momentum_reduce,
    agg_quant_clip_reduce,
    agg_topk_reduce,
    agg_trimmed_reduce,
    fedavg_reduce,
    fedavg_reduce_tree,
    flash_attention,
    gpo_attention,
    ssd_scan,
)
from repro.kernels import ref  # noqa: F401
