# Pallas TPU kernels for the compute hot spots: flash attention (backbone),
# GPO neural-process attention (the paper's module), Mamba2 SSD scan, and
# the FedAvg weighted reduction (the paper's aggregation, Eq. 3).
from repro.kernels.ops import (  # noqa: F401
    fedavg_reduce,
    fedavg_reduce_tree,
    flash_attention,
    gpo_attention,
    ssd_scan,
)
from repro.kernels import ref  # noqa: F401
