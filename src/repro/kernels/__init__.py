# Pallas TPU kernels for the compute hot spots: flash attention (backbone),
# GPO neural-process attention (the paper's module; differentiable via a
# flash-style custom VJP on the banded grid, DESIGN.md §8), Mamba2 SSD
# scan, the server-aggregation reductions (Eq. 3 FedAvg plus the
# generalized delta-moment, rank-trim, DP-clip, and compressed-transport
# kernels, DESIGN.md §7, §9, §10), and the int8 weight-only inference
# matmul for the serving engine (DESIGN.md §12).
from repro.kernels.ops import (  # noqa: F401
    agg_clip_reduce,
    agg_momentum_reduce,
    agg_pairwise_dists,
    agg_quant_clip_reduce,
    agg_topk_reduce,
    agg_trimmed_reduce,
    fedavg_reduce,
    fedavg_reduce_tree,
    flash_attention,
    gpo_attention,
    int8_matmul,
    ssd_scan,
)
from repro.kernels.quant_matmul import (  # noqa: F401
    QuantizedLinear,
    dequantize_linear,
    quantize_linear,
)
from repro.kernels import ref  # noqa: F401
