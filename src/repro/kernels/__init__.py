# Pallas TPU kernels for the compute hot spots: flash attention (backbone),
# GPO neural-process attention (the paper's module; differentiable via a
# flash-style custom VJP on the banded grid, DESIGN.md §8), Mamba2 SSD
# scan, and the server-aggregation reductions (Eq. 3 FedAvg plus the
# generalized delta-moment and rank-trim kernels, DESIGN.md §7).
from repro.kernels.ops import (  # noqa: F401
    agg_clip_reduce,
    agg_momentum_reduce,
    agg_trimmed_reduce,
    fedavg_reduce,
    fedavg_reduce_tree,
    flash_attention,
    gpo_attention,
    ssd_scan,
)
from repro.kernels import ref  # noqa: F401
