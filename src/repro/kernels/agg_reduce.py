"""Generalized server-aggregation Pallas kernels (DESIGN.md §7, §9, §10).

One kernel family, one oracle module (kernels/ref.py): the seed's
``fedavg_reduce`` (Eq. 3 as a weighted reduction over the flattened
(C, P) client-delta matrix — ``fedavg_reduce_flat`` below, formerly its
own ``kernels/fedavg_reduce.py`` module) generalizes into the
aggregation kernels:

1. ``momentum_reduce_flat`` — the weighted delta-moment kernel: one pass
   over the (C, bp) tile produces BOTH the weighted first moment
   Delta[p] = sum_c w[c] * d_c[p] and the updated server-momentum buffer
   m'[p] = beta * m[p] + Delta[p] (FedAvgM; beta=0 returns Delta in both
   outputs, i.e. plain FedAvg). Fusing the momentum update into the
   reduction keeps the kernel bandwidth-bound at the same arithmetic
   intensity: the (1, bp) momentum tile rides along with the (C, bp)
   client stream, so the extra state costs 2/C of the traffic instead of
   a second kernel launch + round trip.
2. ``trimmed_reduce_flat`` — the client-axis sort/trim kernel for the
   robust aggregators: per coordinate, clients are ranked (stable, ties
   broken by client index — exactly a stable argsort), the k lowest and
   k highest are dropped, and the survivors' weighted mean (weights
   renormalized over the survivors) is emitted. ``median`` is the
   maximal trim k = (C-1)//2. Ranks are computed with C predicated
   (C, bp) compare-reduce passes (C is the client axis — tens, not
   thousands), so no on-chip sort network is needed and VMEM holds only
   the streamed tile plus two (1, bp) accumulators.

3. ``clip_reduce_flat`` — the DP-aggregation kernel (DESIGN.md §9): one
   launch computes every client's L2 norm over the full flattened
   parameter axis, rescales each client's delta to the clip bound
   min(1, S/‖d_c‖), optionally adds the presampled per-client Gaussian
   noise tile, and weighted-accumulates into the reduced (1, bp) output.
   The norm is a global reduction over P, so a single streaming sweep
   cannot both finish it and consume it; the kernel instead runs a
   (2, nb) grid — sweep 0 accumulates per-client squared norms into a
   (C, 1) VMEM scratch, sweep 1 applies scale/noise/reduce — i.e. one
   kernel launch, two HBM reads of the delta shard (plus one of the
   noise operand, read only in sweep 1) and one (1, P) write, vs the
   unfused chain's three delta reads plus a full (C, P)
   materialization of the clipped matrix.

4. ``quant_clip_reduce_flat`` — the communication-compression kernel
   (DESIGN.md §10): extends the clip/noise two-sweep grid with an int8
   quantize→dequantize stage. The per-client quantization scale needs
   max|d̃_c| over the FULL parameter axis — a second global reduction on
   top of the clip norm — so the grid grows to (3, nb) when the DP clip
   is on ((2, nb) otherwise): sweep 0 accumulates squared norms, sweep 1
   recomputes the privatized tile on the fly and accumulates per-client
   absmax into a second (C, 1) scratch, sweep 2 quantizes (stochastic
   rounding from a presampled uniform tile), dequantizes, and
   weighted-reduces. No intermediate clipped/quantized (C, P) matrix
   ever reaches HBM; with error feedback the kernel's only (C, P) write
   is the NEW residual e' = d̃ + e − Q(d̃ + e), which is carried round
   state, not an intermediate.

5. ``topk_reduce_flat`` — the top-k threshold/scatter kernel: given
   per-client magnitude thresholds (the k-th largest |d̃_c[p]|,
   computed outside — exact selection is a global sort and does not
   stream), one (nb,) sweep masks sub-threshold entries to zero,
   weighted-reduces the survivors, and (under error feedback) writes
   the masked-out remainder as the new residual.

All kernels share the tiling of ``fedavg_reduce``: the grid walks the
flattened parameter axis, weights sit in an SMEM-resident (C, 1) tile,
and each tile streams HBM once per sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import interpret_default

DEFAULT_BLOCK = 2048

# norm floor shared with core/privacy.py and kernels/ref.py: zero deltas
# keep scale 1 instead of dividing by zero
_NORM_FLOOR = 1e-12

# int8 symmetric-quantization constants, shared with core/compression.py
# and kernels/ref.py: q in [-127, 127], scale floored so an all-zero
# client quantizes to exact zeros instead of dividing by zero
INT8_LEVELS = 127.0
_SCALE_FLOOR = 1e-30


def _pad_cols(x: jnp.ndarray, block: int) -> tuple[jnp.ndarray, int]:
    p = x.shape[-1]
    pad = (-p) % block
    if pad:
        widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
        x = jnp.pad(x, widths)
    return x, p + pad


def _fedavg_kernel(w_ref, x_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)  # (C, 1)
    x = x_ref[...].astype(jnp.float32)  # (C, bp)
    o_ref[...] = jnp.sum(w * x, axis=0, keepdims=True).astype(o_ref.dtype)


def fedavg_reduce_flat(stacked: jnp.ndarray, weights: jnp.ndarray, *,
                       block: int = DEFAULT_BLOCK,
                       interpret: bool | None = None) -> jnp.ndarray:
    """stacked (C, P), weights (C,) -> (P,). P is padded to ``block``.

    Eq. 3 as a fused weighted reduction: each tile streams (C, bp)
    client parameters HBM -> VMEM once and writes (1, bp) back, so the
    kernel runs at HBM speed, which is the roofline for aggregation.
    ``interpret`` defaults to the backend (interpret on CPU, native on
    TPU), matching the ``ops.py`` wrappers, so direct callers never
    silently run interpret mode on hardware.
    """
    if interpret is None:
        interpret = interpret_default()
    c, p = stacked.shape
    stacked, pp = _pad_cols(stacked, block)
    nb = pp // block
    w2 = weights.reshape(c, 1).astype(jnp.float32)

    out = pl.pallas_call(
        _fedavg_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((c, 1), lambda i: (0, 0)),
            pl.BlockSpec((c, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, pp), stacked.dtype),
        interpret=interpret,
    )(w2, stacked)
    return out[0, :p]


def _moment_kernel(beta, w_ref, x_ref, m_ref, d_ref, nm_ref):
    w = w_ref[...].astype(jnp.float32)  # (C, 1)
    x = x_ref[...].astype(jnp.float32)  # (C, bp)
    d = jnp.sum(w * x, axis=0, keepdims=True)  # (1, bp)
    nm = beta * m_ref[...].astype(jnp.float32) + d
    d_ref[...] = d.astype(d_ref.dtype)
    nm_ref[...] = nm.astype(nm_ref.dtype)


def momentum_reduce_flat(stacked: jnp.ndarray, weights: jnp.ndarray,
                         moment: jnp.ndarray, *, beta: float,
                         block: int = DEFAULT_BLOCK,
                         interpret: bool | None = None
                         ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """stacked (C, P) deltas, weights (C,), moment (P,) ->
    (delta (P,), new_moment (P,)) with new_moment = beta*moment + delta."""
    if interpret is None:
        interpret = interpret_default()
    c, p = stacked.shape
    stacked, pp = _pad_cols(stacked, block)
    m2, _ = _pad_cols(moment.reshape(1, -1).astype(jnp.float32), block)
    nb = pp // block
    w2 = weights.reshape(c, 1).astype(jnp.float32)

    d, nm = pl.pallas_call(
        functools.partial(_moment_kernel, beta),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((c, 1), lambda i: (0, 0)),
            pl.BlockSpec((c, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, pp), stacked.dtype),
            jax.ShapeDtypeStruct((1, pp), jnp.float32),
        ],
        interpret=interpret,
    )(w2, stacked, m2)
    return d[0, :p], nm[0, :p]


def _clip_reduce_body(clip, x_ref, noise, w_ref, o_ref, sq_ref):
    """Shared two-sweep body: sweep 0 accumulates squared norms into the
    (C, 1) scratch, sweep 1 clips/noises/reduces the revisited tile."""
    ph = pl.program_id(0)
    i = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)  # (C, bp)

    @pl.when((ph == 0) & (i == 0))
    def _init():
        sq_ref[...] = jnp.zeros_like(sq_ref)

    @pl.when(ph == 0)
    def _accumulate_norms():
        sq_ref[...] += jnp.sum(x * x, axis=1, keepdims=True)

    @pl.when(ph == 1)
    def _clip_and_reduce():
        w = w_ref[...].astype(jnp.float32)  # (C, 1)
        norm = jnp.sqrt(sq_ref[...])  # (C, 1)
        scale = jnp.minimum(1.0, clip / jnp.maximum(norm, _NORM_FLOOR))
        y = x * scale
        if noise is not None:
            y = y + noise[...].astype(jnp.float32)
        o_ref[...] = jnp.sum(w * y, axis=0, keepdims=True).astype(
            o_ref.dtype)


def _clip_reduce_kernel(clip, w_ref, x_ref, o_ref, sq_ref):
    _clip_reduce_body(clip, x_ref, None, w_ref, o_ref, sq_ref)


def _clip_reduce_noise_kernel(clip, w_ref, x_ref, n_ref, o_ref, sq_ref):
    _clip_reduce_body(clip, x_ref, n_ref, w_ref, o_ref, sq_ref)


def clip_reduce_flat(stacked: jnp.ndarray, weights: jnp.ndarray, *,
                     clip: float, noise: jnp.ndarray | None = None,
                     block: int = DEFAULT_BLOCK,
                     interpret: bool | None = None) -> jnp.ndarray:
    """stacked (C, P) deltas, weights (C,), optional presampled σ-scaled
    noise (C, P) -> (P,):  Σ_c w_c · (d_c · min(1, clip/‖d_c‖₂) + n_c),
    the DP-FedAvg reduction, in one fused launch (DESIGN.md §9)."""
    if interpret is None:
        interpret = interpret_default()
    if clip <= 0.0:
        raise ValueError(f"clip={clip} must be > 0 (clip_norm == 0 means "
                         "the privacy pipeline is disabled — callers must "
                         "not reach the kernel)")
    c, p = stacked.shape
    stacked, pp = _pad_cols(stacked, block)
    nb = pp // block
    w2 = weights.reshape(c, 1).astype(jnp.float32)

    in_specs = [
        pl.BlockSpec((c, 1), lambda ph, i: (0, 0)),
        pl.BlockSpec((c, block), lambda ph, i: (0, i)),
    ]
    operands = [w2, stacked]
    if noise is not None:
        noise, _ = _pad_cols(noise, block)
        # ph * i pins the noise to block 0 during the norm sweep (where
        # the kernel never reads it) so it streams HBM once, in sweep 1
        in_specs.append(pl.BlockSpec((c, block), lambda ph, i: (0, ph * i)))
        operands.append(noise)
        kernel = functools.partial(_clip_reduce_noise_kernel, clip)
    else:
        kernel = functools.partial(_clip_reduce_kernel, clip)

    out = pl.pallas_call(
        kernel,
        grid=(2, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block), lambda ph, i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, pp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((c, 1), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out[0, :p]


def _quant_clip_reduce_kernel(clip, has_noise, has_resid, has_uniform,
                              w_ref, x_ref, *rest):
    """Multi-sweep quantized-transport body (DESIGN.md §10).

    Sweeps (clip > 0 adds the leading norm sweep):
      [norm]  sq_c   += Σ_p x²           (the DP clip needs ‖x_c‖₂)
      absmax  amax_c  = max(amax_c, max_p |d̃_c|)   d̃ recomputed on the fly
      quant   t = dequant(Q(d̃)); out += Σ_c w_c t; resid' = d̃ − t

    Operand layout in ``rest`` (presence is static):
      [noise] [resid] [uniform] out [resid'] scratch: [sq] amax
    """
    rest = list(rest)
    n_ref = rest.pop(0) if has_noise else None
    r_ref = rest.pop(0) if has_resid else None
    u_ref = rest.pop(0) if has_uniform else None
    o_ref = rest.pop(0)
    er_ref = rest.pop(0) if has_resid else None
    sq_ref = rest.pop(0) if clip > 0.0 else None
    amax_ref = rest.pop(0)

    nph = 3 if clip > 0.0 else 2
    ph = pl.program_id(0)
    i = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)  # (C, bp)

    @pl.when((ph == nph - 2) & (i == 0))
    def _init_amax():
        amax_ref[...] = jnp.zeros_like(amax_ref)

    if clip > 0.0:
        @pl.when((ph == 0) & (i == 0))
        def _init_norms():
            sq_ref[...] = jnp.zeros_like(sq_ref)

        @pl.when(ph == 0)
        def _accumulate_norms():
            sq_ref[...] += jnp.sum(x * x, axis=1, keepdims=True)

    def released():
        """The codec input d̃ for this tile: DP release (clip + noise)
        then the EF residual add — recomputed per sweep so no (C, P)
        intermediate ever reaches HBM."""
        y = x
        if clip > 0.0:
            norm = jnp.sqrt(sq_ref[...])  # (C, 1)
            y = y * jnp.minimum(1.0, clip / jnp.maximum(norm, _NORM_FLOOR))
            if has_noise:
                y = y + n_ref[...].astype(jnp.float32)
        if has_resid:
            y = y + r_ref[...].astype(jnp.float32)
        return y

    @pl.when(ph == nph - 2)
    def _accumulate_absmax():
        y = released()
        amax_ref[...] = jnp.maximum(
            amax_ref[...], jnp.max(jnp.abs(y), axis=1, keepdims=True))

    @pl.when(ph == nph - 1)
    def _quantize_and_reduce():
        w = w_ref[...].astype(jnp.float32)  # (C, 1)
        y = released()
        s = jnp.maximum(amax_ref[...] / INT8_LEVELS, _SCALE_FLOOR)
        z = y / s
        if has_uniform:  # stochastic rounding from the presampled tile
            q = jnp.floor(z + u_ref[...].astype(jnp.float32))
        else:
            q = jnp.round(z)
        t = jnp.clip(q, -INT8_LEVELS, INT8_LEVELS) * s
        o_ref[...] = jnp.sum(w * t, axis=0, keepdims=True).astype(
            o_ref.dtype)
        if has_resid:
            er_ref[...] = (y - t).astype(er_ref.dtype)


def quant_clip_reduce_flat(stacked: jnp.ndarray, weights: jnp.ndarray, *,
                           clip: float = 0.0,
                           noise: jnp.ndarray | None = None,
                           uniform: jnp.ndarray | None = None,
                           resid: jnp.ndarray | None = None,
                           block: int = DEFAULT_BLOCK,
                           interpret: bool | None = None
                           ) -> tuple[jnp.ndarray, jnp.ndarray | None]:
    """Fused DP-release + int8 quantized transport + weighted reduce.

    stacked (C, P) raw deltas, weights (C,), optional presampled
    σ-scaled noise (C, P), optional presampled U[0,1) rounding tile
    (C, P), optional EF residual (C, P) ->
    (Σ_c w_c · dequant(Q(d̃_c)) of shape (P,), new residual or None)
    where d̃_c = clip/noise release of d_c plus the carried residual.
    One launch; (3, nb) grid with the clip on, (2, nb) otherwise
    (DESIGN.md §10).
    """
    if interpret is None:
        interpret = interpret_default()
    if noise is not None and clip <= 0.0:
        raise ValueError("noise requires clip > 0 (the DP release scales "
                         "noise by the clip bound; see PrivacyConfig)")
    c, p = stacked.shape
    stacked, pp = _pad_cols(stacked, block)
    nb = pp // block
    nph = 3 if clip > 0.0 else 2
    w2 = weights.reshape(c, 1).astype(jnp.float32)

    in_specs = [
        pl.BlockSpec((c, 1), lambda ph, i: (0, 0)),
        pl.BlockSpec((c, block), lambda ph, i: (0, i)),
    ]
    operands = [w2, stacked]
    # operands not consumed by every sweep pin to block 0 on the sweeps
    # that skip them, so each streams HBM only when read:
    #   noise/resid — the absmax + quantize sweeps (the last two);
    #   uniform     — the quantize sweep only.
    last_two = lambda ph, i: (0, ((ph + 1) // 2) * i)  # noqa: E731
    last_one = lambda ph, i: (0, (ph // (nph - 1)) * i)  # noqa: E731
    if noise is not None:
        operands.append(_pad_cols(noise, block)[0])
        in_specs.append(pl.BlockSpec((c, block), last_two))
    if resid is not None:
        operands.append(_pad_cols(resid.astype(jnp.float32), block)[0])
        in_specs.append(pl.BlockSpec(
            (c, block), last_two if nph == 3 else (lambda ph, i: (0, i))))
    if uniform is not None:
        operands.append(_pad_cols(uniform, block)[0])
        in_specs.append(pl.BlockSpec((c, block), last_one))

    out_specs = [pl.BlockSpec((1, block), lambda ph, i: (0, i))]
    out_shape = [jax.ShapeDtypeStruct((1, pp), jnp.float32)]
    if resid is not None:
        out_specs.append(pl.BlockSpec((c, block), lambda ph, i: (0, i)))
        out_shape.append(jax.ShapeDtypeStruct((c, pp), jnp.float32))

    scratch = []
    if clip > 0.0:
        scratch.append(pltpu.VMEM((c, 1), jnp.float32))
    scratch.append(pltpu.VMEM((c, 1), jnp.float32))

    kernel = functools.partial(
        _quant_clip_reduce_kernel, clip, noise is not None,
        resid is not None, uniform is not None)
    outs = pl.pallas_call(
        kernel,
        grid=(nph, nb),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(*operands)
    if resid is not None:
        return outs[0][0, :p], outs[1][:, :p]
    return outs[0][0, :p], None


def _topk_kernel(has_resid, w_ref, x_ref, t_ref, o_ref, *maybe_er):
    x = x_ref[...].astype(jnp.float32)  # (C, bp)
    w = w_ref[...].astype(jnp.float32)  # (C, 1)
    tau = t_ref[...].astype(jnp.float32)  # (C, 1)
    t = jnp.where(jnp.abs(x) >= tau, x, 0.0)
    o_ref[...] = jnp.sum(w * t, axis=0, keepdims=True).astype(o_ref.dtype)
    if has_resid:
        maybe_er[0][...] = (x - t).astype(maybe_er[0].dtype)


def topk_reduce_flat(stacked: jnp.ndarray, weights: jnp.ndarray,
                     thresholds: jnp.ndarray, *, with_residual: bool = False,
                     block: int = DEFAULT_BLOCK,
                     interpret: bool | None = None
                     ) -> tuple[jnp.ndarray, jnp.ndarray | None]:
    """Top-k threshold/scatter + weighted reduce (DESIGN.md §10).

    stacked (C, P) codec inputs d̃ (already released + EF-accumulated),
    weights (C,), thresholds (C,) — the k-th largest |d̃_c| per client —
    -> (Σ_c w_c · t_c of shape (P,), d̃ − t or None) with
    t_c = d̃_c masked where |d̃_c| < τ_c (threshold ties are kept). One
    (nb,) sweep; padded columns are zeros and survive any τ ≥ 0 with
    value 0, so they never perturb the reduce.
    """
    if interpret is None:
        interpret = interpret_default()
    c, p = stacked.shape
    stacked, pp = _pad_cols(stacked.astype(jnp.float32), block)
    nb = pp // block
    w2 = weights.reshape(c, 1).astype(jnp.float32)
    t2 = thresholds.reshape(c, 1).astype(jnp.float32)

    out_specs = [pl.BlockSpec((1, block), lambda i: (0, i))]
    out_shape = [jax.ShapeDtypeStruct((1, pp), jnp.float32)]
    if with_residual:
        out_specs.append(pl.BlockSpec((c, block), lambda i: (0, i)))
        out_shape.append(jax.ShapeDtypeStruct((c, pp), jnp.float32))

    outs = pl.pallas_call(
        functools.partial(_topk_kernel, with_residual),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((c, 1), lambda i: (0, 0)),
            pl.BlockSpec((c, block), lambda i: (0, i)),
            pl.BlockSpec((c, 1), lambda i: (0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[],
        interpret=interpret,
    )(w2, stacked, t2)
    if with_residual:
        return outs[0][0, :p], outs[1][:, :p]
    return outs[0][0, :p], None


def _trim_kernel(k, w_ref, x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # (C, bp)
    w = w_ref[...].astype(jnp.float32)  # (C, 1)
    c = x.shape[0]
    row_ids = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    num = jnp.zeros((1, x.shape[1]), jnp.float32)
    den = jnp.zeros((1, x.shape[1]), jnp.float32)
    for ci in range(c):  # static unroll over the (small) client axis
        xc = x[ci:ci + 1, :]  # (1, bp)
        # stable rank of client ci per coordinate: strictly-smaller
        # values, plus equal values from lower client indices
        before = (x < xc) | ((x == xc) & (row_ids < ci))
        rank = jnp.sum(before.astype(jnp.int32), axis=0, keepdims=True)
        keep = ((rank >= k) & (rank < c - k)).astype(jnp.float32)
        num += keep * w[ci, 0] * xc
        den += keep * w[ci, 0]
    o_ref[...] = (num / den).astype(o_ref.dtype)


def trimmed_reduce_flat(stacked: jnp.ndarray, weights: jnp.ndarray, *,
                        trim: int, block: int = DEFAULT_BLOCK,
                        interpret: bool | None = None) -> jnp.ndarray:
    """stacked (C, P) deltas, weights (C,) -> (P,): per-coordinate
    rank-trimmed weighted mean, ``trim`` clients dropped at each end."""
    if interpret is None:
        interpret = interpret_default()
    c, p = stacked.shape
    if not 0 <= 2 * trim < c:
        raise ValueError(f"trim={trim} must satisfy 0 <= 2*trim < C={c}")
    stacked, pp = _pad_cols(stacked, block)
    nb = pp // block
    w2 = weights.reshape(c, 1).astype(jnp.float32)

    out = pl.pallas_call(
        functools.partial(_trim_kernel, trim),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((c, 1), lambda i: (0, 0)),
            pl.BlockSpec((c, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, pp), stacked.dtype),
        interpret=interpret,
    )(w2, stacked)
    return out[0, :p]


def _pairwise_kernel(x_ref, o_ref):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)  # (C, bp)
    sq = jnp.sum(x * x, axis=1)  # (C,)
    part = sq[:, None] + sq[None, :] - 2.0 * jnp.dot(
        x, x.T, preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part.astype(o_ref.dtype)


def pairwise_dists_flat(stacked: jnp.ndarray, *,
                        block: int = DEFAULT_BLOCK,
                        interpret: bool | None = None) -> jnp.ndarray:
    """stacked (C, P) deltas -> (C, C) pairwise SQUARED L2 distances —
    the Krum/multi-Krum selection metric (DESIGN.md §13).

    The (C, C) Gram-style output is tiny (clients are tens, not
    thousands) and pins at block (0, 0) across the whole (nb,) sweep;
    each grid step streams one (C, bp) tile of the flattened parameter
    axis and accumulates the expansion form ‖x_i‖² + ‖x_j‖² − 2·x_i·x_j
    via one (C, bp) × (bp, C) matmul — the full P-axis never sits in
    VMEM, and HBM is read exactly once. Padded columns are zeros, so
    they add 0 to every entry. Accumulated float error can push an
    entry infinitesimally negative; the wrapper clamps at 0 (distances
    are provably non-negative), keeping downstream sqrt/sort sane.
    """
    if interpret is None:
        interpret = interpret_default()
    c, p = stacked.shape
    stacked, pp = _pad_cols(stacked.astype(jnp.float32), block)
    nb = pp // block

    out = pl.pallas_call(
        _pairwise_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((c, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((c, c), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, c), jnp.float32),
        interpret=interpret,
    )(stacked)
    return jnp.maximum(out, 0.0)
