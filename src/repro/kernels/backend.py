"""Single definition of the interpret-mode default shared by every
Pallas wrapper: interpret on CPU (the validation path), native on TPU.
"""
from __future__ import annotations

import jax


def interpret_default() -> bool:
    return jax.default_backend() != "tpu"
