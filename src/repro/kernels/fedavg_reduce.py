"""DEPRECATED module: folded into ``repro.kernels.agg_reduce`` so the
server-aggregation kernels live as one family with one oracle module
(kernels/ref.py). Import ``fedavg_reduce_flat`` from
``repro.kernels.agg_reduce`` (or use the jit'd ``fedavg_reduce`` wrapper
from ``repro.kernels``); this re-export keeps
``from repro.kernels import fedavg_reduce`` and direct imports of this
module working.
"""
from __future__ import annotations

from repro.kernels.agg_reduce import (  # noqa: F401
    DEFAULT_BLOCK,
    fedavg_reduce_flat,
)
