"""FedAvg weighted-reduction Pallas kernel — Eq. 3 as a fused kernel.

theta^{t+1}[p] = sum_c w[c] * theta_c[p], tiled over the flattened
parameter axis. Bandwidth-bound by design: each tile streams (C, bp)
client parameters HBM -> VMEM once and writes (1, bp) back — arithmetic
intensity C MACs / (C+1) elements, i.e. the kernel runs at HBM speed,
which is the roofline for aggregation. On hardware this is the epilogue
fused after the cross-client reduce-scatter (DESIGN.md §4); weights sit
in SMEM-resident (C, 1) tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import interpret_default

DEFAULT_BLOCK = 2048


def _fedavg_kernel(w_ref, x_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)  # (C, 1)
    x = x_ref[...].astype(jnp.float32)  # (C, bp)
    o_ref[...] = jnp.sum(w * x, axis=0, keepdims=True).astype(o_ref.dtype)


def fedavg_reduce_flat(stacked: jnp.ndarray, weights: jnp.ndarray, *,
                       block: int = DEFAULT_BLOCK,
                       interpret: bool | None = None) -> jnp.ndarray:
    """stacked (C, P), weights (C,) -> (P,). P is padded to ``block``.

    ``interpret`` defaults to the backend (interpret on CPU, native on
    TPU), matching the ``ops.py`` wrappers, so direct callers never
    silently run interpret mode on hardware.
    """
    if interpret is None:
        interpret = interpret_default()
    c, p = stacked.shape
    pad = (-p) % block
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
    pp = p + pad
    nb = pp // block
    w2 = weights.reshape(c, 1).astype(jnp.float32)

    out = pl.pallas_call(
        _fedavg_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((c, 1), lambda i: (0, 0)),
            pl.BlockSpec((c, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, pp), stacked.dtype),
        interpret=interpret,
    )(w2, stacked)
    return out[0, :p]
