"""Flash attention Pallas TPU kernel.

Online-softmax attention with:
  * causal masking,
  * optional sliding window (gemma2/3 local layers; the long_500k dense
    variant),
  * optional logit softcapping (gemma2), fused before max/exp,
  * GQA via index-mapped KV BlockSpecs — the repeated KV heads are never
    materialized in HBM; each q-head grid row maps to its kv head.

Grid: (batch*q_heads, q_blocks, k_blocks), k innermost ("arbitrary"
semantics) carrying the online-softmax state (m, l, acc) in VMEM scratch.
Fully-masked k-blocks are skipped with @pl.when — for a window of W only
ceil(W/bk)+1 k-blocks per q-block do work, which is what makes the SWA
variant sub-quadratic on TPU.

Target: TPU v5e (128x128 MXU tiles). Validated with interpret=True on CPU
against kernels/ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import interpret_default

NEG_INF = -1e30
DEFAULT_BQ = 128
DEFAULT_BK = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int,
                  softcap: float | None, num_kb: int, bq: int, bk: int):
    i_q = pl.program_id(1)
    i_k = pl.program_id(2)

    @pl.when(i_k == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-level relevance: skip k-blocks fully outside the mask
    q_start = i_q * bq
    k_start = i_k * bk
    relevant = True
    if causal:
        relevant = k_start <= q_start + bq - 1
    if window > 0:
        # newest allowed key for the oldest query row: q_start - window + 1
        relevant = jnp.logical_and(relevant, k_start + bk > q_start - window + 1)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)  # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window > 0:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot(p.astype(v.dtype), v))
        m_ref[...] = m_new

    @pl.when(i_k == num_kb - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         softcap: float | None = None,
                         bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                         interpret: bool | None = None):
    """q (B, H, S, hd); k/v (B, KV, S, hd) -> (B, H, S, hd).

    S must be a multiple of the block sizes (ops.flash_attention pads).
    ``interpret`` defaults to the backend (interpret on CPU, native on
    TPU) so direct callers never silently run interpret mode on hardware.
    """
    if interpret is None:
        interpret = interpret_default()
    b, h, s, hd = q.shape
    kv = k.shape[1]
    assert h % kv == 0, (h, kv)
    g = h // kv
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    num_qb, num_kb = s // bq, s // bk
    scale = 1.0 / (hd ** 0.5)

    qf = q.reshape(b * h, s, hd)
    kf = k.reshape(b * kv, s, hd)
    vf = v.reshape(b * kv, s, hd)

    def q_map(i, j, t):
        return (i, j, 0)

    def kv_map(i, j, t):
        bb = i // h
        hh = i % h
        return (bb * kv + hh // g, t, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, num_kb=num_kb, bq=bq, bk=bk)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, num_qb, num_kb),
        in_specs=[
            pl.BlockSpec((1, bq, hd), q_map),
            pl.BlockSpec((1, bk, hd), kv_map),
            pl.BlockSpec((1, bk, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, s, hd), q.dtype),
        scratch_shapes=[
            # (m, l, acc) online-softmax carries, persist across the k grid
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
        if not interpret else None,
    )(qf, kf, vf)
    return out.reshape(b, h, s, hd)
