"""GPO neural-process attention Pallas kernel — the paper's hot spot.

The preference predictor's mask is irregular for a causal flash kernel:
  * context tokens (first m) attend to all context tokens,
  * target tokens attend to context tokens AND themselves only.

TPU-native design (DESIGN.md §4): block the (q, k) plane into MXU-aligned
tiles. The default *banded* grid is ``(h, num_qb, ctx_blocks + 1)``: for
every q-row of tiles the kernel walks only the k-tiles that contain
context columns, plus one final k-step that maps onto the diagonal tile
(target self-attention). The O(S*m + S) work claim therefore holds at the
grid level — the kernel never visits (and never DMAs) the off-diagonal
target×target tiles at all, instead of iterating the full O(S^2/b^2) grid
and predicating tiles away with ``@pl.when`` (the legacy ``banded=False``
grid, kept for A/B benchmarking).

num_ctx is static (it is part of the training configuration, Eq. 1), so
``ctx_blocks`` and the banded grid shape fold at trace time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import interpret_default

NEG_INF = -1e30


def _online_softmax_update(s, v, m_ref, l_ref, acc_ref):
    """One flash-attention accumulator update with scores ``s`` (bq, bk)."""
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jax.lax.dot(p.astype(v.dtype), v))
    m_ref[...] = m_new


def _gpo_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                scale: float, num_ctx: int, num_kb: int, bq: int, bk: int):
    """Legacy full grid (h, num_qb, num_kb): every target×target tile is
    visited and skipped with @pl.when — O(S^2/b^2) grid steps."""
    i_q = pl.program_id(1)
    i_k = pl.program_id(2)

    @pl.when(i_k == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start, k_start = i_q * bq, i_k * bk
    # a (q, k) tile is relevant iff it contains context columns or touches
    # the diagonal (target self-attention)
    has_ctx_cols = k_start < num_ctx
    touches_diag = jnp.logical_and(k_start < q_start + bq,
                                   q_start < k_start + bk)
    relevant = jnp.logical_or(has_ctx_cols, touches_diag)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        # neural-process mask: key is context, or key == query (self)
        mask = jnp.logical_or(k_pos < num_ctx, k_pos == q_pos)
        s = jnp.where(mask, s, NEG_INF)
        _online_softmax_update(s, v, m_ref, l_ref, acc_ref)

    @pl.when(i_k == num_kb - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _gpo_kernel_banded(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                       scale: float, num_ctx: int, ctx_blocks: int, bq: int,
                       bk: int):
    """Banded grid (h, num_qb, ctx_blocks + 1); requires bq == bk.

    k-steps t < ctx_blocks stream the context band; the last step
    (t == ctx_blocks) is mapped by the BlockSpec index_map onto the
    diagonal tile of this q-row. When the diagonal tile already lies
    inside the context band (i_q < ctx_blocks) the last step is a
    duplicate visit and only the finalize runs.
    """
    i_q = pl.program_id(1)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    is_diag_step = t == ctx_blocks
    kb = jnp.where(is_diag_step, i_q, t)  # mirrors the kv index_map
    q_start, k_start = i_q * bq, kb * bk
    # skip the diagonal step when the tile was already accumulated as a
    # context step (its k-block index is < ctx_blocks)
    fresh = jnp.logical_or(jnp.logical_not(is_diag_step), i_q >= ctx_blocks)

    @pl.when(fresh)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.logical_or(k_pos < num_ctx, k_pos == q_pos)
        s = jnp.where(mask, s, NEG_INF)
        _online_softmax_update(s, v, m_ref, l_ref, acc_ref)

    @pl.when(t == ctx_blocks)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _banded_ctx_blocks(num_ctx: int, bk: int, num_kb: int) -> int | None:
    """k-blocks of the context band, or None when the band saturates the
    grid (banded would add a duplicate diagonal step per q-row, so the
    full grid is used instead). Single source of truth for the kernel
    wrapper and gpo_tile_counts."""
    ctx_blocks = min(-(-num_ctx // bk), num_kb)
    return ctx_blocks if ctx_blocks < num_kb else None


def gpo_tile_counts(s: int, num_ctx: int, bq: int, bk: int) -> tuple[int, int]:
    """(banded_tiles, full_grid_tiles) per head for a given shape —
    the grid-level work ratio reported by benchmarks/bench_round.py."""
    num_qb, num_kb = s // bq, s // bk
    ctx_blocks = _banded_ctx_blocks(num_ctx, bk, num_kb)
    banded = num_qb * (ctx_blocks + 1 if ctx_blocks is not None else num_kb)
    return banded, num_qb * num_kb


def gpo_attention_hsd(q, k, v, *, num_ctx: int, bq: int = 128, bk: int = 128,
                      interpret: bool | None = None, banded: bool = True):
    """q, k, v (H, S, hd) -> (H, S, hd) with the neural-process mask.

    S must be a multiple of the block sizes (ops.gpo_attention pads). The
    banded grid requires bq == bk (the wrapper falls back to the full
    grid otherwise). ``interpret`` defaults to the backend (interpret on
    CPU, native on TPU) so direct callers never silently run interpret
    mode on hardware.
    """
    if interpret is None:
        interpret = interpret_default()
    h, s, hd = q.shape
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    num_qb, num_kb = s // bq, s // bk
    scale = 1.0 / (hd ** 0.5)

    def idx(i, j, t):
        return (i, j, 0)

    if banded:
        assert bq == bk, "banded grid requires square tiles"
        ctx_blocks = _banded_ctx_blocks(num_ctx, bk, num_kb)
        banded = ctx_blocks is not None
    if banded:
        grid = (h, num_qb, ctx_blocks + 1)
        kernel = functools.partial(_gpo_kernel_banded, scale=scale,
                                   num_ctx=num_ctx, ctx_blocks=ctx_blocks,
                                   bq=bq, bk=bk)

        def kv_idx(i, j, t):
            # last k-step -> this q-row's diagonal tile; earlier steps
            # walk the context band left-to-right
            return (i, jnp.where(t == ctx_blocks, j, t), 0)
    else:
        grid = (h, num_qb, num_kb)
        kernel = functools.partial(_gpo_kernel, scale=scale, num_ctx=num_ctx,
                                   num_kb=num_kb, bq=bq, bk=bk)

        def kv_idx(i, j, t):
            return (i, t, 0)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), idx),
            pl.BlockSpec((1, bk, hd), kv_idx),
            pl.BlockSpec((1, bk, hd), kv_idx),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), idx),
        out_shape=jax.ShapeDtypeStruct((h, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
        if not interpret else None,
    )(q, k, v)
