"""GPO neural-process attention Pallas kernels — the paper's hot spot,
differentiable end-to-end (DESIGN.md §4, §8).

The preference predictor's mask is irregular for a causal flash kernel:
  * context tokens (first m) attend to all context tokens,
  * target tokens attend to context tokens AND themselves only.

TPU-native design (DESIGN.md §4): block the (q, k) plane into MXU-aligned
tiles. The default *banded* grid is ``(h, num_qb, ctx_blocks + 1)``: for
every q-row of tiles the kernel walks only the k-tiles that contain
context columns, plus one final k-step that maps onto the diagonal tile
(target self-attention). The O(S*m + S) work claim therefore holds at the
grid level — the kernel never visits (and never DMAs) the off-diagonal
target×target tiles at all, instead of iterating the full O(S^2/b^2) grid
and predicating tiles away with ``@pl.when`` (the legacy ``banded=False``
grid, kept for A/B benchmarking).

num_ctx is static (it is part of the training configuration, Eq. 1), so
``ctx_blocks`` and the banded grid shape fold at trace time.

Training hot path (DESIGN.md §8): ``gpo_attention_hsd`` carries a
``custom_vjp`` so ``gpo_loss`` under ``jax.grad`` stays on the tiled
band. The forward kernel residualizes ``(o, lse)`` — per-row logsumexp
stats instead of the (h, S, S) probability tensor — and the backward
pass is a ``delta = rowsum(do * o)`` preprocessing step plus two Pallas
kernels that recompute tile scores from q/k on the fly:

  * **dq** on the forward's banded grid ``(h, num_qb, ctx_blocks + 1)``
    — each q-row accumulates over its band's k-tiles;
  * **dk/dv** on the transposed band, flattened to
    ``(h, ctx_blocks*num_qb + (num_kb - ctx_blocks))`` — context k-tiles
    sweep every q-tile (all rows attend context), pure-target k-tiles
    visit only their diagonal q-tile (self-attention is their sole
    consumer).

No O(S^2)-sized tensor is ever materialized in either direction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import interpret_default

NEG_INF = -1e30


def _np_tile_mask(q_start, k_start, num_ctx: int, bq: int, bk: int):
    """Neural-process mask for one (bq, bk) tile: key is context, or
    key == query (target self-attention)."""
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    return jnp.logical_or(k_pos < num_ctx, k_pos == q_pos)


def _tile_relevant(q_start, k_start, num_ctx: int, bq: int, bk: int):
    """A (q, k) tile is relevant iff it contains context columns or
    touches the diagonal (target self-attention)."""
    return jnp.logical_or(
        k_start < num_ctx,
        jnp.logical_and(k_start < q_start + bq, q_start < k_start + bk))


def _k_step_schedule(i_q, t, *, num_ctx: int, ctx_blocks: int | None,
                     num_kb: int, bq: int, bk: int):
    """(k_start, compute, last) for grid step (q-row i_q, k-step t) —
    the single definition of the per-step schedule shared by the forward
    and dq kernels (their grids MUST agree for gradients to be correct).

    Full grid (``ctx_blocks is None``): k-steps walk every k-tile and
    irrelevant target×target tiles are predicated off. Banded grid:
    k-steps t < ctx_blocks stream the context band, the last step maps
    onto this q-row's diagonal tile, and that step is skipped when the
    diagonal tile was already accumulated as a context step.
    """
    q_start = i_q * bq
    if ctx_blocks is None:
        k_start = t * bk
        compute = _tile_relevant(q_start, k_start, num_ctx, bq, bk)
        last = num_kb - 1
    else:
        kb = jnp.where(t == ctx_blocks, i_q, t)  # mirrors the kv index_map
        k_start = kb * bk
        compute = jnp.logical_or(t != ctx_blocks, i_q >= ctx_blocks)
        last = ctx_blocks
    return k_start, compute, last


def _banded_grid_specs(h: int, num_qb: int, num_kb: int,
                       ctx_blocks: int | None):
    """(grid, kv_idx) for the forward/dq pallas_calls — the one place
    the (h, num_qb, k-steps) grid and its kv BlockSpec index_map are
    built, so forward and backward can never drift apart."""
    if ctx_blocks is not None:
        grid = (h, num_qb, ctx_blocks + 1)

        def kv_idx(i, j, t):
            # last k-step -> this q-row's diagonal tile; earlier steps
            # walk the context band left-to-right
            return (i, jnp.where(t == ctx_blocks, j, t), 0)
    else:
        grid = (h, num_qb, num_kb)

        def kv_idx(i, j, t):
            return (i, t, 0)

    return grid, kv_idx


# ---------------------------------------------------------------------------
# Forward: online softmax, residualizing (o, lse)
# ---------------------------------------------------------------------------
def _gpo_fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref,
                    acc_ref, *, scale: float, num_ctx: int,
                    ctx_blocks: int | None, num_kb: int, bq: int, bk: int):
    """Forward kernel for both grids.

    ``ctx_blocks is None`` — legacy full grid (h, num_qb, num_kb): every
    target×target tile is visited and skipped with @pl.when (O(S^2/b^2)
    grid steps). Otherwise — banded grid (h, num_qb, ctx_blocks + 1);
    k-steps t < ctx_blocks stream the context band and the last step
    (t == ctx_blocks) is mapped by the BlockSpec index_map onto the
    diagonal tile of this q-row; when the diagonal tile already lies
    inside the context band (i_q < ctx_blocks) that step is a duplicate
    visit and only the finalize runs.

    Besides ``o`` the kernel emits the per-row logsumexp ``lse`` — the
    backward residual (DESIGN.md §8) that replaces the (h, S, S)
    probability tensor.
    """
    i_q = pl.program_id(1)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = i_q * bq
    k_start, compute, last = _k_step_schedule(
        i_q, t, num_ctx=num_ctx, ctx_blocks=ctx_blocks, num_kb=num_kb,
        bq=bq, bk=bk)

    @pl.when(compute)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        s = jnp.where(_np_tile_mask(q_start, k_start, num_ctx, bq, bk), s,
                      NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot(p.astype(v.dtype), v))
        m_ref[...] = m_new

    @pl.when(t == last)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_ref[...] + jnp.log(l)


def _gpo_forward(q, k, v, *, num_ctx: int, bq: int, bk: int, interpret: bool,
                 banded: bool):
    """(o (h, s, hd), lse (h, s) f32). ``banded`` must be pre-resolved
    (bq == bk and the band does not saturate the grid)."""
    h, s, hd = q.shape
    num_qb, num_kb = s // bq, s // bk
    scale = 1.0 / (hd ** 0.5)
    ctx_blocks = _banded_ctx_blocks(num_ctx, bk, num_kb) if banded else None
    grid, kv_idx = _banded_grid_specs(h, num_qb, num_kb, ctx_blocks)

    def idx(i, j, t):
        return (i, j, 0)

    def row_idx(i, j, t):
        return (i, j)

    kernel = functools.partial(_gpo_fwd_kernel, scale=scale, num_ctx=num_ctx,
                               ctx_blocks=ctx_blocks, num_kb=num_kb, bq=bq,
                               bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), idx),
            pl.BlockSpec((1, bk, hd), kv_idx),
            pl.BlockSpec((1, bk, hd), kv_idx),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, hd), idx),
            pl.BlockSpec((1, bq), row_idx),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, s, hd), q.dtype),
            jax.ShapeDtypeStruct((h, s), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
        if not interpret else None,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Backward: dq on the forward's banded grid; dk/dv on the transposed band
# ---------------------------------------------------------------------------
def _gpo_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                       dq_ref, acc_ref, *, scale: float, num_ctx: int,
                       ctx_blocks: int | None, num_kb: int, bq: int, bk: int):
    """dq accumulation over this q-row's k-tiles; same grid and k-step
    schedule (band + diagonal, duplicate-diagonal skip) as the forward.
    Tile scores are recomputed from q/k; probabilities come back from the
    residualized lse (p = exp(s - lse)), never from memory."""
    i_q = pl.program_id(1)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = i_q * bq
    k_start, compute, last = _k_step_schedule(
        i_q, t, num_ctx=num_ctx, ctx_blocks=ctx_blocks, num_kb=num_kb,
        bq=bq, bk=bk)

    @pl.when(compute)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        s = jnp.where(_np_tile_mask(q_start, k_start, num_ctx, bq, bk), s,
                      NEG_INF)
        p = jnp.exp(s - lse_ref[0][:, None])  # masked entries -> exactly 0
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))  # (bq, bk)
        ds = p * (dp - delta_ref[0][:, None]) * scale
        acc_ref[...] = acc_ref[...] + jax.lax.dot(ds, k)

    @pl.when(t == last)
    def _finalize():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _gpo_bwd_dkdv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         dk_ref, dv_ref, dk_acc, dv_acc, *, scale: float,
                         num_ctx: int, ctx_blocks: int | None, num_qb: int,
                         bq: int, bk: int):
    """dk/dv accumulation per k-tile over the q-tiles that attend it.

    The grid's second dimension is the *flattened* transposed band:
    steps t < ctx_blocks*num_qb sweep (k-tile j = t // num_qb,
    q-tile t % num_qb) — context keys are read by every q-row — and the
    remaining num_kb - ctx_blocks steps visit each pure-target k-tile's
    diagonal q-tile only (one step per tile: init, accumulate and
    finalize together). k-tile index is non-decreasing in t, so the
    (bk, hd) accumulators carry across exactly the steps of one k-tile.
    ``ctx_blocks is None`` flattens the full (num_kb, num_qb) grid with
    @pl.when predication instead (the legacy A/B grid)."""
    t = pl.program_id(1)

    if ctx_blocks is None:
        j, iq = t // num_qb, t % num_qb
        first = iq == 0
        last = iq == num_qb - 1
    else:
        band_steps = ctx_blocks * num_qb
        is_band = t < band_steps
        diag = ctx_blocks + t - band_steps
        j = jnp.where(is_band, t // num_qb, diag)
        iq = jnp.where(is_band, t % num_qb, diag)
        first = jnp.logical_or(~is_band, t % num_qb == 0)
        last = jnp.logical_or(~is_band, t % num_qb == num_qb - 1)
    q_start, k_start = iq * bq, j * bk

    @pl.when(first)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    def _accumulate():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        s = jnp.where(_np_tile_mask(q_start, k_start, num_ctx, bq, bk), s,
                      NEG_INF)
        p = jnp.exp(s - lse_ref[0][:, None])  # (bq, bk)
        # dv += p^T do ; ds = p * (dp - delta) ; dk += ds^T q
        dv_acc[...] = dv_acc[...] + jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())))
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - delta_ref[0][:, None]) * scale
        dk_acc[...] = dk_acc[...] + jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())))

    if ctx_blocks is None:
        # full grid: predicate away irrelevant (k, q) tiles
        pl.when(_tile_relevant(q_start, k_start, num_ctx, bq, bk))(
            _accumulate)
    else:
        _accumulate()  # every banded step is relevant by construction

    @pl.when(last)
    def _finalize():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def _gpo_backward(q, k, v, do, lse, delta, *, num_ctx: int, bq: int, bk: int,
                  interpret: bool, banded: bool):
    """(dq, dk, dv) via the two banded backward kernels."""
    h, s, hd = q.shape
    num_qb, num_kb = s // bq, s // bk
    scale = 1.0 / (hd ** 0.5)
    ctx_blocks = _banded_ctx_blocks(num_ctx, bk, num_kb) if banded else None

    # ---- dq: the forward's banded grid --------------------------------
    dq_grid, kv_idx = _banded_grid_specs(h, num_qb, num_kb, ctx_blocks)

    def idx(i, j, t):
        return (i, j, 0)

    def row_idx(i, j, t):
        return (i, j)

    dq_kernel = functools.partial(
        _gpo_bwd_dq_kernel, scale=scale, num_ctx=num_ctx,
        ctx_blocks=ctx_blocks, num_kb=num_kb, bq=bq, bk=bk)
    dq = pl.pallas_call(
        dq_kernel,
        grid=dq_grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), idx),
            pl.BlockSpec((1, bk, hd), kv_idx),
            pl.BlockSpec((1, bk, hd), kv_idx),
            pl.BlockSpec((1, bq, hd), idx),
            pl.BlockSpec((1, bq), row_idx),
            pl.BlockSpec((1, bq), row_idx),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), idx),
        out_shape=jax.ShapeDtypeStruct((h, s, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32)],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
        if not interpret else None,
    )(q, k, v, do, lse, delta)

    # ---- dk/dv: the transposed band, flattened ------------------------
    if ctx_blocks is not None:
        steps = ctx_blocks * num_qb + (num_kb - ctx_blocks)

        def decode(t):
            band_steps = ctx_blocks * num_qb
            diag = ctx_blocks + t - band_steps
            j = jnp.where(t < band_steps, t // num_qb, diag)
            iq = jnp.where(t < band_steps, t % num_qb, diag)
            return j, iq
    else:
        steps = num_kb * num_qb

        def decode(t):
            return t // num_qb, t % num_qb

    def t_q_idx(i, t):
        return (i, decode(t)[1], 0)

    def t_kv_idx(i, t):
        return (i, decode(t)[0], 0)

    def t_row_idx(i, t):
        return (i, decode(t)[1])

    dkdv_kernel = functools.partial(
        _gpo_bwd_dkdv_kernel, scale=scale, num_ctx=num_ctx,
        ctx_blocks=ctx_blocks, num_qb=num_qb, bq=bq, bk=bk)
    dk, dv = pl.pallas_call(
        dkdv_kernel,
        grid=(h, steps),
        in_specs=[
            pl.BlockSpec((1, bq, hd), t_q_idx),
            pl.BlockSpec((1, bk, hd), t_kv_idx),
            pl.BlockSpec((1, bk, hd), t_kv_idx),
            pl.BlockSpec((1, bq, hd), t_q_idx),
            pl.BlockSpec((1, bq), t_row_idx),
            pl.BlockSpec((1, bq), t_row_idx),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, hd), t_kv_idx),
            pl.BlockSpec((1, bk, hd), t_kv_idx),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, s, hd), k.dtype),
            jax.ShapeDtypeStruct((h, s, hd), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, hd), jnp.float32),
            pltpu.VMEM((bk, hd), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
        if not interpret else None,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# custom_vjp wiring + grid accounting
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _gpo_attention(q, k, v, num_ctx, bq, bk, interpret, banded):
    o, _ = _gpo_forward(q, k, v, num_ctx=num_ctx, bq=bq, bk=bk,
                        interpret=interpret, banded=banded)
    return o


def _gpo_attention_fwd(q, k, v, num_ctx, bq, bk, interpret, banded):
    o, lse = _gpo_forward(q, k, v, num_ctx=num_ctx, bq=bq, bk=bk,
                          interpret=interpret, banded=banded)
    return o, (q, k, v, o, lse)


def _gpo_attention_bwd(num_ctx, bq, bk, interpret, banded, res, do):
    q, k, v, o, lse = res
    # preprocessing pass: delta_i = sum_d do_id * o_id = sum_j p_ij dp_ij,
    # the softmax-jacobian row term shared by every tile of row i
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    return _gpo_backward(q, k, v, do.astype(q.dtype), lse, delta,
                         num_ctx=num_ctx, bq=bq, bk=bk, interpret=interpret,
                         banded=banded)


_gpo_attention.defvjp(_gpo_attention_fwd, _gpo_attention_bwd)


def _banded_ctx_blocks(num_ctx: int, bk: int, num_kb: int) -> int | None:
    """k-blocks of the context band, or None when the band saturates the
    grid (banded would add a duplicate diagonal step per q-row, so the
    full grid is used instead). Single source of truth for the kernel
    wrappers and gpo_tile_counts."""
    ctx_blocks = min(-(-num_ctx // bk), num_kb)
    return ctx_blocks if ctx_blocks < num_kb else None


def gpo_tile_counts(s: int, num_ctx: int, bq: int, bk: int) -> tuple[int, int]:
    """(banded_tiles, full_grid_tiles) per head for a given shape —
    the grid-level work ratio reported by benchmarks/bench_round.py."""
    num_qb, num_kb = s // bq, s // bk
    ctx_blocks = _banded_ctx_blocks(num_ctx, bk, num_kb)
    banded = num_qb * (ctx_blocks + 1 if ctx_blocks is not None else num_kb)
    return banded, num_qb * num_kb


def gpo_tile_counts_bwd(s: int, num_ctx: int, bq: int,
                        bk: int) -> tuple[int, int]:
    """(banded_bwd_tiles, full_grid_bwd_tiles) per head: dq grid steps
    plus dk/dv grid steps — the backward-pass analogue of
    ``gpo_tile_counts`` reported by benchmarks (BENCH_attn.json)."""
    num_qb, num_kb = s // bq, s // bk
    ctx_blocks = _banded_ctx_blocks(num_ctx, bk, num_kb)
    full = 2 * num_qb * num_kb
    if ctx_blocks is None:
        return full, full
    dq = num_qb * (ctx_blocks + 1)
    dkdv = ctx_blocks * num_qb + (num_kb - ctx_blocks)
    return dq + dkdv, full


def gpo_attention_hsd(q, k, v, *, num_ctx: int, bq: int = 128, bk: int = 128,
                      interpret: bool | None = None, banded: bool = True):
    """q, k, v (H, S, hd) -> (H, S, hd) with the neural-process mask.

    Differentiable: a flash-style custom VJP keeps ``jax.grad`` on the
    same banded grid (DESIGN.md §8) — both round engines train through
    this kernel when ``GPOConfig.use_pallas_attention`` is set.

    S must be a multiple of the block sizes (ops.gpo_attention pads). The
    banded grid requires bq == bk (the wrapper falls back to the full
    grid otherwise). ``interpret`` defaults to the backend (interpret on
    CPU, native on TPU) so direct callers never silently run interpret
    mode on hardware.
    """
    if interpret is None:
        interpret = interpret_default()
    h, s, hd = q.shape
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    if banded:
        assert bq == bk, "banded grid requires square tiles"
        # resolve the saturated-band fallback HERE so the forward and
        # backward pallas_calls agree on the grid for this shape
        banded = _banded_ctx_blocks(num_ctx, bk, s // bk) is not None
    return _gpo_attention(q, k, v, num_ctx, bq, bk, bool(interpret), banded)
