"""GPO neural-process attention Pallas kernel — the paper's hot spot.

The preference predictor's mask is irregular for a causal flash kernel:
  * context tokens (first m) attend to all context tokens,
  * target tokens attend to context tokens AND themselves only.

TPU-native design (DESIGN.md §4): block the (q, k) plane into MXU-aligned
tiles; (target-q x target-k) tiles are *diagonal-only* — off-diagonal
target-target tiles are skipped entirely with @pl.when, so the kernel does
O(S*m + S) work instead of O(S^2) when targets dominate (the GPO regime:
t >> m at evaluation).

num_ctx is static (it is part of the training configuration, Eq. 1), so
the block-relevance predicate folds at trace time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _gpo_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                scale: float, num_ctx: int, num_kb: int, bq: int, bk: int):
    i_q = pl.program_id(1)
    i_k = pl.program_id(2)

    @pl.when(i_k == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start, k_start = i_q * bq, i_k * bk
    # a (q, k) tile is relevant iff it contains context columns or touches
    # the diagonal (target self-attention)
    has_ctx_cols = k_start < num_ctx
    touches_diag = jnp.logical_and(k_start < q_start + bq,
                                   q_start < k_start + bk)
    relevant = jnp.logical_or(has_ctx_cols, touches_diag)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        # neural-process mask: key is context, or key == query (self)
        mask = jnp.logical_or(k_pos < num_ctx, k_pos == q_pos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot(p.astype(v.dtype), v))
        m_ref[...] = m_new

    @pl.when(i_k == num_kb - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def gpo_attention_hsd(q, k, v, *, num_ctx: int, bq: int = 128, bk: int = 128,
                      interpret: bool = True):
    """q, k, v (H, S, hd) -> (H, S, hd) with the neural-process mask.

    S must be a multiple of the block sizes (ops.gpo_attention pads).
    """
    h, s, hd = q.shape
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    num_qb, num_kb = s // bq, s // bk
    scale = 1.0 / (hd ** 0.5)

    def idx(i, j, t):
        return (i, j, 0)

    def kv_idx(i, j, t):
        return (i, t, 0)

    kernel = functools.partial(_gpo_kernel, scale=scale, num_ctx=num_ctx,
                               num_kb=num_kb, bq=bq, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=(h, num_qb, num_kb),
        in_specs=[
            pl.BlockSpec((1, bq, hd), idx),
            pl.BlockSpec((1, bk, hd), kv_idx),
            pl.BlockSpec((1, bk, hd), kv_idx),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), idx),
        out_shape=jax.ShapeDtypeStruct((h, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
        if not interpret else None,
    )(q, k, v)
