"""Jit'd public wrappers around the Pallas kernels.

Handle layout (model uses (B, S, H, hd); kernels use (B, H, S, hd)),
padding to block multiples, and backend selection: on CPU the kernels run
in interpret mode (the validation path); on TPU they lower natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.agg_reduce import (
    clip_reduce_flat,
    fedavg_reduce_flat,
    momentum_reduce_flat,
    pairwise_dists_flat,
    quant_clip_reduce_flat,
    topk_reduce_flat,
    trimmed_reduce_flat,
)
from repro.kernels.backend import interpret_default as _interpret_default
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.gpo_attention import gpo_attention_hsd
from repro.kernels.quant_matmul import int8_matmul_flat
from repro.kernels.ssd_scan import ssd_scan_bhsp
from repro.utils.pytree import (
    tree_index,
    tree_ravel_clients,
    tree_unflatten_from_vector,
)


def _pad_seq(x, block, axis):
    pad = (-x.shape[axis]) % block
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float | None = None, bq: int = 128,
                    bk: int = 128, interpret: bool | None = None):
    """Model layout: q (B, S, H, hd), k/v (B, S, KV, hd) -> (B, S, H, hd)."""
    if interpret is None:
        interpret = _interpret_default()
    s_orig = q.shape[1]
    bq = min(bq, max(16, s_orig))
    bk = min(bk, max(16, s_orig))
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    qt, _ = _pad_seq(qt, bq, 2)
    # pad K/V to the q-padded length so q/k grids agree
    target = qt.shape[2]
    kt = jnp.pad(kt, ((0, 0), (0, 0), (0, target - kt.shape[2]), (0, 0)))
    vt = jnp.pad(vt, ((0, 0), (0, 0), (0, target - vt.shape[2]), (0, 0)))
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               softcap=softcap, bq=bq, bk=bk,
                               interpret=interpret)
    return out[:, :, :s_orig].transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=(
    "num_ctx", "bq", "bk", "interpret", "banded"))
def gpo_attention(q, k, v, *, num_ctx: int, bq: int = 128, bk: int = 128,
                  interpret: bool | None = None, banded: bool = True):
    """GPO layout: q/k/v (S, H, hd) -> (S, H, hd); neural-process mask.

    Differentiable: the kernel carries a flash-style custom VJP
    (DESIGN.md §8), so this wrapper is safe on the training hot path
    (``gpo_loss`` under ``jax.grad``) as well as in inference. Padding
    appends masked-out target rows (they only self-attend and their
    cotangents are zero after the slice, so real outputs and gradients
    are unaffected). ``banded`` selects the O(S*m + S) grid that only
    visits context-band + diagonal tiles (needs bq == bk; falls back to
    the full predicated grid otherwise)."""
    if interpret is None:
        interpret = _interpret_default()
    s_orig = q.shape[0]
    bq = min(bq, max(16, s_orig))
    bk = min(bk, max(16, s_orig))
    banded = banded and bq == bk
    qt = q.transpose(1, 0, 2)
    kt = k.transpose(1, 0, 2)
    vt = v.transpose(1, 0, 2)
    qt, _ = _pad_seq(qt, bq, 1)
    target = qt.shape[1]
    kt = jnp.pad(kt, ((0, 0), (0, target - kt.shape[1]), (0, 0)))
    vt = jnp.pad(vt, ((0, 0), (0, target - vt.shape[1]), (0, 0)))
    out = gpo_attention_hsd(qt, kt, vt, num_ctx=num_ctx, bq=bq, bk=bk,
                            interpret=interpret, banded=banded)
    return out[:, :s_orig].transpose(1, 0, 2)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A_log, B, C, D, *, chunk: int = 128,
             interpret: bool | None = None):
    """Model layout (same as repro.models.ssm): x (b, s, h, p), dt (b, s, h),
    B/C (b, s, n). Pads s to the chunk size with dt=0 (exact identity)."""
    if interpret is None:
        interpret = _interpret_default()
    s_orig = x.shape[1]
    chunk = min(chunk, max(16, s_orig))
    pad = (-s_orig) % chunk
    if pad:
        padf = lambda a: jnp.pad(  # noqa: E731
            a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, dt, B, C = padf(x), padf(dt), padf(B), padf(C)
    y = ssd_scan_bhsp(x, dt, A_log, B, C, D, chunk=chunk,
                      interpret=interpret)
    return y[:, :s_orig]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fedavg_reduce(stacked, weights, *, block: int = 2048,
                  interpret: bool | None = None):
    """stacked (C, P) flattened client params, weights (C,) -> (P,)."""
    if interpret is None:
        interpret = _interpret_default()
    return fedavg_reduce_flat(stacked, weights, block=block,
                              interpret=interpret)


@functools.partial(jax.jit, static_argnames=("beta", "block", "interpret"))
def agg_momentum_reduce(stacked, weights, moment, *, beta: float,
                        block: int = 2048, interpret: bool | None = None):
    """stacked (C, P) client deltas, weights (C,), moment (P,) ->
    (weighted delta moment (P,), beta*moment + delta (P,)) in one fused
    pass (the FedAvgM server update; DESIGN.md §7)."""
    if interpret is None:
        interpret = _interpret_default()
    return momentum_reduce_flat(stacked, weights, moment, beta=beta,
                                block=block, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("clip", "block", "interpret"))
def agg_clip_reduce(stacked, weights, *, clip: float, noise=None,
                    block: int = 2048, interpret: bool | None = None):
    """stacked (C, P) client deltas, weights (C,), optional presampled
    σ-scaled per-client noise (C, P) -> (P,): the fused DP-aggregation
    kernel (DESIGN.md §9) — per-client L2 norm, scale-to-clip, noise add
    and weighted accumulate in one launch. ``noise=None`` is the
    clip-only path (a distinct trace; no dummy zero matrix streams)."""
    if interpret is None:
        interpret = _interpret_default()
    return clip_reduce_flat(stacked, weights, clip=clip, noise=noise,
                            block=block, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("clip", "block", "interpret"))
def agg_quant_clip_reduce(stacked, weights, *, clip: float = 0.0,
                          noise=None, uniform=None, resid=None,
                          block: int = 2048,
                          interpret: bool | None = None):
    """stacked (C, P) raw client deltas, weights (C,), optional
    presampled σ-scaled noise (C, P), optional presampled U[0,1)
    stochastic-rounding tile (C, P), optional EF residual (C, P) ->
    (reduced (P,), new residual (C, P) | None): the fused DP-release +
    int8 quantized-transport + weighted-reduce kernel (DESIGN.md §10).
    ``clip=0`` skips the DP stage (a distinct, shorter-grid trace);
    ``uniform=None`` rounds to nearest."""
    if interpret is None:
        interpret = _interpret_default()
    return quant_clip_reduce_flat(stacked, weights, clip=clip, noise=noise,
                                  uniform=uniform, resid=resid, block=block,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("with_residual", "block",
                                             "interpret"))
def agg_topk_reduce(stacked, weights, thresholds, *,
                    with_residual: bool = False, block: int = 2048,
                    interpret: bool | None = None):
    """stacked (C, P) codec inputs, weights (C,), per-client magnitude
    thresholds (C,) -> (reduced (P,), residual (C, P) | None): the
    top-k threshold/scatter + weighted-reduce kernel (DESIGN.md §10)."""
    if interpret is None:
        interpret = _interpret_default()
    return topk_reduce_flat(stacked, weights, thresholds,
                            with_residual=with_residual, block=block,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def agg_pairwise_dists(stacked, *, block: int = 2048,
                       interpret: bool | None = None):
    """stacked (C, P) client deltas -> (C, C) pairwise squared L2
    distances over the flattened parameter axis — the Krum/multi-Krum
    selection metric (DESIGN.md §13). One streaming sweep of the (C, P)
    matrix; the tiny (C, C) output accumulates in VMEM."""
    if interpret is None:
        interpret = _interpret_default()
    return pairwise_dists_flat(stacked, block=block, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("trim", "block", "interpret"))
def agg_trimmed_reduce(stacked, weights, *, trim: int, block: int = 2048,
                       interpret: bool | None = None):
    """stacked (C, P) client deltas, weights (C,) -> (P,): rank-trimmed
    weighted mean over the client axis (trim clients cut at each end;
    trim=(C-1)//2 is the coordinate-wise median)."""
    if interpret is None:
        interpret = _interpret_default()
    return trimmed_reduce_flat(stacked, weights, trim=trim, block=block,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def int8_matmul(x, q, scale, *, bm: int = 128, bn: int = 128,
                interpret: bool | None = None):
    """x (M, K) f32 activations, q (K, N) int8 weight, scale (N,) f32
    per-output-channel -> (M, N) f32: the weight-only int8 inference
    matmul (DESIGN.md §12). The int8 tile is what streams from HBM —
    4x fewer weight bytes than f32 at identical output up to the f32
    accumulation order."""
    if interpret is None:
        interpret = _interpret_default()
    return int8_matmul_flat(x, q, scale, bm=bm, bn=bn, interpret=interpret)


def fedavg_reduce_tree(stacked_tree, weights, *, interpret: bool | None = None):
    """Pytree convenience: stack clients' trees -> aggregated tree via the
    Pallas reduction (Eq. 3). The (C, P) matrix is produced by one vmapped
    tree-ravel, not a per-client Python loop — this is the path the round
    engines call when ``use_pallas_aggregation`` is set."""
    like = tree_index(stacked_tree, 0)
    vecs = tree_ravel_clients(stacked_tree)
    avg = fedavg_reduce(vecs, weights, interpret=interpret)
    return tree_unflatten_from_vector(avg, like)
