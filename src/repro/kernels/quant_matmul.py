"""Int8 weight-only inference matmul (DESIGN.md §12).

Serving reuses the §10 symmetric-quantization contract (127 levels,
floored scale — the constants are imported from ``agg_reduce`` so the
transport codec and the inference path cannot drift) but flips the
granularity: transport quantizes per *client row* of the (C, P) delta
matrix, inference quantizes each dense weight per *output channel*
(scale_n = max_k |W[k, n]| / 127), which keeps the worst-case relative
weight error at 1/254 per column regardless of how differently scaled
the columns are.

The kernel computes  out = (x @ deq(q)) = (x @ q_f32) * scale  with the
scale applied AFTER the reduction (deq is a per-column constant, so it
commutes with the sum over k) — the int8 weight tile is what streams
from HBM, at a quarter of the f32 bytes. Weights dominate the serving
working set at small batch (the activation tile is (bm, K) with bm ≤
the padded batch of target points), so weight bytes are the roofline;
the matmul itself runs on the MXU in f32 after an in-register upcast.

Grid: (M/bm, N/bn); each step reads the full K axis (GPO's K ≤ d_ff, a
few hundred — one VMEM tile), so no cross-step accumulator is needed.
Oracle: ``kernels/ref.py::ref_int8_matmul``; interpret-mode fallback per
``kernels/backend.py`` like every other kernel family.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.agg_reduce import INT8_LEVELS, _SCALE_FLOOR
from repro.kernels.backend import interpret_default


class QuantizedLinear(NamedTuple):
    """An int8-quantized dense weight: ``q`` int8 with the original
    weight's shape (..., K, N), ``scale`` f32 (..., N) per-output-channel
    dequantization scales. Leading dims (the stacked-layer axis) are
    carried through, so ``lax.scan`` over stacked GPO layers slices a
    per-layer (K, N) / (N,) pair exactly like a plain weight."""

    q: jnp.ndarray
    scale: jnp.ndarray


def quantize_linear(w: jnp.ndarray) -> QuantizedLinear:
    """Per-output-channel symmetric int8 quantization of a dense weight
    (..., K, N). Round-to-nearest: weights are load-time constants, so
    the stochastic rounding the §10 transport codec uses (unbiasedness
    across rounds) buys nothing here and would make serving depend on a
    key."""
    x = w.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-2) / INT8_LEVELS,
                        _SCALE_FLOOR)
    q = jnp.clip(jnp.round(x / scale[..., None, :]),
                 -INT8_LEVELS, INT8_LEVELS)
    return QuantizedLinear(q=q.astype(jnp.int8), scale=scale)


def dequantize_linear(ql: QuantizedLinear) -> jnp.ndarray:
    """(..., K, N) f32 reconstruction — the value the kernel's fused
    matmul is algebraically equal to multiplying by."""
    return ql.q.astype(jnp.float32) * ql.scale[..., None, :]


def _int8_matmul_kernel(x_ref, q_ref, s_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # (bm, K)
    w = q_ref[...].astype(jnp.float32)  # (K, bn) upcast in-register
    s = s_ref[...].astype(jnp.float32)  # (1, bn)
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    o_ref[...] = (acc * s).astype(o_ref.dtype)


def int8_matmul_flat(x: jnp.ndarray, q: jnp.ndarray, scale: jnp.ndarray,
                     *, bm: int = 128, bn: int = 128,
                     interpret: bool | None = None) -> jnp.ndarray:
    """x (M, K) f32, q (K, N) int8, scale (N,) f32 -> (M, N) f32:
    the weight-only-quantized dense layer. M and N pad to the block
    grid; K pads to the sublane multiple with zero rows (exact: they
    contribute 0 to the dot, and the matching scale pads are sliced
    off)."""
    if interpret is None:
        interpret = interpret_default()
    m, k = x.shape
    k2, n = q.shape
    if k != k2 or scale.shape != (n,):
        raise ValueError(f"int8_matmul shapes: x {x.shape}, q {q.shape}, "
                         f"scale {scale.shape}")
    bm = min(bm, max(8, m))
    bn = min(bn, max(8, n))
    pad_m, pad_n, pad_k = (-m) % bm, (-n) % bn, (-k) % 8
    xf = jnp.pad(x.astype(jnp.float32), ((0, pad_m), (0, pad_k)))
    qp = jnp.pad(q, ((0, pad_k), (0, pad_n)))
    sp = jnp.pad(scale.astype(jnp.float32), (0, pad_n)).reshape(1, -1)
    kp = k + pad_k

    out = pl.pallas_call(
        _int8_matmul_kernel,
        grid=(xf.shape[0] // bm, sp.shape[1] // bn),
        in_specs=[
            pl.BlockSpec((bm, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((kp, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xf.shape[0], sp.shape[1]),
                                       jnp.float32),
        interpret=interpret,
    )(xf, qp, sp)
    return out[:m, :n]
