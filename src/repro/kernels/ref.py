"""Pure-jnp oracles for every Pallas kernel.

Each oracle is the most *obviously correct* implementation (naive masked
softmax; step-by-step recurrence), deliberately independent from the
optimized model-code paths, so kernel tests triangulate three
implementations: kernel == oracle == model path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def ref_attention(q, k, v, *, causal=True, window=0, softcap=None):
    """q (B,H,S,hd), k/v (B,KV,S,hd) -> (B,H,S,hd). Naive masked softmax."""
    b, h, s, hd = q.shape
    kv = k.shape[1]
    g = h // kv
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, kk) / np.sqrt(hd)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window > 0:
        mask = mask & (qpos - kpos < window)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(vv.dtype), vv)


def ref_gpo_attention(q, k, v, *, num_ctx: int):
    """q/k/v (H,S,hd) with the neural-process mask."""
    h, s, hd = q.shape
    scores = jnp.einsum("hqd,hkd->hqk", q, k) / np.sqrt(hd)
    kpos = jnp.arange(s)[None, :]
    qpos = jnp.arange(s)[:, None]
    mask = (kpos < num_ctx) | (kpos == qpos)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    return jnp.einsum("hqk,hkd->hqd", probs.astype(v.dtype), v)


def ref_gpo_attention_grads(q, k, v, do, *, num_ctx: int):
    """(dq, dk, dv) for the neural-process attention, written out as the
    textbook softmax-attention gradient formulas (dense (h, S, S)
    intermediates, no autodiff, no flash recompute) — deliberately
    independent from both ``jax.grad`` of the oracle and the custom-VJP
    kernels it validates."""
    h, s, hd = q.shape
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    dof = do.astype(jnp.float32)
    scale = 1.0 / np.sqrt(hd)
    scores = jnp.einsum("hqd,hkd->hqk", qf, kf) * scale
    kpos = jnp.arange(s)[None, :]
    qpos = jnp.arange(s)[:, None]
    mask = (kpos < num_ctx) | (kpos == qpos)
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    dv = jnp.einsum("hqk,hqd->hkd", p, dof)
    dp = jnp.einsum("hqd,hkd->hqk", dof, vf)
    delta = jnp.sum(dp * p, axis=-1, keepdims=True)  # = rowsum(do * o)
    ds = p * (dp - delta) * scale
    dq = jnp.einsum("hqk,hkd->hqd", ds, kf)
    dk = jnp.einsum("hqk,hqd->hkd", ds, qf)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def ref_ssd(x, dt, A_log, B, C, D):
    """Step-by-step SSD recurrence (the definition, O(S) sequential).

    x (b,s,h,p); dt (b,s,h); A_log/D (h,); B/C (b,s,n) -> y like x.
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    a = -jnp.exp(A_log.astype(jnp.float32))

    def step(state, t):
        xt = x[:, t].astype(jnp.float32)  # (b,h,p)
        dtt = dt[:, t].astype(jnp.float32)  # (b,h)
        bt = B[:, t].astype(jnp.float32)  # (b,n)
        ct = C[:, t].astype(jnp.float32)
        decay = jnp.exp(dtt * a[None, :])  # (b,h)
        state = (decay[..., None, None] * state
                 + jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], bt))
        y = jnp.einsum("bhpn,bn->bhp", state, ct) + xt * D[None, :, None]
        return state, y

    state0 = jnp.zeros((b, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(step, state0, jnp.arange(s))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)  # (b,s,h,p)


def ref_fedavg_flat(stacked, weights):
    """stacked (C, P), weights (C,) -> (P,)."""
    return jnp.einsum("c,cp->p", weights.astype(jnp.float32),
                      stacked.astype(jnp.float32)).astype(stacked.dtype)


def ref_momentum_reduce_flat(stacked, weights, moment, *, beta):
    """Weighted delta moment + server momentum: the obvious two-liner."""
    d = jnp.einsum("c,cp->p", weights.astype(jnp.float32),
                   stacked.astype(jnp.float32))
    nm = beta * moment.astype(jnp.float32) + d
    return d.astype(stacked.dtype), nm


def ref_clip_reduce(stacked, weights, *, clip, noise=None):
    """DP-FedAvg reduction written out explicitly: per-client L2 norm,
    scale to the clip bound, optional presampled noise add, weighted sum
    — the oracle for the fused ``agg_clip_reduce`` kernel (DESIGN.md §9).
    The 1e-12 norm floor matches the kernel: zero deltas keep scale 1."""
    x = stacked.astype(jnp.float32)
    norms = jnp.sqrt(jnp.sum(jnp.square(x), axis=1))
    scale = jnp.minimum(1.0, clip / jnp.maximum(norms, 1e-12))
    y = x * scale[:, None]
    if noise is not None:
        y = y + noise.astype(jnp.float32)
    return jnp.einsum("c,cp->p", weights.astype(jnp.float32), y)


def ref_quant_clip_reduce(stacked, weights, *, clip=0.0, noise=None,
                          uniform=None, resid=None):
    """Fused quantized-transport oracle written out stage by stage
    (DESIGN.md §10): DP release (clip to the bound, add presampled
    noise), EF residual add, per-client symmetric int8 quantization
    (scale = absmax/127 floored at 1e-30 so zero rows stay zero;
    stochastic rounding q = ⌊z + u⌋ from the presampled uniform tile,
    round-to-nearest without it), dequantize, weighted sum. Returns
    (reduced (P,), new residual (C, P) | None). The 1e-12 norm floor and
    the 127-level symmetric grid match the kernel by shared constant."""
    x = stacked.astype(jnp.float32)
    if clip > 0.0:
        norms = jnp.sqrt(jnp.sum(jnp.square(x), axis=1))
        x = x * jnp.minimum(1.0, clip / jnp.maximum(norms, 1e-12))[:, None]
        if noise is not None:
            x = x + noise.astype(jnp.float32)
    if resid is not None:
        x = x + resid.astype(jnp.float32)
    scales = jnp.maximum(jnp.max(jnp.abs(x), axis=1) / 127.0, 1e-30)
    z = x / scales[:, None]
    q = (jnp.floor(z + uniform.astype(jnp.float32)) if uniform is not None
         else jnp.round(z))
    t = jnp.clip(q, -127.0, 127.0) * scales[:, None]
    out = jnp.einsum("c,cp->p", weights.astype(jnp.float32), t)
    return out, (x - t if resid is not None else None)


def ref_topk_reduce(stacked, weights, *, frac):
    """Top-k transport oracle: per client keep the entries whose
    magnitude reaches the ⌈frac·P⌉-th largest |value| (threshold ties
    kept), zero the rest, weighted-sum the survivors. Returns
    (reduced (P,), masked-out remainder (C, P)) — the remainder is the
    EF residual."""
    x = stacked.astype(jnp.float32)
    c, p = x.shape
    k = max(1, int(np.ceil(frac * p)))
    mags = np.abs(np.asarray(x))
    tau = np.sort(mags, axis=1)[:, p - k]  # k-th largest per client
    t = jnp.where(jnp.abs(x) >= jnp.asarray(tau)[:, None], x, 0.0)
    out = jnp.einsum("c,cp->p", weights.astype(jnp.float32), t)
    return out, x - t


def ref_int8_matmul(x, q, scale):
    """Weight-only-quantized dense layer written out as dequantize-then-
    matmul: x (M, K) f32, q (K, N) int8, scale (N,) f32 per-output-
    channel -> (M, N) f32. The fused kernel applies the scale after the
    reduction instead (a per-column constant commutes with the sum over
    k) — algebraically identical; this oracle materializes the f32
    weight so the two orderings are genuinely independent."""
    w = q.astype(jnp.float32) * scale.astype(jnp.float32)[None, :]
    return x.astype(jnp.float32) @ w


def ref_trimmed_flat(stacked, weights, *, trim):
    """Rank-trimmed weighted mean via an explicit stable argsort: sort
    each coordinate's clients (ties by client index), drop ``trim`` at
    each end, weighted-mean the survivors with renormalized weights."""
    x = stacked.astype(jnp.float32)
    c = x.shape[0]
    order = jnp.argsort(x, axis=0, stable=True)
    xs = jnp.take_along_axis(x, order, axis=0)
    ws = weights.astype(jnp.float32)[order]
    keep = ((jnp.arange(c) >= trim) & (jnp.arange(c) < c - trim))
    keep = keep.astype(jnp.float32)[:, None]
    num = jnp.sum(keep * ws * xs, axis=0)
    den = jnp.sum(keep * ws, axis=0)
    return (num / den).astype(stacked.dtype)


def ref_pairwise_sq_dists(stacked):
    """(C, P) deltas -> (C, C) pairwise squared L2 distances via the
    direct difference form sum_p (x_i[p] − x_j[p])² — no expansion
    trick, so the Pallas kernel's ‖x_i‖² + ‖x_j‖² − 2·x_i·x_j
    accumulation is genuinely independent of this oracle."""
    x = stacked.astype(jnp.float32)
    return jnp.sum((x[:, None, :] - x[None, :, :]) ** 2, axis=-1)
