"""Mamba2 SSD chunked-scan Pallas kernel.

TPU adaptation of the CUDA chunked scan (DESIGN.md §4): the grid walks
(batch*head, chunk) with the chunk dimension sequential; the running
(P, N) state lives in VMEM scratch across chunk steps. Per chunk:

  intra-chunk:  (L, L) masked decay x (C B^T) quadratic form -> MXU matmul
  inter-chunk:  y += exp(cs) * C @ state^T;  state = exp(total)*state + X^T B

No warp shuffles needed — the sequential dependency is exactly one VMEM
tensor per (b, h) lane, and everything else is systolic matmul work.

B/C are shared across heads (ngroups=1): their BlockSpecs index by batch
only, so the kernel never duplicates them in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import interpret_default


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, alog_ref, d_ref, y_ref,
                state_ref, *, chunk: int):
    i_c = pl.program_id(1)

    @pl.when(i_c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)  # (L, P)
    dt = dt_ref[0].astype(jnp.float32)  # (L,)
    bm = b_ref[0].astype(jnp.float32)  # (L, N)
    cm = c_ref[0].astype(jnp.float32)  # (L, N)
    a = -jnp.exp(alog_ref[0, 0].astype(jnp.float32))  # scalar
    d = d_ref[0, 0].astype(jnp.float32)

    dA = dt * a  # (L,)
    cs = jnp.cumsum(dA)  # (L,)
    # decay(i, j) = exp(cs_i - cs_j), lower-triangular
    diff = cs[:, None] - cs[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    decay = jnp.where(tri, jnp.exp(diff), 0.0)

    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())))  # (L, L)
    scores = cb * decay * dt[None, :]
    xdt = x * dt[:, None]

    # scores already carries dt_j, so the matmul consumes plain x
    y_intra = jax.lax.dot(scores, x)

    state = state_ref[...]  # (P, N)
    in_decay = jnp.exp(cs)  # (L,)
    y_inter = in_decay[:, None] * jax.lax.dot_general(
        cm, state, (((1,), (1,)), ((), ())))  # (L, P)

    y = y_intra + y_inter + d * x
    y_ref[0] = y.astype(y_ref.dtype)

    total = cs[-1]
    decay_to_end = jnp.exp(total - cs)  # (L,)
    # state' = exp(total) * state + sum_j decay_to_end_j * dt_j * x_j B_j^T
    xw = xdt * decay_to_end[:, None]  # (L, P)
    state_ref[...] = (jnp.exp(total) * state
                      + jax.lax.dot_general(xw, bm, (((0,), (0,)), ((), ()))))


def ssd_scan_bhsp(x, dt, A_log, B, C, D, *, chunk: int = 128,
                  interpret: bool | None = None):
    """x (b, s, h, p); dt (b, s, h); A_log/D (h,); B/C (b, s, n) -> y like x.

    s must be a multiple of ``chunk`` (ops.ssd_scan pads with dt=0, which is
    an exact identity for the recurrence). ``interpret`` defaults to the
    backend (interpret on CPU, native on TPU) so direct callers never
    silently run interpret mode on hardware.
    """
    if interpret is None:
        interpret = interpret_default()
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    # lane layout: (b*h, s, p) for x/y; dt (b*h, s); B/C stay (b, s, n)
    xf = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dtf = dt.transpose(0, 2, 1).reshape(b * h, s)
    alog = jnp.broadcast_to(A_log[None, :], (b, h)).reshape(b * h, 1)
    df = jnp.broadcast_to(D[None, :], (b, h)).reshape(b * h, 1)

    def x_map(i, c):
        return (i, c, 0)

    def dt_map(i, c):
        return (i, c)

    def bc_map(i, c):
        return (i // h, c, 0)

    def scalar_map(i, c):
        return (i, 0)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), x_map),
            pl.BlockSpec((1, chunk), dt_map),
            pl.BlockSpec((1, chunk, n), bc_map),
            pl.BlockSpec((1, chunk, n), bc_map),
            pl.BlockSpec((1, 1), scalar_map),
            pl.BlockSpec((1, 1), scalar_map),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), x_map),
        out_shape=jax.ShapeDtypeStruct((b * h, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
        if not interpret else None,
    )(xf, dtf, B, C, alog, df)
    return y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
