import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes WITHOUT allocating anything (params/batches/caches are
ShapeDtypeStructs).

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
      --shape train_4k [--multi-pod] [--out results.json]

Per pair this prints/records:
  * compiled.memory_analysis()  — proves the layout fits 16 GB/chip,
  * compiled.cost_analysis()    — per-chip FLOPs / bytes for §Roofline,
  * the collective schedule (op kind -> bytes) parsed from the HLO,
  * the three roofline terms + bottleneck + MODEL_FLOPS/HLO_FLOPs ratio.

The 2x16x16 multi-pod pass proves the 'pod' axis shards (hierarchical
FedAvg / data parallelism over DCI); the roofline table is single-pod.
"""  # noqa: E402

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_arch, override
from repro.core.trainer import make_prefill_step, make_serve_step, make_train_step
from repro.launch import roofline as rl
from repro.launch.mesh import data_axes, make_production_mesh
from repro.launch.sharding import (
    adafactor_state_shardings,
    adam_state_shardings,
    batch_shardings,
    cache_shardings,
    params_shardings,
)
from repro.launch.specs import (
    batch_specs,
    cache_specs,
    count_params,
    input_specs,
    params_specs,
    serving_config,
    train_settings,
)
from repro.models.partitioning import activation_sharding
from repro.optim import adafactor, adam


def _mem_stats(memory_analysis) -> dict:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(memory_analysis, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def lower_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
               fsdp_override: bool | None = None,
               cfg_overrides: dict | None = None,
               verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    num_chips = int(jnp.prod(jnp.asarray(list(mesh.shape.values()))))
    shape = INPUT_SHAPES[shape_name]
    cfg = override(get_arch(arch), param_dtype="bfloat16",
                   activation_dtype="bfloat16")
    cfg = serving_config(cfg, shape)
    if cfg_overrides:
        cfg = override(cfg, **cfg_overrides)
    settings = train_settings(cfg)
    fsdp = settings.fsdp if fsdp_override is None else fsdp_override
    baxes = data_axes(mesh)

    p_shapes = params_specs(cfg)
    p_shard = params_shardings(p_shapes, cfg, mesh, fsdp=fsdp)

    t0 = time.time()
    ctx = activation_sharding(mesh)
    ctx.__enter__()
    if shape.kind == "train":
        opt = adafactor(1e-3) if settings.optimizer == "adafactor" else adam(1e-3)
        opt_shapes = jax.eval_shape(opt.init, p_shapes)
        if settings.optimizer == "adafactor":
            o_shard = adafactor_state_shardings(p_shard, p_shapes, mesh)
        else:
            o_shard = adam_state_shardings(p_shard, mesh)
        b_shapes = batch_specs(cfg, shape, with_labels=True)
        b_shard = batch_shardings(b_shapes, mesh, baxes)
        step = make_train_step(cfg, opt, microbatch=settings.microbatch,
                               remat=settings.remat)
        jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                         out_shardings=(p_shard, o_shard, None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(p_shapes, opt_shapes, b_shapes)
    elif shape.kind == "prefill":
        b_shapes = batch_specs(cfg, shape, with_labels=False)
        b_shard = batch_shardings(b_shapes, mesh, baxes)
        c_shapes = cache_specs(cfg, shape)
        c_shard = cache_shardings(c_shapes, cfg, mesh, baxes)
        step = make_prefill_step(cfg, shape.seq_len)
        jitted = jax.jit(step, in_shardings=(p_shard, b_shard),
                         out_shardings=(None, c_shard))
        lowered = jitted.lower(p_shapes, b_shapes)
    else:  # decode
        c_shapes = cache_specs(cfg, shape)
        c_shard = cache_shardings(c_shapes, cfg, mesh, baxes)
        tok_spec = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        tok_shard = batch_shardings({"tokens": tok_spec}, mesh, baxes)["tokens"]
        pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
        pos_shard = NamedSharding(mesh, P())
        step = make_serve_step(cfg)
        jitted = jax.jit(step,
                         in_shardings=(p_shard, c_shard, tok_shard, pos_shard),
                         out_shardings=(None, c_shard),
                         donate_argnums=(1,))
        lowered = jitted.lower(p_shapes, c_shapes, tok_spec, pos_spec)
    ctx.__exit__(None, None, None)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # pre-0.5 jax wraps it in a list
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    mflops = rl.model_flops(cfg, shape, num_chips)
    roof = rl.analyze(cost, hlo, model_flops_per_chip=mflops)
    xla_flops = float(cost.get("flops", 0.0))  # while-body-once cross-check

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(v) for v in mesh.shape.values()),
        "multi_pod": multi_pod,
        "num_chips": num_chips,
        "params": count_params(cfg),
        "fsdp": fsdp,
        "kind": shape.kind,
        "optimizer": settings.optimizer if shape.kind == "train" else None,
        "microbatch": settings.microbatch if shape.kind == "train" else None,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": _mem_stats(mem),
        "roofline": roof.as_dict(),
        "xla_cost_flops": xla_flops,
    }
    if verbose:
        print(f"== {arch} x {shape_name} mesh={result['mesh']} ==")
        print("memory_analysis:", mem)
        print("cost_analysis: flops=%.3e bytes=%.3e" % (
            roof.flops_per_chip, roof.bytes_per_chip))
        print("collectives:", roof.collectives.bytes_by_kind)
        print("roofline: compute=%.2fms memory=%.2fms collective=%.2fms "
              "-> %s | useful=%.2f" % (
                  roof.compute_s * 1e3, roof.memory_s * 1e3,
                  roof.collective_s * 1e3, roof.bottleneck,
                  roof.useful_ratio))
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=sorted(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None, help="append result as json line")
    args = ap.parse_args()
    try:
        result = lower_pair(args.arch, args.shape, multi_pod=args.multi_pod)
        status = "ok"
    except Exception:
        traceback.print_exc()
        result = {"arch": args.arch, "shape": args.shape,
                  "multi_pod": args.multi_pod, "error": traceback.format_exc()}
        status = "error"
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(result) + "\n")
    print(f"DRYRUN {status}: {args.arch} x {args.shape} "
          f"multi_pod={args.multi_pod}")
    if status == "error":
        raise SystemExit(1)


if __name__ == "__main__":
    main()
