import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes WITHOUT allocating anything (params/batches/caches are
ShapeDtypeStructs).

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
      --shape train_4k [--multi-pod] [--out results.json]

Per pair this prints/records:
  * compiled.memory_analysis()  — proves the layout fits 16 GB/chip,
  * compiled.cost_analysis()    — per-chip FLOPs / bytes for §Roofline,
  * the collective schedule (op kind -> bytes) parsed from the HLO,
  * the three roofline terms + bottleneck + MODEL_FLOPS/HLO_FLOPs ratio.

The 2x16x16 multi-pod pass proves the 'pod' axis shards (hierarchical
FedAvg / data parallelism over DCI); the roofline table is single-pod.
"""  # noqa: E402

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_arch, override
from repro.core.trainer import make_prefill_step, make_serve_step, make_train_step
from repro.launch import roofline as rl
from repro.launch.mesh import data_axes, make_production_mesh
from repro.launch.sharding import (
    adafactor_state_shardings,
    adam_state_shardings,
    batch_shardings,
    cache_shardings,
    params_shardings,
)
from repro.launch.specs import (
    batch_specs,
    cache_specs,
    count_params,
    input_specs,
    params_specs,
    serving_config,
    train_settings,
)
from repro.models.partitioning import activation_sharding
from repro.optim import adafactor, adam


def _mem_stats(memory_analysis) -> dict:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(memory_analysis, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def lower_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
               fsdp_override: bool | None = None,
               cfg_overrides: dict | None = None,
               verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    num_chips = int(jnp.prod(jnp.asarray(list(mesh.shape.values()))))
    shape = INPUT_SHAPES[shape_name]
    cfg = override(get_arch(arch), param_dtype="bfloat16",
                   activation_dtype="bfloat16")
    cfg = serving_config(cfg, shape)
    if cfg_overrides:
        cfg = override(cfg, **cfg_overrides)
    settings = train_settings(cfg)
    fsdp = settings.fsdp if fsdp_override is None else fsdp_override
    baxes = data_axes(mesh)

    p_shapes = params_specs(cfg)
    p_shard = params_shardings(p_shapes, cfg, mesh, fsdp=fsdp)

    t0 = time.time()
    ctx = activation_sharding(mesh)
    ctx.__enter__()
    if shape.kind == "train":
        opt = adafactor(1e-3) if settings.optimizer == "adafactor" else adam(1e-3)
        opt_shapes = jax.eval_shape(opt.init, p_shapes)
        if settings.optimizer == "adafactor":
            o_shard = adafactor_state_shardings(p_shard, p_shapes, mesh)
        else:
            o_shard = adam_state_shardings(p_shard, mesh)
        b_shapes = batch_specs(cfg, shape, with_labels=True)
        b_shard = batch_shardings(b_shapes, mesh, baxes)
        step = make_train_step(cfg, opt, microbatch=settings.microbatch,
                               remat=settings.remat)
        jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                         out_shardings=(p_shard, o_shard, None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(p_shapes, opt_shapes, b_shapes)
    elif shape.kind == "prefill":
        b_shapes = batch_specs(cfg, shape, with_labels=False)
        b_shard = batch_shardings(b_shapes, mesh, baxes)
        c_shapes = cache_specs(cfg, shape)
        c_shard = cache_shardings(c_shapes, cfg, mesh, baxes)
        step = make_prefill_step(cfg, shape.seq_len)
        jitted = jax.jit(step, in_shardings=(p_shard, b_shard),
                         out_shardings=(None, c_shard))
        lowered = jitted.lower(p_shapes, b_shapes)
    else:  # decode
        c_shapes = cache_specs(cfg, shape)
        c_shard = cache_shardings(c_shapes, cfg, mesh, baxes)
        tok_spec = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        tok_shard = batch_shardings({"tokens": tok_spec}, mesh, baxes)["tokens"]
        pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
        pos_shard = NamedSharding(mesh, P())
        step = make_serve_step(cfg)
        jitted = jax.jit(step,
                         in_shardings=(p_shard, c_shard, tok_shard, pos_shard),
                         out_shardings=(None, c_shard),
                         donate_argnums=(1,))
        lowered = jitted.lower(p_shapes, c_shapes, tok_spec, pos_spec)
    ctx.__exit__(None, None, None)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # pre-0.5 jax wraps it in a list
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    mflops = rl.model_flops(cfg, shape, num_chips)
    roof = rl.analyze(cost, hlo, model_flops_per_chip=mflops)
    xla_flops = float(cost.get("flops", 0.0))  # while-body-once cross-check

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(v) for v in mesh.shape.values()),
        "multi_pod": multi_pod,
        "num_chips": num_chips,
        "params": count_params(cfg),
        "fsdp": fsdp,
        "kind": shape.kind,
        "optimizer": settings.optimizer if shape.kind == "train" else None,
        "microbatch": settings.microbatch if shape.kind == "train" else None,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": _mem_stats(mem),
        "roofline": roof.as_dict(),
        "xla_cost_flops": xla_flops,
    }
    if verbose:
        print(f"== {arch} x {shape_name} mesh={result['mesh']} ==")
        print("memory_analysis:", mem)
        print("cost_analysis: flops=%.3e bytes=%.3e" % (
            roof.flops_per_chip, roof.bytes_per_chip))
        print("collectives:", roof.collectives.bytes_by_kind)
        print("roofline: compute=%.2fms memory=%.2fms collective=%.2fms "
              "-> %s | useful=%.2f" % (
                  roof.compute_s * 1e3, roof.memory_s * 1e3,
                  roof.collective_s * 1e3, roof.bottleneck,
                  roof.useful_ratio))
    return result


def lower_gpo_round(agg_name: str, *, clients: int = 8,
                    edges: int = 1,
                    use_pallas: bool = False,
                    use_pallas_attention: bool = False,
                    clip_norm: float = 0.0,
                    noise_multiplier: float = 0.0,
                    compress: str = "none",
                    topk_frac: float = 0.01,
                    faults: bool = False,
                    attack: str = "none",
                    attackers: int = 0,
                    norm_bound: float = 0.0,
                    verbose: bool = True) -> dict:
    """Compile the shard_map federated GPO round for one aggregation
    strategy on a ``clients``-device 'data' mesh and report its
    collective schedule (DESIGN.md §7): linear strategies must show ONE
    parameter-sized all-reduce (the weighted delta psum); the robust
    strategies an all-gather of the flat client-delta matrix instead.
    ``use_pallas_attention`` routes every local epoch's fwd+bwd through
    the banded custom-VJP attention kernels (DESIGN.md §8) so the
    compiled schedule reflects the fused training hot path.
    ``clip_norm`` > 0 compiles the DP client-delta pipeline
    (DESIGN.md §9): clip + noise happen shard-locally BEFORE the
    collectives, so the schedule must keep the exact same shape — one
    psum of the (already privatized) weighted delta for the linear
    family, an all-gather of the privatized matrix for the robust one.
    ``compress`` compiles the delta codec (DESIGN.md §10): for the
    robust family under ``int8`` the flat-delta all-gather turns into
    an int8-payload + f32-scale all-gather (~4x fewer bytes — the
    reported byte counts, parsed both flat from the HLO text and
    trip-count-aware via ``launch/hlo_cost.py``, prove it); the linear
    family dequantizes shard-locally and keeps its one f32 psum.
    ``faults`` compiles the fault-aware round (DESIGN.md §11): the
    failure schedule is derived replicated from the fault key and
    survivor weights are zeroed/renormalized shard-locally, so the
    linear family's collective schedule must keep the SAME single
    parameter-sized psum — tests/test_availability.py pins the byte
    counts equal to the fault-free round.
    ``edges`` > 1 compiles the §14 two-level client→edge→server round
    on an (edges, clients/edges) ('edge', 'data') mesh: the robust
    family's flat all-gather splits into an intra-edge hop (C/E rows)
    plus a cross-edge hop of only E candidate rows (int8 when
    ``compress="int8"``) — the per-op ``collective_ops`` entry makes the
    two hops individually visible — while the linear family keeps its
    one psum over both axes."""
    from jax.sharding import NamedSharding
    from repro.configs import (AdversaryConfig, AggConfig,
                               AvailabilityConfig, CompressionConfig,
                               FedConfig, GPOConfig, HierarchyConfig,
                               PrivacyConfig)
    from repro.core import make_aggregator
    from repro.core.availability import init_fault_state
    from repro.core.federated import make_sharded_round
    from repro.core.gpo import init_gpo_params
    from repro.data import SurveyConfig, make_survey_data
    from repro.launch import hlo_cost
    from repro.launch.sharding import (fault_state_shardings,
                                       server_state_shardings)
    from repro.optim import adam
    from repro.utils.pytree import tree_count_params

    if edges > 1:
        # §14 two-level edge mesh: one client per device, E edge shards
        mesh = jax.make_mesh((edges, clients // edges), ("edge", "data"))
        caxes = ("edge", "data")
    else:
        mesh = jax.make_mesh((clients,), ("data",))
        caxes = ("data",)
    data = make_survey_data(SurveyConfig(num_groups=clients,
                                         num_questions=30, d_embed=16,
                                         seed=0))
    gcfg = GPOConfig(d_embed=16, d_model=32, num_layers=1, num_heads=2,
                     d_ff=32)
    privacy = PrivacyConfig(clip_norm=clip_norm,
                            noise_multiplier=noise_multiplier)
    compression = CompressionConfig(kind=compress, topk_frac=topk_frac)
    avail = (AvailabilityConfig(online_prob=0.8, crash_prob=0.05,
                                straggler_prob=0.1, max_staleness=4)
             if faults else AvailabilityConfig())
    adversary = AdversaryConfig(kind=attack, num_attackers=attackers)
    fcfg = FedConfig(num_clients=clients, local_epochs=2, num_context=6,
                     num_target=6,
                     agg=AggConfig(name=agg_name,
                                   num_malicious=attackers,
                                   norm_bound=norm_bound),
                     use_pallas_aggregation=use_pallas,
                     use_pallas_attention=use_pallas_attention,
                     privacy=privacy, compression=compression,
                     avail=avail, adversary=adversary,
                     hierarchy=HierarchyConfig(num_edges=edges))
    opt = adam(fcfg.lr)
    agg = make_aggregator(fcfg.agg, num_clients=clients,
                          use_pallas=use_pallas)
    params = init_gpo_params(gcfg, jax.random.PRNGKey(0))
    server_state = agg.init(params)
    round_fn = make_sharded_round(gcfg, fcfg, data, mesh,
                                  client_axes=caxes, opt=opt, agg=agg)

    spec = NamedSharding(mesh, P(caxes if len(caxes) > 1 else caxes[0]))
    shard = lambda t: jax.tree.map(  # noqa: E731
        lambda x: jax.ShapeDtypeStruct(
            (clients,) + tuple(x.shape), x.dtype, sharding=spec), t)
    cp = shard(params)
    opt_s = shard(opt.init(params))
    keys = jax.ShapeDtypeStruct((clients, 2), jnp.uint32, sharding=spec)
    gids = jax.ShapeDtypeStruct((clients,), jnp.int32, sharding=spec)
    repl = NamedSharding(mesh, P())
    # fault mode: weights arrive replicated — every shard renormalizes
    # the survivor mass redundantly (DESIGN.md §11)
    w = jax.ShapeDtypeStruct((clients,), jnp.float32,
                             sharding=repl if faults else spec)
    srv = jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(tuple(x.shape), x.dtype,
                                          sharding=s),
        server_state, server_state_shardings(server_state, mesh))
    args = (cp, opt_s, keys, gids, w, srv)
    if faults:
        fault0 = init_fault_state(clients, tree_count_params(params))
        f_shard = fault_state_shardings(mesh, caxes)
        fault = jax.tree.map(
            lambda x, s: jax.ShapeDtypeStruct(tuple(x.shape), x.dtype,
                                              sharding=s),
            fault0, f_shard)
        fkey = jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=repl)
        args += (fault, fkey)
    if compression.enabled and compression.error_feedback:
        args += (jax.ShapeDtypeStruct(
            (clients, tree_count_params(params)), jnp.float32,
            sharding=spec),)
    if adversary.enabled:
        # replicated Byzantine key, LAST (after the EF residual) per the
        # round's trailing-arg order
        args += (jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=repl),)

    t0 = time.time()
    lowered = jax.jit(round_fn).lower(*args)
    compiled = lowered.compile()
    hlo = compiled.as_text()
    coll = rl.parse_collectives(hlo)
    # trip-count-aware cross-check: collectives inside while loops count
    # once per iteration in hlo_cost's walk (DESIGN.md §6)
    cost_totals = hlo_cost.analyze_hlo(hlo)
    cost_coll = cost_totals.collective_bytes
    result = {
        "agg": agg_name,
        "clients": clients,
        "edges": edges,
        "use_pallas_aggregation": use_pallas,
        "use_pallas_attention": use_pallas_attention,
        "private": privacy.enabled,
        "clip_norm": clip_norm,
        "noise_multiplier": noise_multiplier,
        "compress": compress,
        "topk_frac": topk_frac if compress == "topk" else None,
        "faults": faults,
        "attack": attack,
        "attackers": attackers,
        "norm_bound": norm_bound,
        "linear": agg.linear,
        "compile_s": round(time.time() - t0, 1),
        "collective_bytes_by_kind": dict(coll.bytes_by_kind),
        "collective_count_by_kind": dict(coll.count_by_kind),
        "collective_count": coll.total_count,
        "hlo_cost_collective_bytes_by_kind": {
            k: float(v) for k, v in cost_coll.items()},
        # per-op collective detail (kind, bytes, trip multiplier): makes
        # the §14 two-hop schedule individually visible — the intra-edge
        # and cross-edge all-gathers land as separate entries
        "collective_ops": [[k, float(b), float(m)]
                           for k, b, m in cost_totals.collective_ops],
        "memory": _mem_stats(compiled.memory_analysis()),
    }
    if verbose:
        print(f"== gpo-fed round x agg={agg_name} mesh={clients}"
              + (f" edges={edges}" if edges > 1 else "")
              + (f" compress={compress}" if compress != "none" else "")
              + (" faults" if faults else "")
              + (f" attack={attack}({attackers})" if attack != "none"
                 else "")
              + (f" norm_bound={norm_bound}" if norm_bound else "")
              + " ==")
        print("collectives:", result["collective_bytes_by_kind"])
        print("collectives (hlo_cost, trip-aware):",
              result["hlo_cost_collective_bytes_by_kind"])
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=sorted(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--gpo-fed", action="store_true",
                    help="lower the shard_map federated GPO round instead "
                         "of a backbone (arch/shape ignored)")
    ap.add_argument("--agg", default="fedavg",
                    help="aggregation strategy for --gpo-fed")
    ap.add_argument("--clients", type=int, default=8,
                    help="client-mesh size for --gpo-fed")
    ap.add_argument("--edges", type=int, default=1,
                    help="edge shards for the §14 two-level "
                         "client→edge→server round (must divide "
                         "--clients; 1 = flat)")
    ap.add_argument("--pallas-attn", action="store_true",
                    help="route --gpo-fed local training through the "
                         "banded custom-VJP attention kernels")
    ap.add_argument("--private", action="store_true",
                    help="compile the --gpo-fed round with the DP "
                         "client-delta pipeline (shard-local clip+noise "
                         "before the round's collectives, DESIGN.md §9)")
    ap.add_argument("--clip-norm", type=float, default=1.0,
                    help="per-client L2 clip for --private")
    ap.add_argument("--noise-multiplier", type=float, default=1.0,
                    help="Gaussian noise multiplier for --private")
    ap.add_argument("--compress", default="none",
                    choices=["none", "int8", "topk"],
                    help="compile the --gpo-fed round with the delta "
                         "codec (DESIGN.md §10): robust strategies "
                         "all-gather int8 payloads + f32 scales instead "
                         "of f32 vectors")
    ap.add_argument("--topk-frac", type=float, default=0.01,
                    help="fraction of coordinates kept for "
                         "--compress topk")
    ap.add_argument("--faults", action="store_true",
                    help="compile the --gpo-fed round with the fault-"
                         "injection layer (DESIGN.md §11): replicated "
                         "failure schedule, masked survivor weights — "
                         "the linear family must keep its ONE psum")
    ap.add_argument("--attack", default="none",
                    choices=["none", "sign_flip", "scaled", "gaussian",
                             "alie", "label_flip"],
                    help="compile the --gpo-fed round with the Byzantine "
                         "attack stage (DESIGN.md §13); linear family "
                         "keeps its collective schedule byte-identical")
    ap.add_argument("--attackers", type=int, default=2,
                    help="Byzantine clients per round for --attack")
    ap.add_argument("--norm-bound", type=float, default=0.0,
                    help="server-side L2 norm bound on received rows "
                         "(0 = off)")
    ap.add_argument("--out", default=None, help="append result as json line")
    args = ap.parse_args()
    if not args.gpo_fed and not (args.arch and args.shape):
        ap.error("--arch and --shape are required unless --gpo-fed")
    what = (f"gpo-fed x {args.agg} clients={args.clients}"
            + (" private" if args.private else "")
            + (f" compress={args.compress}" if args.compress != "none"
               else "")
            + (" faults" if args.faults else "")
            + (f" attack={args.attack}" if args.attack != "none"
               else "") if args.gpo_fed
            else f"{args.arch} x {args.shape} multi_pod={args.multi_pod}")
    try:
        if args.gpo_fed:
            result = lower_gpo_round(
                args.agg, clients=args.clients, edges=args.edges,
                use_pallas_attention=args.pallas_attn,
                clip_norm=args.clip_norm if args.private else 0.0,
                noise_multiplier=(args.noise_multiplier if args.private
                                  else 0.0),
                compress=args.compress, topk_frac=args.topk_frac,
                faults=args.faults,
                attack=args.attack,
                attackers=args.attackers if args.attack != "none" else 0,
                norm_bound=args.norm_bound)
        else:
            result = lower_pair(args.arch, args.shape,
                                multi_pod=args.multi_pod)
        status = "ok"
    except Exception:
        traceback.print_exc()
        result = {"arch": args.arch, "shape": args.shape,
                  "gpo_fed": args.gpo_fed,
                  "multi_pod": args.multi_pod, "error": traceback.format_exc()}
        status = "error"
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(result) + "\n")
    print(f"DRYRUN {status}: {what}")
    if status == "error":
        raise SystemExit(1)


if __name__ == "__main__":
    main()
