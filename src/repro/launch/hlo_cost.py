"""HLO cost engine: trip-count-aware FLOPs / bytes / collective analysis.

``compiled.cost_analysis()`` counts every while-loop body ONCE — for a
layer-scanned transformer that under-counts compute by ~num_layers x
(verified in EXPERIMENTS.md §Dry-run). This module parses the optimized
HLO text and walks the call graph instead:

  * dot ops: 2 * output_elems * contraction_size exact MXU FLOPs
    (contraction size from the operand symbol table);
  * other array ops: 1 FLOP / output element (VPU estimate);
  * while: body + cond costs x trip count (parsed from the loop condition's
    compare constant — jax scans always lower to 0..N LT loops);
  * fusion/call: recurse for FLOPs; for HBM bytes the *fusion op's*
    operands + outputs are counted (internals stay in registers/VMEM),
    which is the right memory model for fused kernels;
  * collectives: bytes by kind, trip-count aware (a psum inside a scanned
    layer counts num_layers times).

This is the data source for §Roofline; `cost_analysis()` is kept as a
cross-check on the non-loop part.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from functools import lru_cache

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "fp8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"([a-z][a-z0-9\-]*)\(")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_BDIMS_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "copy-done",
    "all-reduce-done", "all-gather-done", "collective-permute-done",
    "reshape", "broadcast", "transpose",  # layout ops: ~free on TPU or fused
}


def _shapes_in(text: str):
    return [(d, dims) for d, dims in _SHAPE_RE.findall(text)]


def _shape_elems(dims: str) -> int:
    if not dims:
        return 1
    return int(np.prod([int(x) for x in dims.split(",") if x]))


def _shape_bytes(dtype: str, dims: str) -> int:
    return _DTYPE_BYTES.get(dtype, 0) * _shape_elems(dims)


@dataclass
class Op:
    name: str
    opcode: str
    out_shapes: list  # [(dtype, dims)]
    operands: list  # names
    line: str


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    symbols: dict = field(default_factory=dict)  # name -> [(dtype, dims)]


@dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: dict = field(default_factory=dict)
    collective_counts: dict = field(default_factory=dict)
    # per-op detail: (kind, payload bytes, trip multiplier) for every
    # collective, in walk order. The kind-keyed dicts above sum these;
    # the list keeps ops with the same kind separable — e.g. the §14
    # two-hop schedule's intra-edge vs cross-edge all-gathers.
    collective_ops: list = field(default_factory=list)

    def add_collective(self, kind: str, nbytes: float, mult: float):
        self.collective_bytes[kind] = (
            self.collective_bytes.get(kind, 0.0) + nbytes * mult)
        self.collective_counts[kind] = (
            self.collective_counts.get(kind, 0.0) + mult)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def parse_module(hlo_text: str) -> tuple[dict, str]:
    """Parse computations. Returns ({name: Computation}, entry_name)."""
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1))
                if line.startswith("ENTRY"):
                    entry = m.group(1)
                continue
        else:
            if line.strip() == "}":
                comps[cur.name] = cur
                cur = None
                continue
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            name, rhs = dm.groups()
            om = _OPCODE_RE.search(rhs)
            opcode = om.group(1) if om else ""
            type_part = rhs[: om.start()] if om else rhs
            out_shapes = _shapes_in(type_part)
            # operand names within the opcode's paren group
            operands = []
            if om:
                depth, j = 0, om.end() - 1
                start = j
                while j < len(rhs):
                    if rhs[j] == "(":
                        depth += 1
                    elif rhs[j] == ")":
                        depth -= 1
                        if depth == 0:
                            break
                    j += 1
                operands = re.findall(r"%([\w.\-]+)", rhs[start:j + 1])
            op = Op(name=name, opcode=opcode, out_shapes=out_shapes,
                    operands=operands, line=line)
            cur.ops.append(op)
            cur.symbols[name] = out_shapes
    if cur is not None:
        comps[cur.name] = cur
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = sum(_shape_elems(d) for _, d in op.out_shapes)
    m = _LHS_CDIMS_RE.search(op.line)
    if not m or not op.operands:
        return 2.0 * out_elems  # degenerate
    cdims = [int(x) for x in m.group(1).split(",") if x]
    lhs_shapes = comp.symbols.get(op.operands[0])
    if not lhs_shapes:
        return 2.0 * out_elems
    lhs_dims = ([int(x) for x in lhs_shapes[0][1].split(",") if x]
                if lhs_shapes[0][1] else [])
    k = 1
    for c in cdims:
        if c < len(lhs_dims):
            k *= lhs_dims[c]
    return 2.0 * out_elems * k


def _trip_count(cond: Computation) -> int:
    consts = []
    for op in cond.ops:
        consts += [int(x) for x in _CONST_INT_RE.findall(op.line)]
    return max(consts) if consts else 1


class HloCost:
    def __init__(self, hlo_text: str):
        self.comps, self.entry = parse_module(hlo_text)
        self._memo: dict[str, CostTotals] = {}

    def _comp_cost(self, name: str) -> CostTotals:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        total = CostTotals()
        self._memo[name] = total  # guards recursion
        if comp is None:
            return total
        for op in comp.ops:
            oc = op.opcode
            out_bytes = sum(_shape_bytes(d, s) for d, s in op.out_shapes)
            out_elems = sum(_shape_elems(s) for _, s in op.out_shapes)
            if oc in _FREE_OPS or not oc:
                continue
            if oc == "while":
                cm = _COND_BODY_RE.search(op.line)
                if cm:
                    cond_name, body_name = cm.groups()
                    n = _trip_count(self.comps.get(cond_name,
                                                   Computation("?")))
                    body = self._comp_cost(body_name)
                    cond = self._comp_cost(cond_name)
                    total.flops += n * (body.flops + cond.flops)
                    total.bytes += n * (body.bytes + cond.bytes)
                    for k, v in body.collective_bytes.items():
                        total.collective_bytes[k] = (
                            total.collective_bytes.get(k, 0.0) + n * v)
                        total.collective_counts[k] = (
                            total.collective_counts.get(k, 0.0)
                            + n * body.collective_counts.get(k, 0.0))
                    for k, b, m in body.collective_ops:
                        total.collective_ops.append((k, b, n * m))
                continue
            if oc in ("fusion", "call", "async-start"):
                cm = _CALLS_RE.search(op.line) or _TO_APPLY_RE.search(op.line)
                if cm:
                    inner = self._comp_cost(cm.group(1))
                    total.flops += inner.flops
                    # bytes: fusion boundary only (operands + outputs)
                    opnd_bytes = sum(
                        _shape_bytes(d, s)
                        for o in op.operands
                        for d, s in comp.symbols.get(o, []))
                    total.bytes += out_bytes + opnd_bytes
                    for k, v in inner.collective_bytes.items():
                        total.add_collective(k, v, 1.0)
                    total.collective_ops.extend(inner.collective_ops)
                continue
            if oc == "conditional":
                branches = re.findall(r"%([\w.\-]+)", op.line.split(
                    "branch_computations")[-1]) if \
                    "branch_computations" in op.line else []
                if branches:
                    costs = [self._comp_cost(b) for b in branches]
                    best = max(costs, key=lambda c: c.flops)
                    total.flops += best.flops
                    total.bytes += best.bytes
                continue
            base = oc.replace("-start", "")
            if base in COLLECTIVE_KINDS:
                total.add_collective(base, out_bytes, 1.0)
                total.collective_ops.append((base, float(out_bytes), 1.0))
                total.bytes += out_bytes
                continue
            if oc in ("dot", "convolution"):
                total.flops += _dot_flops(op, comp)
                opnd_bytes = sum(
                    _shape_bytes(d, s)
                    for o in op.operands
                    for d, s in comp.symbols.get(o, []))
                total.bytes += out_bytes + opnd_bytes
                continue
            # generic elementwise / reduce / scatter / copy / dus ...
            total.flops += out_elems
            opnd_bytes = sum(
                _shape_bytes(d, s)
                for o in op.operands
                for d, s in comp.symbols.get(o, []))
            total.bytes += out_bytes + opnd_bytes
        return total

    def totals(self) -> CostTotals:
        return self._comp_cost(self.entry)


def analyze_hlo(hlo_text: str) -> CostTotals:
    return HloCost(hlo_text).totals()
