"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e: one pod = 16x16 = 256 chips (data, model);
    multi-pod = 2 pods = 512 chips with a leading hierarchical 'pod' axis
    (DCI-connected) carrying hierarchical FedAvg / data parallelism."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """The batch/client axes: ('pod', 'data') on multi-pod, else ('data',)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]
