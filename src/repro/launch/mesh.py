"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e: one pod = 16x16 = 256 chips (data, model);
    multi-pod = 2 pods = 512 chips with a leading hierarchical 'pod' axis
    (DCI-connected) carrying hierarchical FedAvg / data parallelism."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_edge_mesh(num_edges: int, clients_per_edge: int):
    """Two-level federation mesh (DESIGN.md §14): a leading 'edge' axis
    of E edge shards in front of the intra-edge client ('data') axis.
    Hand ``client_axes=('edge', 'data')`` to ``make_sharded_round`` with
    ``FedConfig.hierarchy.num_edges == num_edges`` and the robust
    family's aggregate stage compiles the real two-hop collective
    schedule: an intra-edge all-gather of C/E rows, then a cross-edge
    all-gather of only E candidate rows (int8 when the §10 codec is on).
    The linear family keeps its single psum over both axes — which IS
    the composed two-hop partial-sum schedule on a real torus."""
    return jax.make_mesh((num_edges, clients_per_edge), ("edge", "data"))


def data_axes(mesh) -> tuple[str, ...]:
    """The batch/client axes: ('pod', 'data') on multi-pod, else ('data',)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def client_axes(mesh) -> tuple[str, ...]:
    """The federated CLIENT axes, in hop order: the hierarchical outer
    axis first ('edge' on a §14 edge mesh, 'pod' multi-pod), then the
    intra-shard 'data' axis."""
    return tuple(a for a in mesh.axis_names if a in ("edge", "pod", "data"))


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]
