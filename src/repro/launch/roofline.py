"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (DESIGN.md §6):

  compute    = HLO_FLOPs_per_chip / peak_FLOP/s
  memory     = HLO_bytes_per_chip / HBM_bw
  collective = collective_bytes_per_chip / (links * link_bw)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` (the partitioned,
i.e. per-chip, module). collective_bytes is NOT in cost_analysis: we parse
the optimized HLO text and sum the output-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(output bytes ~ bytes moved per chip; reduce-scatter input>output and
all-gather output>input roughly cancel across a typical module — recorded
as a known approximation).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link (use 1 link as the conservative unit)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-reduce.5 = bf16[16,512]{1,0} all-reduce(
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^=]*?\b(" + "|".join(
        _COLLECTIVES) + r")\b")
# tuple-result collectives:  = (bf16[8,128], bf16[8,128]) all-reduce(
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*(" + "|".join(_COLLECTIVES) + r")\b")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    if not dims:
        return nb
    return nb * int(np.prod([int(d) for d in dims.split(",") if d]))


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()

    def add(kind, nbytes):
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1

    for line in hlo_text.splitlines():
        if "-start" in line:  # avoid double counting start/done pairs
            continue
        m = _TUPLE_RE.search(line)  # tuple results first (multi-operand)
        if m:
            shapes, kind = m.groups()
            nbytes = sum(_shape_bytes(d, s)
                         for d, s in _SHAPE_RE.findall(shapes))
            add(kind, nbytes)
            continue
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            add(kind, _shape_bytes(dtype, dims))
    return stats


@dataclass
class Roofline:
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_per_chip: float = 0.0
    useful_ratio: float = 0.0
    collectives: CollectiveStats | None = None

    def as_dict(self) -> dict:
        d = {
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "collective_bytes": self.collective_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops_per_chip": self.model_flops_per_chip,
            "useful_ratio": self.useful_ratio,
        }
        if self.collectives:
            d["collective_bytes_by_kind"] = self.collectives.bytes_by_kind
            d["collective_count_by_kind"] = self.collectives.count_by_kind
        return d


def analyze(cost: dict, hlo_text: str,
            model_flops_per_chip: float = 0.0) -> Roofline:
    """Roofline terms from the trip-count-aware HLO cost engine
    (launch/hlo_cost.py). ``cost`` (= compiled.cost_analysis()) is kept in
    the record as the XLA cross-check of the non-loop part — XLA counts
    while bodies once, so it under-counts scanned models (EXPERIMENTS.md)."""
    from repro.launch.hlo_cost import analyze_hlo

    totals = analyze_hlo(hlo_text)
    flops = float(totals.flops)
    nbytes = float(totals.bytes)
    coll = CollectiveStats(
        bytes_by_kind={k: float(v) for k, v in totals.collective_bytes.items()},
        count_by_kind={k: float(v)
                       for k, v in totals.collective_counts.items()})
    compute_s = flops / PEAK_FLOPS
    memory_s = nbytes / HBM_BW
    collective_s = coll.total_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    return Roofline(
        flops_per_chip=flops, bytes_per_chip=nbytes,
        collective_bytes=float(coll.total_bytes),
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops_per_chip=model_flops_per_chip,
        useful_ratio=(model_flops_per_chip / flops) if flops else 0.0,
        collectives=coll)


def model_flops(cfg, shape, num_chips: int) -> float:
    """6 * N * D with N = active params (MoE: routed subset), D = tokens
    processed; decode shapes process B tokens per step."""
    from repro.launch.specs import count_params

    n_total = count_params(cfg)
    if cfg.is_moe:
        # active = total - (inactive experts' FFN params)
        per_expert = 3 * cfg.d_model * cfg.d_ff * cfg.num_layers
        inactive = (cfg.num_experts - cfg.experts_per_token) * per_expert
        n_active = n_total - inactive
    else:
        n_active = n_total
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        factor = 6  # fwd 2ND + bwd 4ND
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        factor = 2
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        factor = 2
    return factor * n_active * tokens / num_chips
