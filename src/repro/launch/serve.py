"""Serving launcher: batched greedy decoding against a prefilled KV cache,
or the GPO preference-serving engine (the paper's inference product).

The GPO path trains once and checkpoints the predictor (repro.checkpoint);
``--restore`` serves the latest checkpoint from ``--ckpt-dir`` instead of
retraining, which is the actual serving contract — the trained preference
model is the product, not the training loop. Requests flow through
``core.serving.PreferenceServer`` (DESIGN.md §12): admission-controlled
queue, bucketed continuous batching, LRU prefix/KV cache over shared ICL
contexts, and optional int8 weights (``--int8``).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
      --prompt-len 16 --gen-len 16 --batch 4
  PYTHONPATH=src python -m repro.launch.serve --gpo --requests 64
  PYTHONPATH=src python -m repro.launch.serve --gpo --restore --int8 \
      --requests 64 --hit-ratio 0.75
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (
    latest_checkpoint,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs import (
    AggConfig,
    FedConfig,
    GPOConfig,
    ServeConfig,
    get_arch,
    smoke_variant,
)
from repro.core import (
    FederatedGPO,
    PreferenceServer,
    greedy_decode,
    init_gpo_params,
    latency_summary,
    make_prefill_step,
    make_request_trace,
)
from repro.data import SurveyConfig, make_survey_data, split_groups
from repro.models import init_params


def serve_lm(args) -> None:
    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    b, p = args.batch, args.prompt_len
    total = p + args.gen_len
    prompts = jax.random.randint(key, (b, p), 0, cfg.vocab_size)
    kw = {}
    if cfg.is_encoder_decoder:
        kw["enc_embeds"] = jax.random.normal(
            key, (b, cfg.enc_seq_len, cfg.d_model))
    prefill = jax.jit(lambda pr, batch: make_prefill_step(cfg, total)(
        pr, batch))
    t0 = time.time()
    last_logits, cache = prefill(params, {"tokens": prompts, **kw})
    first = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]
    toks, _ = greedy_decode(cfg, params, cache, first, p, args.gen_len - 1)
    toks = np.asarray(jnp.concatenate([first, toks], axis=1))
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={b} prompt={p} generated={args.gen_len}")
    print(f"tokens/s={b * args.gen_len / dt:.1f}")
    for i in range(min(b, 4)):
        print(f"  seq{i}: {toks[i].tolist()}")


def _restore_params(ckpt_dir: str, gcfg: GPOConfig, seed: int) -> dict:
    """Load the latest GPO checkpoint or fail with an actionable error
    (never a raw stack trace): missing checkpoint, torn/corrupt file, and
    architecture mismatch each get their own message."""
    path = latest_checkpoint(ckpt_dir)
    if path is None:
        raise SystemExit(
            f"--restore: no checkpoint under {ckpt_dir!r}; run "
            "once without --restore to train and save one")
    like = init_gpo_params(gcfg, jax.random.PRNGKey(seed))
    try:
        params = restore_checkpoint(path, like)
    except (OSError, ValueError, KeyError) as e:
        raise SystemExit(
            f"--restore: checkpoint {path!r} is unreadable or does not "
            f"match the GPO architecture ({type(e).__name__}: {e}); "
            "delete it and retrain, or point --ckpt-dir at a checkpoint "
            "saved by this launcher") from e
    print(f"restored GPO predictor from {path}")
    return params


def serve_gpo(args) -> None:
    """Preference serving for unseen groups — the aligned-LLM reward-model
    path the paper proposes (§5), through the multi-tenant engine
    (DESIGN.md §12). Trains once and checkpoints; ``--restore`` loads the
    latest checkpoint instead."""
    data = make_survey_data(SurveyConfig(seed=args.seed))
    tr, ev = split_groups(data, seed=args.seed)
    gcfg = GPOConfig(d_embed=data.phi.shape[-1])
    if args.restore:
        params = _restore_params(args.ckpt_dir, gcfg, args.seed)
    else:
        fcfg = FedConfig(num_clients=len(tr), rounds=args.rounds,
                         seed=args.seed,
                         agg=AggConfig(name=args.agg, prox_mu=args.prox_mu))
        fed = FederatedGPO(gcfg, fcfg, data, tr, ev)
        print(f"training federated GPO for {args.rounds} rounds ...")
        fed.run(rounds=args.rounds)
        params = fed.global_params
        path = save_checkpoint(
            args.ckpt_dir, args.rounds, params,
            metadata={"rounds": args.rounds, "seed": args.seed,
                      "agg": args.agg, "d_embed": gcfg.d_embed})
        print(f"saved GPO predictor to {path} (serve with --restore)")

    scfg = ServeConfig(max_batch=args.max_batch,
                       int8_weights=args.int8)
    server = PreferenceServer(params, gcfg, scfg,
                              num_options=data.num_options)
    trace = make_request_trace(
        data, list(ev), num_requests=args.requests,
        hit_ratio=args.hit_ratio, rate=args.rate, seed=args.seed + 7)
    # warm up the jit shape family before timing: compile time is a
    # one-time cost, not per-request serving latency.
    t0 = time.time()
    server.run_trace(trace[: min(len(trace), scfg.max_batch)])
    t_compile = time.time() - t0
    server.reset(clear_cache=True)
    t0 = time.time()
    results = server.run_trace(trace)
    wall = time.time() - t0
    summary = latency_summary(results, wall)
    mode = "int8" if args.int8 else "f32"
    print(f"compile+first-call: {t_compile*1e3:.1f}ms (one-time)")
    print(f"served {summary['completed']}/{args.requests} requests "
          f"({mode}) in {wall*1e3:.1f}ms over {len(server.batches)} "
          f"batches; rejected={server.stats.rejected}")
    print(f"  p50={summary['p50_ms']:.2f}ms p99={summary['p99_ms']:.2f}ms "
          f"qps={summary['qps']:.1f} "
          f"prefix-cache hit-rate={summary['hit_rate']:.2f}")
    from repro.core.fairness import alignment_score

    for c in results[: min(4, len(results))]:
        req = trace[c.rid]
        truth = np.asarray(data.prefs)[req.meta["group"], req.meta["tgt_q"]]
        score = float(alignment_score(jnp.asarray(c.pred),
                                      jnp.asarray(truth)))
        print(f"  rid={c.rid} group={req.meta['group']} AS={score:.4f} "
              f"hit={c.cache_hit} "
              f"pred[0]={np.round(c.pred[0], 3).tolist()}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--gpo", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="checkpoints/gpo_serve")
    ap.add_argument("--restore", action="store_true",
                    help="load the latest GPO checkpoint instead of "
                         "retraining (gpo mode)")
    ap.add_argument("--agg", default="fedavg",
                    help="server-aggregation strategy for the training "
                         "path (DESIGN.md §7)")
    ap.add_argument("--prox-mu", type=float, default=0.0,
                    help="FedProx proximal coefficient (required > 0 for "
                         "--agg fedprox to differ from fedavg)")
    ap.add_argument("--requests", type=int, default=32,
                    help="gpo mode: number of requests in the load trace")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="gpo mode: engine batch cap per decode dispatch")
    ap.add_argument("--hit-ratio", type=float, default=0.5,
                    help="gpo mode: fraction of requests sharing an "
                         "already-seen ICL prefix (prefix-cache pressure)")
    ap.add_argument("--rate", type=float, default=None,
                    help="gpo mode: offered request rate in req/s "
                         "(default: all arrive at t=0, saturation)")
    ap.add_argument("--int8", action="store_true",
                    help="gpo mode: quantize weights to int8 at load "
                         "time and serve through the fused int8 kernel "
                         "(DESIGN.md §12)")
    args = ap.parse_args()
    if args.gpo:
        serve_gpo(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
