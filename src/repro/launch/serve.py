"""Serving launcher: batched greedy decoding against a prefilled KV cache,
or batched GPO preference prediction (the paper's inference product).

The GPO path trains once and checkpoints the predictor (repro.checkpoint);
``--restore`` serves the latest checkpoint from ``--ckpt-dir`` instead of
retraining, which is the actual serving contract — the trained preference
model is the product, not the training loop.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
      --prompt-len 16 --gen-len 16 --batch 4
  PYTHONPATH=src python -m repro.launch.serve --gpo --batch 8
  PYTHONPATH=src python -m repro.launch.serve --gpo --restore --batch 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (
    latest_checkpoint,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs import AggConfig, FedConfig, GPOConfig, get_arch, smoke_variant
from repro.core import (
    FederatedGPO,
    greedy_decode,
    init_gpo_params,
    make_prefill_step,
    predict_preferences,
)
from repro.data import SurveyConfig, make_survey_data, sample_icl_batch, split_groups
from repro.models import init_params


def serve_lm(args) -> None:
    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    b, p = args.batch, args.prompt_len
    total = p + args.gen_len
    prompts = jax.random.randint(key, (b, p), 0, cfg.vocab_size)
    kw = {}
    if cfg.is_encoder_decoder:
        kw["enc_embeds"] = jax.random.normal(
            key, (b, cfg.enc_seq_len, cfg.d_model))
    prefill = jax.jit(lambda pr, batch: make_prefill_step(cfg, total)(
        pr, batch))
    t0 = time.time()
    last_logits, cache = prefill(params, {"tokens": prompts, **kw})
    first = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]
    toks, _ = greedy_decode(cfg, params, cache, first, p, args.gen_len - 1)
    toks = np.asarray(jnp.concatenate([first, toks], axis=1))
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={b} prompt={p} generated={args.gen_len}")
    print(f"tokens/s={b * args.gen_len / dt:.1f}")
    for i in range(min(b, 4)):
        print(f"  seq{i}: {toks[i].tolist()}")


def serve_gpo(args) -> None:
    """Batched preference prediction for unseen groups — the aligned-LLM
    reward-model serving path the paper proposes (§5). Trains once and
    checkpoints; ``--restore`` loads the latest checkpoint instead."""
    data = make_survey_data(SurveyConfig(seed=args.seed))
    tr, ev = split_groups(data, seed=args.seed)
    gcfg = GPOConfig(d_embed=data.phi.shape[-1])
    fcfg = FedConfig(num_clients=len(tr), rounds=args.rounds, seed=args.seed,
                     agg=AggConfig(name=args.agg, prox_mu=args.prox_mu))
    if args.restore:
        path = latest_checkpoint(args.ckpt_dir)
        if path is None:
            raise SystemExit(
                f"--restore: no checkpoint under {args.ckpt_dir!r}; run "
                "once without --restore to train and save one")
        like = init_gpo_params(gcfg, jax.random.PRNGKey(args.seed))
        params = restore_checkpoint(path, like)
        print(f"restored GPO predictor from {path}")
    else:
        fed = FederatedGPO(gcfg, fcfg, data, tr, ev)
        print(f"training federated GPO for {args.rounds} rounds ...")
        fed.run(rounds=args.rounds)
        params = fed.global_params
        path = save_checkpoint(
            args.ckpt_dir, args.rounds, params,
            metadata={"rounds": args.rounds, "seed": args.seed,
                      "agg": args.agg, "d_embed": gcfg.d_embed})
        print(f"saved GPO predictor to {path} (serve with --restore)")

    @jax.jit
    def predict_batch(keys, groups):
        def one(k, g):
            batch = sample_icl_batch(k, data, g, fcfg.num_context,
                                     fcfg.num_target)
            pred = predict_preferences(params, gcfg, batch.ctx_x,
                                       batch.ctx_y, batch.tgt_x,
                                       data.num_options)
            truth = batch.tgt_y.reshape(-1, data.num_options)
            return pred, truth

        return jax.vmap(one)(keys, groups)

    key = jax.random.PRNGKey(args.seed + 7)
    groups = jnp.asarray(
        np.resize(ev, args.batch), jnp.int32)
    keys = jax.random.split(key, args.batch)
    # warm up before timing: the first call pays the JIT trace+compile,
    # which is not per-request serving latency. Report both separately.
    t0 = time.time()
    jax.block_until_ready(predict_batch(keys, groups))
    t_compile = time.time() - t0
    t0 = time.time()
    pred, truth = jax.block_until_ready(predict_batch(keys, groups))
    dt = time.time() - t0
    from repro.core.fairness import alignment_score

    scores = jax.vmap(alignment_score)(pred, truth)
    print(f"compile+first-call: {t_compile*1e3:.1f}ms (one-time)")
    print(f"served {args.batch} group-preference requests in {dt*1e3:.1f}ms "
          f"steady-state ({dt*1e3/args.batch:.2f}ms/request)")
    for i in range(min(args.batch, 4)):
        print(f"  group {int(groups[i])}: AS={float(scores[i]):.4f} "
              f"pred[0]={np.round(np.asarray(pred[i][0]), 3).tolist()}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--gpo", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="checkpoints/gpo_serve")
    ap.add_argument("--restore", action="store_true",
                    help="load the latest GPO checkpoint instead of "
                         "retraining (gpo mode)")
    ap.add_argument("--agg", default="fedavg",
                    help="server-aggregation strategy for the training "
                         "path (DESIGN.md §7)")
    ap.add_argument("--prox-mu", type=float, default=0.0,
                    help="FedProx proximal coefficient (required > 0 for "
                         "--agg fedprox to differ from fedavg)")
    args = ap.parse_args()
    if args.gpo:
        serve_gpo(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
