"""Sharding rules: parameter / optimizer-state / activation / cache
PartitionSpecs for every architecture on the production meshes.

Conventions (DESIGN.md §6):

* `model` axis: tensor parallelism — attention heads (via the fused q/kv
  projection columns), d_ff, experts (when E divides the axis), vocab.
* `data` axis (+ `pod` on multi-pod): batch / FedAvg clients; with
  ``fsdp=True`` the *frozen or adafactor-trained* parameter matrices also
  shard their second dimension over it (ZeRO-3 style) — required for the
  >=27B archs to fit 16 GB/chip.
* every rule is divisibility-guarded: a dim is sharded only if the axis
  size divides it, otherwise the next candidate (or replication) is used —
  e.g. mamba2's vocab 50280 is not 16-divisible, so its embedding shards
  d_model instead.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

PyTree = Any


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _guarded(mesh, shape, assignment: dict[int, Any]) -> P:
    """Build a PartitionSpec keeping only divisible assignments."""
    spec = [None] * len(shape)
    for dim, axis in assignment.items():
        if axis is None:
            continue
        if shape[dim] % _axis_size(mesh, axis) == 0:
            spec[dim] = axis
    return P(*spec)


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------
def param_spec(path: str, shape: tuple, cfg: ModelConfig, mesh, *,
               fsdp: bool) -> P:
    """PartitionSpec for one parameter leaf. ``path`` is the jax keystr.

    Stacked per-layer leaves carry a leading L (or super-block) batch of
    dims which are never sharded; rules address the trailing dims.
    """
    d_axis = "data" if (fsdp and "data" in mesh.axis_names) else None
    nd = len(shape)
    last, sec = nd - 1, nd - 2

    def tail_matmul(in_axis, out_axis):
        return _guarded(mesh, shape, {sec: in_axis, last: out_axis})

    if re.search(r"\bembed\b|'embed'", path) or path.endswith("['embed']"):
        # (V, d): vocab over model if divisible; otherwise REPLICATE —
        # sharding the gathered (trailing) dim trips an XLA SPMD
        # dynamic-slice verifier bug inside scanned train steps (observed
        # on granite/whisper, vocab % 16 != 0), and the non-divisible
        # vocabs all belong to <1B archs where a replicated embed is cheap.
        if shape[0] % _axis_size(mesh, "model") == 0:
            return _guarded(mesh, shape, {0: "model", 1: d_axis})
        return P(*([None] * len(shape)))
    if "lm_head" in path:
        if shape[last] % _axis_size(mesh, "model") == 0:
            return tail_matmul(d_axis, "model")
        return tail_matmul("model", None)
    if "router" in path:
        return tail_matmul(d_axis, None)
    if re.search(r"w_gate|w_up", path):
        if cfg.is_moe and nd >= 3:
            # (L, E, d, ff): expert-parallel when E divides model axis
            e_dim = nd - 3
            if shape[e_dim] % _axis_size(mesh, "model") == 0:
                return _guarded(mesh, shape, {e_dim: "model", sec: d_axis})
            return tail_matmul(d_axis, "model")
        return tail_matmul(d_axis, "model")
    if "w_down" in path:
        if cfg.is_moe and nd >= 3:
            e_dim = nd - 3
            if shape[e_dim] % _axis_size(mesh, "model") == 0:
                return _guarded(mesh, shape, {e_dim: "model", last: d_axis})
            return tail_matmul("model", d_axis)
        return tail_matmul("model", d_axis)
    if re.search(r"\bwq\b|'wq'|\bwk\b|'wk'|\bwv\b|'wv'|in_proj", path):
        return tail_matmul(d_axis, "model")
    if re.search(r"\bwo\b|'wo'|out_proj", path):
        return tail_matmul("model", d_axis)
    if re.search(r"'b[qkv]'", path):
        return _guarded(mesh, shape, {last: "model"})
    # norms, conv, dt_bias, A_log, D, small vectors: replicated
    return P(*([None] * nd))


def params_shardings(params_shapes: PyTree, cfg: ModelConfig, mesh, *,
                     fsdp: bool) -> PyTree:
    def assign(path, leaf):
        return NamedSharding(
            mesh, param_spec(path, tuple(leaf.shape), cfg, mesh, fsdp=fsdp))

    return jax.tree_util.tree_map_with_path(
        lambda p, l: assign(jax.tree_util.keystr(p), l), params_shapes)


# ---------------------------------------------------------------------------
# Optimizer-state rules (state trees mirror params leaf-for-leaf)
# ---------------------------------------------------------------------------
def adam_state_shardings(p_shard: PyTree, mesh):
    """AdamState(step, mu, nu): mu/nu mirror the param shardings."""
    from repro.optim.optimizers import AdamState

    return AdamState(step=NamedSharding(mesh, P()), mu=p_shard, nu=p_shard)


def server_state_shardings(state: PyTree, mesh) -> PyTree:
    """Server-aggregator ``AggState`` is replicated on every shard
    (DESIGN.md §7): the post-psum server update is deterministic, so each
    client shard carries the momentum/moment trees and adaptive scores
    whole rather than paying a gather before every round. This covers
    the DP pipeline (DESIGN.md §9) too: clip/noise state is either
    shard-local (per-client noise keys fold out of the sharded ``keys``
    argument) or host-side (the Rényi accountant), so a private round
    adds NO device-resident server state — any pytree handed here (e.g.
    a future accountant-on-device extension) replicates the same way."""
    repl = NamedSharding(mesh, P())
    return jax.tree.map(lambda _: repl, state)


def client_delta_sharding(mesh, client_axes=None) -> NamedSharding:
    """Sharding for client-stacked round arguments — (C, ...) leaves
    whose leading axis is the global client axis — on a federation mesh:
    the leading dim shards over the client axes, ('edge', 'data') on the
    §14 two-level edge mesh, ('pod', 'data') multi-pod, ('data',)
    otherwise (``client_axes=None`` derives them from the mesh via
    ``launch.mesh.client_axes``)."""
    from repro.launch.mesh import client_axes as _client_axes

    ax = tuple(client_axes) if client_axes else _client_axes(mesh)
    return NamedSharding(mesh, P(ax if len(ax) > 1 else ax[0]))


def fault_state_shardings(mesh, client_axes=("data",)) -> PyTree:
    """Shardings for ``core.availability.FaultState`` on the production
    mesh (DESIGN.md §11). The schedule metadata — round counter, crash-
    rejoin gates, pending due/weight/birth vectors — is replicated: every
    shard recomputes the full-population failure schedule from the
    replicated fault key, so no collective is spent agreeing on who
    failed. Only ``pending`` (the in-flight straggler payloads, the one
    parameter-sized leaf, (C, P)) shards over the client axes with its
    owners — multi-axis layouts (('pod', 'data'), or the §14
    ('edge', 'data') edge mesh) pass straight through."""
    from repro.core.availability import FaultState

    ax = tuple(client_axes)
    repl = NamedSharding(mesh, P())
    return FaultState(
        round=repl,
        offline_until=repl,
        pending=NamedSharding(mesh, P(ax if len(ax) > 1 else ax[0])),
        pending_due=repl,
        pending_weight=repl,
        pending_birth=repl)


def byz_key_sharding(mesh) -> NamedSharding:
    """Sharding for the round's Byzantine key (DESIGN.md §13): REPLICATED,
    like the §11 fault key — every shard derives the full-population
    attacker mask from the one (2,) uint32 key (``adversary.
    fold_byz_key`` of the round key), spending no collective on agreeing
    who is corrupt. It is the round's LAST trailing argument (after the
    EF residual, when present)."""
    return NamedSharding(mesh, P())


def adafactor_state_shardings(p_shard: PyTree, params_shapes: PyTree, mesh):
    """AdafactorState: v_row drops the param's last dim, v_col its
    second-to-last; v_full only exists for <2-D leaves (replicated)."""
    from repro.optim.optimizers import AdafactorState

    scalar = NamedSharding(mesh, P())

    def row_one(sh: NamedSharding, shape):
        if len(shape.shape) >= 2:
            spec = tuple(sh.spec)
            return NamedSharding(mesh, P(*spec[:-1]))
        return scalar

    def col_one(sh: NamedSharding, shape):
        if len(shape.shape) >= 2:
            spec = tuple(sh.spec)
            return NamedSharding(mesh, P(*(spec[:-2] + spec[-1:])))
        return scalar

    def full_one(sh: NamedSharding, shape):
        return scalar if len(shape.shape) >= 2 else sh

    v_row = jax.tree.map(row_one, p_shard, params_shapes)
    v_col = jax.tree.map(col_one, p_shard, params_shapes)
    v_full = jax.tree.map(full_one, p_shard, params_shapes)
    return AdafactorState(step=scalar, v_row=v_row, v_col=v_col,
                          v_full=v_full)


# ---------------------------------------------------------------------------
# Activation / batch / cache rules
# ---------------------------------------------------------------------------
def batch_shardings(batch_shapes: dict, mesh, batch_axes) -> dict:
    """tokens/labels (B, S[, d]): B over the data axes (replicate if B==1)."""
    out = {}
    for k, v in batch_shapes.items():
        b = v.shape[0]
        ax = batch_axes if b % _axis_size(mesh, tuple(batch_axes)) == 0 \
            else None
        spec = [None] * len(v.shape)
        if ax:
            spec[0] = tuple(ax) if len(ax) > 1 else ax[0]
        out[k] = NamedSharding(mesh, P(*spec))
    return out


def cache_shardings(cache_shapes: dict, cfg: ModelConfig, mesh,
                    batch_axes) -> dict:
    """Decode caches.

    * batch > 1: batch over data axes; KV heads over model when divisible,
      else the sequence axis over model (flash-decode style partial
      attention, GSPMD inserts the combine).
    * batch == 1 (long_500k): the cache SEQUENCE axis carries the
      parallelism — over (data x model) when KV heads don't divide model,
      else seq over data + KV over model.
    """
    m = _axis_size(mesh, "model")
    d_ax = tuple(batch_axes)
    out = {}
    for k, v in cache_shapes.items():
        shape = v.shape
        spec = [None] * len(shape)
        if k in ("ring_k", "ring_v", "glob_k", "glob_v"):
            # (n_super, n_per, B, S|W, KV, hd): batch over data, KV over
            # model when divisible, else the length axis over model
            _, _, B, S, KV, _ = shape
            if B % _axis_size(mesh, d_ax) == 0 and B > 1:
                spec[2] = d_ax if len(d_ax) > 1 else d_ax[0]
            if KV % m == 0:
                spec[4] = "model"
            elif S % m == 0:
                spec[3] = "model"
            out[k] = NamedSharding(mesh, P(*spec))
            continue
        if k in ("tail_k", "tail_v"):
            _, B, S, KV, _ = shape
            if B % _axis_size(mesh, d_ax) == 0 and B > 1:
                spec[1] = d_ax if len(d_ax) > 1 else d_ax[0]
            if KV % m == 0:
                spec[3] = "model"
            elif S % m == 0:
                spec[2] = "model"
            out[k] = NamedSharding(mesh, P(*spec))
            continue
        if k in ("k", "v", "cross_k", "cross_v", "shared_k", "shared_v"):
            L, B, S, KV, hd = shape
            big_batch = B % _axis_size(mesh, d_ax) == 0 and B > 1
            if big_batch:
                spec[1] = d_ax if len(d_ax) > 1 else d_ax[0]
                if KV % m == 0:
                    spec[3] = "model"
                elif S % m == 0:
                    spec[2] = "model"
            else:
                if KV % m == 0:
                    spec[3] = "model"
                    if S % _axis_size(mesh, d_ax) == 0:
                        spec[2] = d_ax if len(d_ax) > 1 else d_ax[0]
                else:
                    both = d_ax + ("model",)
                    if S % _axis_size(mesh, both) == 0:
                        spec[2] = both
        elif k == "ssm":
            L, B, H, Pd, N = shape
            if B % _axis_size(mesh, d_ax) == 0 and B > 1:
                spec[1] = d_ax if len(d_ax) > 1 else d_ax[0]
            if H % m == 0:
                spec[2] = "model"
        elif k == "conv":
            L, B, W, C = shape
            if B % _axis_size(mesh, d_ax) == 0 and B > 1:
                spec[1] = d_ax if len(d_ax) > 1 else d_ax[0]
            if C % m == 0:
                spec[3] = "model"
        out[k] = NamedSharding(mesh, P(*spec))
    return out
