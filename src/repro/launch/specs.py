"""ShapeDtypeStruct stand-ins for every model input (no allocation), plus
per-architecture dry-run training settings.

``input_specs(cfg, shape)`` mirrors exactly what the real data pipeline /
serving frontend produces:

* token archs: {"tokens": (B, S) i32, "labels": (B, S) i32}
* VLM (llava): the anyres ViT+projector frontend is a STUB — the spec is
  pre-projected patch+text embeddings (B, S, d_model) bf16 (+ labels).
* audio (whisper): the mel+conv frontend is a STUB — encoder frames
  (B, 1500, d_model) bf16; decoder consumes tokens.
* decode shapes: ONE new token (B, 1) + the pre-allocated cache specs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import GLOBAL, InputShape, ModelConfig, override
from repro.models import init_cache

Sds = jax.ShapeDtypeStruct


@dataclass(frozen=True)
class TrainSettings:
    optimizer: str  # "adam" | "adafactor"
    microbatch: int
    remat: bool = True
    fsdp: bool = True


# chosen by parameter count (DESIGN.md §6): adafactor + deep microbatching
# for the >=5B archs, adam for the small ones.
# microbatch counts sized so the per-chip transient attention-score /
# dispatch buffers stay O(few GB) at train_4k (memory_analysis-verified)
ARCH_TRAIN_SETTINGS: dict[str, TrainSettings] = {
    # grok: mb=4 adopted in §Perf iteration 3 (4x fewer FSDP weight
    # re-gathers; activation headroom verified at 194 MB/chip)
    "grok-1-314b": TrainSettings("adafactor", 4),
    "llava-next-34b": TrainSettings("adafactor", 16),
    "qwen3-32b": TrainSettings("adafactor", 16),
    "gemma2-27b": TrainSettings("adafactor", 16),
    "gemma3-27b": TrainSettings("adafactor", 16),
    "granite-moe-3b-a800m": TrainSettings("adam", 8),
    "zamba2-1.2b": TrainSettings("adam", 8),
    "mamba2-780m": TrainSettings("adam", 1),
    "whisper-small": TrainSettings("adam", 4),
    "qwen2-0.5b": TrainSettings("adam", 8),
}


def train_settings(cfg: ModelConfig) -> TrainSettings:
    return ARCH_TRAIN_SETTINGS.get(cfg.name, TrainSettings("adam", 1))


def serving_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Apply the long-context serving variant where required.

    Pure full-attention archs run long_500k only as the documented
    sliding-window variant (DESIGN.md §5); gemma2/3, zamba2, mamba2 are
    natively sub-quadratic and keep their published pattern.
    """
    if shape.name == "long_500k" and cfg.long_context_variant:
        return override(cfg, window_pattern=(cfg.long_context_window,))
    return cfg


def batch_specs(cfg: ModelConfig, shape: InputShape, *,
                with_labels: bool) -> dict[str, Sds]:
    b, s = shape.global_batch, shape.seq_len
    adt = jnp.dtype(cfg.activation_dtype)
    out: dict[str, Sds] = {}
    if cfg.input_kind == "embeddings":
        out["embeds"] = Sds((b, s, cfg.d_model), adt)
    else:
        out["tokens"] = Sds((b, s), jnp.int32)
    if cfg.is_encoder_decoder:
        out["enc_embeds"] = Sds((b, cfg.enc_seq_len, cfg.d_model), adt)
    if with_labels:
        out["labels"] = Sds((b, s), jnp.int32)
    return out


def cache_specs(cfg: ModelConfig, shape: InputShape) -> dict[str, Sds]:
    """eval_shape of init_cache — no allocation."""
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))


def decode_specs(cfg: ModelConfig, shape: InputShape):
    """(cache, tokens, cache_pos) specs for serve_step."""
    cache = cache_specs(cfg, shape)
    tokens = Sds((shape.global_batch, 1), jnp.int32)
    pos = Sds((), jnp.int32)
    return cache, tokens, pos


def params_specs(cfg: ModelConfig) -> Any:
    from repro.models import init_params

    return jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Every model input for the given workload shape, as
    ShapeDtypeStructs (weak-type-correct, shardable, zero allocation)."""
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, shape, with_labels=True)}
    if shape.kind == "prefill":
        return {"batch": batch_specs(cfg, shape, with_labels=False)}
    cache, tokens, pos = decode_specs(cfg, shape)
    return {"cache": cache, "tokens": tokens, "cache_pos": pos}


def count_params(cfg: ModelConfig) -> int:
    import numpy as np

    shapes = params_specs(cfg)
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(shapes)))
