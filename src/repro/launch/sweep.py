import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Run the full (architecture x input-shape) dry-run sweep, resumably.

  PYTHONPATH=src python -m repro.launch.sweep --out results/dryrun.jsonl
  PYTHONPATH=src python -m repro.launch.sweep --multi-pod --out results/dryrun_mp.jsonl

Each pair is lowered+compiled in-process; results append as JSON lines.
Already-recorded (arch, shape, multi_pod) triples are skipped, so the sweep
can be re-launched after interruption.
"""  # noqa: E402

import argparse
import gc
import json
import time
import traceback


def done_keys(path: str) -> set:
    keys = set()
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if "error" not in r:
                    keys.add((r["arch"], r["shape"], r.get("multi_pod",
                                                           False)))
    return keys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--arch", default=None, help="restrict to one arch")
    ap.add_argument("--shape", default=None, help="restrict to one shape")
    args = ap.parse_args()

    from repro.configs import ALL_ARCHS, INPUT_SHAPES
    from repro.launch.dryrun import lower_pair

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = done_keys(args.out)
    archs = [args.arch] if args.arch else list(ALL_ARCHS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    todo = [(a, s) for a in archs for s in shapes
            if (a, s, args.multi_pod) not in done]
    print(f"sweep: {len(todo)} pairs to run (skipping {len(done)} done)")

    for i, (arch, shape) in enumerate(todo):
        t0 = time.time()
        print(f"[{i+1}/{len(todo)}] {arch} x {shape} "
              f"multi_pod={args.multi_pod}", flush=True)
        try:
            result = lower_pair(arch, shape, multi_pod=args.multi_pod,
                                verbose=False)
            status = "ok"
        except Exception:
            result = {"arch": arch, "shape": shape,
                      "multi_pod": args.multi_pod,
                      "error": traceback.format_exc()}
            status = "ERROR"
        with open(args.out, "a") as f:
            f.write(json.dumps(result) + "\n")
        print(f"   -> {status} in {time.time()-t0:.0f}s", flush=True)
        import jax

        jax.clear_caches()
        gc.collect()
    print("sweep complete")


if __name__ == "__main__":
    main()
