"""Training launcher.

Three trainer modes, all runnable on CPU with --smoke (reduced configs):

  standard  — plain LM training of the selected architecture.
  fedavg    — the paper's technique on the backbone: C clients run local
              LM steps on disjoint data shards; rounds end with Eq. 3
              weighted parameter averaging.
  fedlora   — frozen backbone, federated LoRA adapters (the large-arch
              recipe).
  gpo       — the paper's own experiment: federated GPO preference
              predictor on synthetic survey data (see benchmarks/ for the
              full figure reproduction).

All federated trainers take ``--agg`` (plus the matching hyperparameter
flags) to select the server-aggregation strategy from the registry in
``repro.core.aggregation`` (DESIGN.md §7), ``--clip-norm`` /
``--noise-multiplier`` / ``--dp-delta`` to run the differentially-
private client-delta pipeline (DESIGN.md §9; per-round ε is reported
from the Rényi accountant), and ``--compress`` / ``--topk-frac`` /
``--no-error-feedback`` to compress the client→server deltas (int8
stochastic quantization or top-k sparsification with an EF21 residual,
DESIGN.md §10 — applied AFTER the DP release, so ε is unchanged).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --trainer fedavg --rounds 3 --local-steps 2 --agg fedavgm
  PYTHONPATH=src python -m repro.launch.train --trainer gpo --rounds 50 \
      --agg adaptive
  PYTHONPATH=src python -m repro.launch.train --trainer gpo --rounds 50 \
      --clip-norm 0.5 --noise-multiplier 0.8
  PYTHONPATH=src python -m repro.launch.train --trainer gpo --rounds 50 \
      --compress int8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import (
    AdversaryConfig,
    AggConfig,
    CompressionConfig,
    FedConfig,
    GPOConfig,
    INPUT_SHAPES,
    PrivacyConfig,
    get_arch,
    smoke_variant,
)
from repro.core.privacy import make_accountant
from repro.core import (
    AGGREGATORS,
    FederatedGPO,
    broadcast_to_clients,
    init_lora,
    make_aggregator,
    make_backbone_fedavg_round,
    make_fedlora_round,
    make_train_step,
    normalize_weights,
)
from repro.data import LMDataConfig, make_survey_data, SurveyConfig, split_groups
from repro.data.lm_data import synthetic_lm_batches
from repro.models import init_params
from repro.optim import adam
from repro.utils.pytree import tree_count_params


def _stack_client_batches(it, clients: int, steps: int):
    batches = [[next(it) for _ in range(steps)] for _ in range(clients)]
    per_client = [
        jax.tree.map(lambda *xs: jnp.stack(xs), *bs) for bs in batches]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_client)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--trainer", default="standard",
                    choices=["standard", "fedavg", "fedlora", "gpo"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    # server-aggregation strategy (DESIGN.md §7); applies to the gpo,
    # fedavg, and fedlora trainers
    ap.add_argument("--agg", default="fedavg", choices=AGGREGATORS.names())
    ap.add_argument("--server-lr", type=float, default=1.0)
    ap.add_argument("--server-momentum", type=float, default=0.9,
                    help="fedavgm server momentum")
    ap.add_argument("--prox-mu", type=float, default=0.0,
                    help="FedProx client proximal coefficient (gpo trainer)")
    ap.add_argument("--trim-frac", type=float, default=0.1,
                    help="trimmed_mean per-side trim fraction")
    ap.add_argument("--fair-temp", type=float, default=1.0,
                    help="adaptive fairness-weight temperature")
    # DP client-delta pipeline (DESIGN.md §9); applies to every
    # federated trainer. --clip-norm 0 (default) disables it.
    ap.add_argument("--clip-norm", type=float, default=0.0,
                    help="per-client L2 clip on the flat delta (0 = off)")
    ap.add_argument("--noise-multiplier", type=float, default=0.0,
                    help="Gaussian noise std = z * clip-norm per client")
    ap.add_argument("--dp-delta", type=float, default=1e-5,
                    help="target delta for the Renyi accountant's eps")
    # client->server delta compression (DESIGN.md §10); applies to every
    # federated trainer. --compress none (default) disables it.
    ap.add_argument("--compress", default="none",
                    choices=["none", "int8", "topk"],
                    help="delta codec: int8 stochastic quantization or "
                         "top-k magnitude sparsification")
    ap.add_argument("--topk-frac", type=float, default=0.01,
                    help="fraction of coordinates kept per client "
                         "(--compress topk)")
    ap.add_argument("--no-error-feedback", action="store_true",
                    help="disable the EF21 error-feedback residual")
    # Byzantine attack simulation + defenses (DESIGN.md §13). --attack
    # none (default) disables the stage; pick a defense with --agg
    # krum/multi_krum/geomedian/median and/or --norm-bound.
    ap.add_argument("--attack", default="none",
                    choices=["none", "sign_flip", "scaled", "gaussian",
                             "alie", "label_flip"],
                    help="per-round Byzantine client attack (label_flip "
                         "is gpo-only)")
    ap.add_argument("--attackers", type=int, default=0,
                    help="number of Byzantine clients per round (also "
                         "the defenses' assumed f)")
    ap.add_argument("--attack-scale", type=float, default=10.0,
                    help="model-replacement factor for --attack scaled")
    ap.add_argument("--norm-bound", type=float, default=0.0,
                    help="server-side per-client L2 norm bound on "
                         "received deltas (0 = off)")
    ap.add_argument("--multi-krum-m", type=int, default=3,
                    help="rows averaged by --agg multi_krum")
    args = ap.parse_args()

    agg_cfg = AggConfig(name=args.agg, server_lr=args.server_lr,
                        momentum=args.server_momentum,
                        prox_mu=args.prox_mu, trim_frac=args.trim_frac,
                        fair_temp=args.fair_temp,
                        num_malicious=args.attackers,
                        multi_krum_m=args.multi_krum_m,
                        norm_bound=args.norm_bound)
    adv_cfg = AdversaryConfig(kind=args.attack,
                              num_attackers=args.attackers,
                              scale=args.attack_scale)
    adv_cfg.validate()
    priv_cfg = PrivacyConfig(clip_norm=args.clip_norm,
                             noise_multiplier=args.noise_multiplier,
                             target_delta=args.dp_delta)
    priv_cfg.validate()
    comp_cfg = CompressionConfig(kind=args.compress,
                                 topk_frac=args.topk_frac,
                                 error_feedback=not args.no_error_feedback)
    comp_cfg.validate()

    if args.trainer == "gpo":
        data = make_survey_data(SurveyConfig(seed=args.seed))
        tr, ev = split_groups(data, seed=args.seed)
        gcfg = GPOConfig(d_embed=data.phi.shape[-1])
        fcfg = FedConfig(num_clients=len(tr), rounds=args.rounds,
                         seed=args.seed, agg=agg_cfg, privacy=priv_cfg,
                         compression=comp_cfg, adversary=adv_cfg)
        fed = FederatedGPO(gcfg, fcfg, data, tr, ev)
        hist = fed.run(rounds=args.rounds, log_every=10)
        print(f"final loss={hist.round_loss[-1]:.4f} "
              f"AS={hist.eval_mean_as[-1]:.4f} FI={hist.eval_fi[-1]:.4f}")
        if hist.round_eps:
            print(f"privacy: eps={hist.round_eps[-1]:.3f} at "
                  f"delta={priv_cfg.target_delta:g} after {args.rounds} "
                  f"rounds (clip={priv_cfg.clip_norm}, "
                  f"z={priv_cfg.noise_multiplier})")
        if args.ckpt_dir:
            save_checkpoint(args.ckpt_dir, args.rounds, fed.global_params)
        return

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    opt = adam(args.lr)
    data_cfg = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                            global_batch=args.batch, seed=args.seed)
    it = synthetic_lm_batches(data_cfg)

    if args.trainer == "standard":
        step = jax.jit(make_train_step(cfg, opt))
        opt_state = opt.init(params)
        t0 = time.time()
        for i in range(args.steps):
            params, opt_state, m = step(params, opt_state, next(it))
            if i % max(1, args.steps // 10) == 0:
                print(f"step {i:4d} loss={float(m['loss']):.4f}")
        print(f"done: {args.steps} steps in {time.time()-t0:.1f}s "
              f"final loss={float(m['loss']):.4f}")
    else:
        c = args.clients
        weights = normalize_weights(jnp.ones((c,)))
        agg = make_aggregator(agg_cfg, num_clients=c)
        if args.trainer == "fedavg":
            client_params = broadcast_to_clients(params, c)
            opt_states = jax.vmap(opt.init)(client_params)
            rnd = jax.jit(make_backbone_fedavg_round(
                cfg, opt, args.local_steps, agg=agg, privacy=priv_cfg,
                compression=comp_cfg, adversary=adv_cfg))
            server_state = agg.init(params)
            payload = params
        else:
            lora = init_lora(params, key, rank=8)
            client_params = broadcast_to_clients(lora, c)
            opt_states = jax.vmap(opt.init)(client_params)
            rnd = jax.jit(make_fedlora_round(
                cfg, params, opt, args.local_steps, agg=agg,
                privacy=priv_cfg, compression=comp_cfg,
                adversary=adv_cfg))
            server_state = agg.init(lora)
            payload = lora
        # full participation => sampling rate 1 for the accountant
        accountant = make_accountant(priv_cfg, 1.0)
        noise_base = jax.random.PRNGKey(args.seed + 17)
        # EF residual (DESIGN.md §10): one flat f32 row per client
        ef = comp_cfg.enabled and comp_cfg.error_feedback
        # trailing-arg contract of _aggregated_round: [resid][, round_key]
        need_key = (priv_cfg.enabled
                    or (comp_cfg.enabled and comp_cfg.needs_rng)
                    or adv_cfg.enabled)
        resid = (jnp.zeros((c, tree_count_params(payload)), jnp.float32)
                 if ef else None)
        for r in range(args.rounds):
            batches = _stack_client_batches(it, c, args.local_steps)
            round_args = (client_params, opt_states, batches, weights,
                          server_state)
            if ef:
                round_args += (resid,)
            if need_key:
                round_args += (jax.random.fold_in(noise_base, r),)
            out = rnd(*round_args)
            client_params, opt_states, losses, server_state = out[:4]
            if ef:
                resid = out[4]
            eps = (f" eps={accountant.epsilon(r + 1):.3f}"
                   if accountant else "")
            print(f"round {r:3d} client losses="
                  f"{np.round(np.asarray(losses), 4)}{eps}")
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps,
                        params if args.trainer == "standard"
                        else client_params)


if __name__ == "__main__":
    main()
