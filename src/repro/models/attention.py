"""GQA attention with windowing, softcapping, qk-norm, and KV caching.

One attention implementation serves every assigned arch:

* GQA via head-grouped einsum (never materializes repeated KV in HBM);
* window may be a *traced* per-layer scalar, so local/global alternating
  patterns (gemma2 1:1, gemma3 5:1) ride a single ``lax.scan`` over layers
  with the window as scan-xs — this is what keeps the HLO small enough to
  compile 62-layer models quickly;
* decode attends one query against a pre-allocated cache with validity
  masking (positions >= cache_pos are masked).

The Pallas flash kernel (`repro.kernels.flash_attention`) implements the
same contract for the TPU target; this jnp path is the oracle and the
dry-run lowering path.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm, rope, softcap
from repro.models.partitioning import (
    prefers_q_sharding,
    prefers_repeat_kv,
    shard_act,
)

NEG_INF = -2.3819763e38  # bf16-safe large negative


class AttnParams(NamedTuple):
    wq: jnp.ndarray  # (d, H*hd)
    wk: jnp.ndarray  # (d, KV*hd)
    wv: jnp.ndarray  # (d, KV*hd)
    wo: jnp.ndarray  # (H*hd, d)
    bq: Optional[jnp.ndarray]
    bk: Optional[jnp.ndarray]
    bv: Optional[jnp.ndarray]
    q_norm: Optional[jnp.ndarray]  # (hd,)
    k_norm: Optional[jnp.ndarray]  # (hd,)


def init_attn_params(key, cfg, dtype) -> AttnParams:
    from repro.models.layers import dense_init

    ks = jax.random.split(key, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    zeros = lambda n: jnp.zeros((n,), dtype)  # noqa: E731
    return AttnParams(
        wq=dense_init(ks[0], (d, qd), dtype=dtype),
        wk=dense_init(ks[1], (d, kvd), dtype=dtype),
        wv=dense_init(ks[2], (d, kvd), dtype=dtype),
        wo=dense_init(ks[3], (qd, d), dtype=dtype),
        bq=zeros(qd) if cfg.qkv_bias else None,
        bk=zeros(kvd) if cfg.qkv_bias else None,
        bv=zeros(kvd) if cfg.qkv_bias else None,
        q_norm=jnp.zeros((cfg.head_dim,), dtype) if cfg.qk_norm else None,
        k_norm=jnp.zeros((cfg.head_dim,), dtype) if cfg.qk_norm else None,
    )


def _project_qkv(p: AttnParams, x, num_heads, num_kv, head_dim, eps):
    b, s, _ = x.shape
    q = jnp.einsum("bsd,de->bse", x, p.wq)
    k = jnp.einsum("bsd,de->bse", x, p.wk)
    v = jnp.einsum("bsd,de->bse", x, p.wv)
    if p.bq is not None:
        q, k, v = q + p.bq, k + p.bk, v + p.bv
    q = shard_act(q.reshape(b, s, num_heads, head_dim),
                  ("batch", "seq", "heads", "hd"))
    k = shard_act(k.reshape(b, s, num_kv, head_dim),
                  ("batch", "seq", "kv_heads", "hd"))
    v = shard_act(v.reshape(b, s, num_kv, head_dim),
                  ("batch", "seq", "kv_heads", "hd"))
    if p.q_norm is not None:
        q = rms_norm(q, p.q_norm, eps)
        k = rms_norm(k, p.k_norm, eps)
    return q, k, v


def gqa_scores_softmax(q, k, v, mask, logit_cap):
    """q (b,sq,H,hd), k/v (b,sk,KV,hd), mask (b,1 or KV*G? , sq, sk) bool.

    Returns (b, sq, H, hd). Softmax in f32.
    """
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    if kv != h and prefers_repeat_kv(h, kv):
        # repeated-KV layout: keeps one shardable 'heads' dim when the
        # grouped form would force score replication (see partitioning.py)
        g = h // kv
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        kv = h
    if kv == h:
        from repro.models.partitioning import logical_axis_size

        h_ok = h % max(logical_axis_size("heads"), 1) == 0
        if not h_ok:  # MHA with non-divisible heads: q-sequence shard
            q = shard_act(q, ("batch", "seq_q", None, None))
        scores = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32)
        scores = shard_act(scores, ("batch", "heads", None, None) if h_ok
                           else ("batch", None, "seq_q", None))
        scores = scores / jnp.sqrt(jnp.asarray(hd, jnp.float32))
        if logit_cap is not None:
            scores = softcap(scores, logit_cap)
        scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhqs,bshd->bqhd", probs, v)
        return shard_act(out, ("batch", "seq", "heads", "hd"))
    g = h // kv
    q_sharded = prefers_q_sharding(h, kv)
    if q_sharded:
        q = shard_act(q, ("batch", "seq_q", None, None))
    qg = q.reshape(b, sq, kv, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    scores = shard_act(scores, ("batch", "kv_heads", None,
                                "seq_q" if q_sharded else None, None))
    scores = scores / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    if logit_cap is not None:
        scores = softcap(scores, logit_cap)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    out = out.reshape(b, sq, h, hd)
    return shard_act(out, ("batch", "seq", "heads", "hd"))


def make_causal_window_mask(q_pos, k_pos, window, k_valid=None):
    """bool mask (b?, sq, sk). window traced scalar; <=0 means global."""
    causal = k_pos[..., None, :] <= q_pos[..., :, None]
    dist = q_pos[..., :, None] - k_pos[..., None, :]
    win = jnp.asarray(window, jnp.int32)
    inside = jnp.where(win > 0, dist < win, True)
    mask = causal & inside
    if k_valid is not None:
        mask = mask & k_valid[..., None, :]
    return mask


# sequences >= this use the q-chunked (flash-style) XLA path: scores for a
# 32k prefill would otherwise materialize B*H*S^2 f32 (hundreds of GB/chip)
CHUNK_THRESHOLD = 8192
Q_CHUNK = 1024


def _chunked_gqa(q, k, v, window, logit_cap, q_chunk: int):
    """Causal/windowed attention, scanning q in chunks of ``q_chunk``.

    q (b,s,h,hd) and k/v (b,s,kv,hd) are already roped. Peak score memory
    drops from O(S^2) to O(q_chunk * S) — the XLA-level analogue of the
    Pallas flash kernel (which replaces this on real TPUs).
    """
    b, s, h, hd = q.shape
    assert s % q_chunk == 0, (s, q_chunk)
    nq = s // q_chunk
    qc = q.reshape(b, nq, q_chunk, h, hd).transpose(1, 0, 2, 3, 4)
    k_pos = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)

    def body(_, inp):
        qblk, i = inp  # (b, qc, h, hd), scalar chunk index
        q_pos = (i * q_chunk
                 + jnp.arange(q_chunk, dtype=jnp.int32))[None, :].repeat(b, 0)
        mask = make_causal_window_mask(q_pos, k_pos, window)
        out = gqa_scores_softmax(qblk, k, v, mask, logit_cap)
        return None, out

    _, outs = jax.lax.scan(body, None, (qc, jnp.arange(nq)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)


def attend_full(p: AttnParams, x, cfg, *, window, theta, positions=None):
    """Training / encoder-free full-sequence self attention (no cache).

    positions defaults to arange; window/theta may be traced scalars.
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
    q, k, v = _project_qkv(p, x, cfg.num_heads, cfg.num_kv_heads,
                           cfg.head_dim, cfg.norm_eps)
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)
    if s >= CHUNK_THRESHOLD and s % Q_CHUNK == 0:
        out = _chunked_gqa(q, k, v, window, cfg.attn_logit_softcap, Q_CHUNK)
    else:
        mask = make_causal_window_mask(positions, positions, window)
        out = gqa_scores_softmax(q, k, v, mask, cfg.attn_logit_softcap)
    return jnp.einsum("bse,ed->bsd", out.reshape(b, s, -1), p.wo)


def prefill(p: AttnParams, x, cfg, *, window, theta, cache_len):
    """Full-sequence attention that also materializes the KV cache.

    Returns (out (b,s,d), k_cache (b,cache_len,KV,hd), v_cache).
    """
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
    q, k, v = _project_qkv(p, x, cfg.num_heads, cfg.num_kv_heads,
                           cfg.head_dim, cfg.norm_eps)
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)
    if s >= CHUNK_THRESHOLD and s % Q_CHUNK == 0:
        out = _chunked_gqa(q, k, v, window, cfg.attn_logit_softcap, Q_CHUNK)
    else:
        mask = make_causal_window_mask(positions, positions, window)
        out = gqa_scores_softmax(q, k, v, mask, cfg.attn_logit_softcap)
    out = jnp.einsum("bse,ed->bsd", out.reshape(b, s, -1), p.wo)
    pad = cache_len - s
    if pad > 0:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return out, k, v


def decode_step(p: AttnParams, x, k_cache, v_cache, cache_pos, cfg, *,
                window, theta):
    """One-token decode. x (b,1,d); caches (b,S,KV,hd); cache_pos scalar.

    Writes the new KV at cache_pos, attends against positions < cache_pos+1.
    Returns (out (b,1,d), k_cache, v_cache).
    """
    b = x.shape[0]
    s_max = k_cache.shape[1]
    pos = jnp.full((b, 1), cache_pos, jnp.int32)
    q, k, v = _project_qkv(p, x, cfg.num_heads, cfg.num_kv_heads,
                           cfg.head_dim, cfg.norm_eps)
    q = rope(q, pos, theta)
    k = rope(k, pos, theta)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, cache_pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, cache_pos, axis=1)
    k_pos = jnp.arange(s_max, dtype=jnp.int32)[None, :].repeat(b, 0)
    k_valid = k_pos <= cache_pos  # includes the token just written
    mask = make_causal_window_mask(pos, k_pos, window, k_valid=k_valid)
    out = gqa_scores_softmax(q, k_cache, v_cache, mask, cfg.attn_logit_softcap)
    out = jnp.einsum("bse,ed->bsd", out.reshape(b, 1, -1), p.wo)
    return out, k_cache, v_cache


def ring_decode_step(p: AttnParams, x, k_cache, v_cache, cache_pos, cfg, *,
                     window: int, theta):
    """One-token decode against a RING cache of ``window`` slots.

    The cache holds the last ``window`` (roped) keys/values at slot
    ``pos % window``; slot s currently stores true position
    ``pos - (slot - s)`` if s <= slot else ``pos - (slot + window - s)``,
    which is always within (pos - window, pos] — so the sliding-window +
    causal mask reduces to ``true_pos >= 0`` (unfilled slots).

    Memory: O(window) instead of O(context) per local layer — for gemma3's
    5:1 pattern at 32k that removes 97% of local-layer cache traffic.
    """
    b = x.shape[0]
    w = k_cache.shape[1]
    pos = jnp.full((b, 1), cache_pos, jnp.int32)
    q, k, v = _project_qkv(p, x, cfg.num_heads, cfg.num_kv_heads,
                           cfg.head_dim, cfg.norm_eps)
    q = rope(q, pos, theta)
    k = rope(k, pos, theta)
    slot = jnp.asarray(cache_pos, jnp.int32) % w
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot, axis=1)
    s = jnp.arange(w, dtype=jnp.int32)
    true_pos = jnp.where(s <= slot,
                         cache_pos - (slot - s),
                         cache_pos - (slot + w - s))
    mask = (true_pos >= 0)[None, None, :].repeat(b, 0)  # (b, 1, w)
    out = gqa_scores_softmax(q, k_cache, v_cache, mask,
                             cfg.attn_logit_softcap)
    out = jnp.einsum("bse,ed->bsd", out.reshape(b, 1, -1), p.wo)
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder -> encoder states)
# ---------------------------------------------------------------------------
class CrossAttnParams(NamedTuple):
    wq: jnp.ndarray
    wk: jnp.ndarray
    wv: jnp.ndarray
    wo: jnp.ndarray


def init_cross_attn_params(key, cfg, dtype) -> CrossAttnParams:
    from repro.models.layers import dense_init

    ks = jax.random.split(key, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    return CrossAttnParams(
        wq=dense_init(ks[0], (d, qd), dtype=dtype),
        wk=dense_init(ks[1], (d, kvd), dtype=dtype),
        wv=dense_init(ks[2], (d, kvd), dtype=dtype),
        wo=dense_init(ks[3], (qd, d), dtype=dtype),
    )


def cross_kv(p: CrossAttnParams, enc_out, cfg):
    """Precompute (k, v) for the encoder memory (done once at prefill)."""
    b, s, _ = enc_out.shape
    k = jnp.einsum("bsd,de->bse", enc_out, p.wk).reshape(
        b, s, cfg.num_kv_heads, cfg.head_dim)
    v = jnp.einsum("bsd,de->bse", enc_out, p.wv).reshape(
        b, s, cfg.num_kv_heads, cfg.head_dim)
    return k, v


def cross_attend(p: CrossAttnParams, x, k, v, cfg):
    b, sq, _ = x.shape
    sk = k.shape[1]
    q = jnp.einsum("bsd,de->bse", x, p.wq).reshape(
        b, sq, cfg.num_heads, cfg.head_dim)
    mask = jnp.ones((b, sq, sk), bool)
    out = gqa_scores_softmax(q, k, v, mask, None)
    return jnp.einsum("bse,ed->bsd", out.reshape(b, sq, -1), p.wo)


def encoder_self_attend(p: AttnParams, x, cfg):
    """Bidirectional (encoder) self attention, sinusoid-free (RoPE)."""
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
    q, k, v = _project_qkv(p, x, cfg.num_heads, cfg.num_kv_heads,
                           cfg.head_dim, cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    mask = jnp.ones((b, s, s), bool)
    out = gqa_scores_softmax(q, k, v, mask, cfg.attn_logit_softcap)
    return jnp.einsum("bse,ed->bsd", out.reshape(b, s, -1), p.wo)
