"""Primitive layers shared by every backbone."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init (LeCun)."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    std = 1.0 / jnp.sqrt(fan_in)
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    normed = x * jax.lax.rsqrt(var + eps)
    # (1 + scale) parameterization (gemma/llama style, init scale = 0)
    return (normed * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(x: jnp.ndarray, cap) -> jnp.ndarray:
    return cap * jnp.tanh(x / cap)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta) -> jnp.ndarray:
    """Rotary embedding.

    x: (..., S, H, hd) — positions: broadcastable to (..., S).
    ``theta`` may be a traced scalar (per-layer theta rides the layer scan in
    gemma3).
    """
    hd = x.shape[-1]
    half = hd // 2
    freq_exponents = jnp.arange(half, dtype=jnp.float32) / half
    inv_freq = jnp.asarray(theta, jnp.float32) ** (-freq_exponents)  # (half,)
    angles = positions.astype(jnp.float32)[..., None] * inv_freq  # (..., S, half)
    sin = jnp.sin(angles)[..., None, :]  # (..., S, 1, half)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate, w_up, w_down) -> jnp.ndarray:
    from repro.models.partitioning import shard_act

    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(u.dtype) * u
    if h.ndim == 3:
        h = shard_act(h, ("batch", "seq", "ff"))
    out = jnp.einsum("...f,fd->...d", h, w_down)
    if out.ndim == 3:
        out = shard_act(out, ("batch", "seq", "embed"))
    return out


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       final_softcap=None) -> jnp.ndarray:
    """Mean next-token NLL. logits (B, S, V) any float dtype; labels (B, S)."""
    logits = logits.astype(jnp.float32)
    if final_softcap is not None:
        logits = softcap(logits, final_softcap)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
