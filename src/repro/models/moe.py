"""Mixture-of-Experts MLP with top-k routing and capacity-bounded
scatter/gather dispatch.

Design notes (TPU adaptation, see DESIGN.md §4):

* Dispatch is **sort/scatter based**, not the Shazeer one-hot-einsum
  dispatch: the einsum form counts T*E*C*d fake MAC FLOPs that would
  dominate `cost_analysis()` for fine-grained experts (granite d_ff=512)
  and poison the roofline. Scatter moves exactly T*k*d bytes — the honest
  cost.
* Expert compute is a single batched einsum (E, C, d) x (E, d, ff): MXU
  friendly, and GSPMD shards C over `data` and ff over `model`
  (expert-data parallelism + tensor-parallel experts). When E divides the
  model axis the weights may instead be expert-sharded; the sharding rules
  in `launch/sharding.py` pick per-arch.
* Tokens overflowing expert capacity C = ceil(T*k/E) * capacity_factor are
  dropped (standard dropping MoE); the router aux loss keeps load balanced
  so drops are rare.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.partitioning import logical_axis_size, shard_act

CAPACITY_FACTOR = 1.25


def _shard_moe(x):
    """(E, C, d): experts over model when divisible, capacity over data."""
    return shard_act(x, ("experts", "capacity", "embed"))


def _shard_moe_blocked(x):
    """(nb, E, C_local, d): token blocks over data, experts over model."""
    return shard_act(x, ("capacity", "experts", None, "embed"))


class MoEParams(NamedTuple):
    router: jnp.ndarray  # (d, E)
    w_gate: jnp.ndarray  # (E, d, ff)
    w_up: jnp.ndarray  # (E, d, ff)
    w_down: jnp.ndarray  # (E, ff, d)


def init_moe_params(key, cfg, dtype) -> MoEParams:
    from repro.models.layers import dense_init

    ks = jax.random.split(key, 4)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    return MoEParams(
        router=dense_init(ks[0], (d, e), dtype=jnp.float32),
        w_gate=dense_init(ks[1], (e, d, f), in_axis=1, dtype=dtype),
        w_up=dense_init(ks[2], (e, d, f), in_axis=1, dtype=dtype),
        w_down=dense_init(ks[3], (e, f, d), in_axis=1, dtype=dtype),
    )


def expert_capacity(num_tokens: int, num_experts: int, k: int,
                    factor: float = CAPACITY_FACTOR) -> int:
    cap = int(num_tokens * k / num_experts * factor) + 1
    # MXU-align the capacity dimension
    return max(8, -(-cap // 8) * 8)


def _dispatch_block(xf, topk_idx, num_experts: int, k: int, cap: int):
    """Scatter one token block into its (E, cap, d) expert buffer.

    Returns (expert_in, target, token_of_pair, keep). vmapped over token
    blocks so that, with blocks laid out on the `data` axis, the scatter is
    shard-local — a replicated dispatch buffer would otherwise be
    all-reduced across every data shard (measured 4 GB/occurrence f32 on
    grok-1 train_4k; see EXPERIMENTS.md §Perf).
    """
    t, d = xf.shape
    flat_expert = topk_idx.reshape(-1)  # (T*k,)
    onehot = jax.nn.one_hot(flat_expert, num_experts, dtype=jnp.int32)
    pos_in_expert = jnp.cumsum(onehot, axis=0) - onehot  # (T*k, E)
    slot = jnp.take_along_axis(pos_in_expert, flat_expert[:, None],
                               axis=1)[:, 0]
    keep = slot < cap
    target = jnp.where(keep, flat_expert * cap + slot, num_experts * cap)
    token_of_pair = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((num_experts * cap + 1, d), xf.dtype)
    buf = buf.at[target].set(xf[token_of_pair])
    expert_in = buf[: num_experts * cap].reshape(num_experts, cap, d)
    return expert_in, target, token_of_pair, keep


def _combine_block(expert_out, target, token_of_pair, keep, topk_probs,
                   t: int):
    """Gather one block's expert outputs back to token order (weighted)."""
    e, cap, d = expert_out.shape
    flat_out = expert_out.reshape(e * cap, d)
    flat_out = jnp.concatenate(
        [flat_out, jnp.zeros((1, d), flat_out.dtype)], axis=0)
    pair_out = flat_out[target]
    w = (topk_probs.reshape(-1) * keep).astype(pair_out.dtype)
    contrib = pair_out * w[:, None]
    return jnp.zeros((t, d), expert_out.dtype).at[token_of_pair].add(contrib)


def moe_ffn(p: MoEParams, x: jnp.ndarray, num_experts: int, k: int,
            aux_coef: float = 0.01, capacity_factor: float = CAPACITY_FACTOR):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    Fully jit/SPMD compatible: fixed shapes, no ragged ops. Tokens are
    dispatched in ``nb`` = data-axis-size independent blocks (nb=1 without
    a sharding context) so dispatch/combine scatters stay shard-local.
    """
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ p.router)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_probs, topk_idx = jax.lax.top_k(probs, k)  # (T, k)
    # renormalize the chosen experts' weights (mixtral/grok convention)
    topk_probs = topk_probs / jnp.maximum(
        topk_probs.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance aux loss (Switch-style, global) ----
    me = probs.mean(axis=0)  # (E,) mean router prob
    one_hot_top1 = jax.nn.one_hot(topk_idx[:, 0], num_experts)
    ce = one_hot_top1.mean(axis=0)  # (E,) fraction of tokens (top-1)
    aux = aux_coef * num_experts * jnp.sum(me * ce)

    # ---- block-local dispatch ----
    nb = logical_axis_size("capacity")
    if nb <= 1 or t % nb != 0:
        nb = 1
    tl = t // nb
    cap = expert_capacity(tl, num_experts, k, capacity_factor)
    xb = xf.reshape(nb, tl, d)
    ib = topk_idx.reshape(nb, tl, k)
    pb = topk_probs.reshape(nb, tl, k)
    expert_in, target, token_of_pair, keep = jax.vmap(
        lambda xx, ii: _dispatch_block(xx, ii, num_experts, k, cap))(xb, ib)
    expert_in = _shard_moe_blocked(expert_in)  # (nb, E, cap, d)

    # ---- expert FFN (SwiGLU), batched over blocks ----
    g = jnp.einsum("necd,edf->necf", expert_in, p.w_gate)
    u = jnp.einsum("necd,edf->necf", expert_in, p.w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(u.dtype) * u
    expert_out = jnp.einsum("necf,efd->necd", h, p.w_down)
    expert_out = _shard_moe_blocked(expert_out)

    # ---- combine per block ----
    out = jax.vmap(
        lambda eo, tg, tp, kp, w: _combine_block(eo, tg, tp, kp, w, tl))(
        expert_out, target, token_of_pair, keep, pb)
    return out.reshape(b, s, d).astype(x.dtype), aux
