"""Logical activation-sharding rules (MaxText-style).

Model code annotates activations with *logical* axis names
(``shard_act(x, ("batch", "seq", "heads", "hd"))``); a context installed by
the launcher maps logical names to mesh axes with divisibility guards.
Without a context (unit tests, CPU experiments) the calls are identity.

Why this exists: without explicit constraints GSPMD is free to shard an
attention contraction dimension, which materializes *partial* full-size
score tensors and all-reduces them (measured: 721 GB/step on qwen2-0.5b
train_4k, see EXPERIMENTS.md §Dry-run). Pinning activations to
batch->data, heads/ff/vocab->model (only when divisible) makes XLA move
weights (small) instead of activations (huge) — the standard production
layout.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_STATE = threading.local()


def default_rules(mesh) -> dict:
    batch = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return {
        "batch": batch if len(batch) > 1 else (batch[0] if batch else None),
        "heads": "model",
        "kv_heads": "model",
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "capacity": batch if len(batch) > 1 else (batch[0] if batch else None),
        "ssm_heads": "model",
        # query-sequence parallelism: used when NO head dim divides the
        # model axis (llava 56H/8KV, qwen2 14H/2KV) — scores shard on the
        # query dim instead of being replicated
        "seq_q": "model",
        # replicated logical axes
        "seq": None,
        "embed": None,
        "hd": None,
        "state": None,
    }


@contextmanager
def activation_sharding(mesh, rules: Optional[dict] = None):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = (mesh, rules or default_rules(mesh))
    try:
        yield
    finally:
        _STATE.ctx = prev


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def logical_axis_size(name: str) -> int:
    """Mesh size the given logical axis maps to (1 without a context)."""
    ctx = getattr(_STATE, "ctx", None)
    if ctx is None:
        return 1
    mesh, rules = ctx
    return _axis_size(mesh, rules.get(name))


def prefers_repeat_kv(num_heads: int, num_kv_heads: int) -> bool:
    """GQA layout choice under the installed sharding context.

    When Q-heads divide the model axis but KV-heads do not (qwen3: 64/8 on
    a 16-way axis), the grouped (b,s,kv,g,hd) form splits the shardable
    head dim into two unshardable factors and GSPMD must replicate the
    O(S^2) score tensor (measured: 35 TB of all-gather per qwen3-32b 32k
    prefill). Repeating KV to the full head count keeps one clean
    'heads' dim instead — tiny KV duplication, zero score gathers.
    """
    size = logical_axis_size("heads")
    if size <= 1:
        return False
    return num_heads % size == 0 and num_kv_heads % size != 0


def prefers_q_sharding(num_heads: int, num_kv_heads: int) -> bool:
    """Neither head dim divides the model axis: shard attention on the
    query-sequence dim instead (valid for any head count; the per-dim
    divisibility guard in shard_act skips decode's q-length of 1)."""
    size = logical_axis_size("heads")
    if size <= 1:
        return False
    return num_heads % size != 0 and num_kv_heads % size != 0


def shard_act(x, logical: Sequence[Optional[str]]):
    """Constrain ``x`` to the logical spec under the installed context.

    Identity when no context is installed or x is not a jax array-like.
    Dims whose size the mapped mesh axes do not divide are left unsharded.
    """
    ctx = getattr(_STATE, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    if len(logical) != x.ndim:
        return x
    spec = []
    for dim, name in zip(x.shape, logical):
        axis = rules.get(name) if name else None
        if axis is not None and dim % _axis_size(mesh, axis) == 0:
            spec.append(axis)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
