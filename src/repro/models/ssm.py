"""Mamba2 block via state-space duality (SSD), arXiv:2405.21060.

TPU adaptation (DESIGN.md §4): the CUDA implementation is a warp-level
chunked scan; here the *same chunked SSD decomposition* is expressed as

  * intra-chunk: a masked quadratic "attention form" — an MXU matmul over
    (chunk x chunk) tiles;
  * inter-chunk: a `lax.scan` over chunk states (the only sequential part,
    length S/chunk);

which is exactly the structure the `ssd_scan` Pallas kernel implements with
the inter-chunk state carried in VMEM scratch. This module is the jnp
reference/lowering path.

State convention (per head): S_t = exp(dt_t * A) * S_{t-1} + dt_t * x_t B_t^T
with A < 0 scalar per head, y_t = S_t C_t + D * x_t.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm
from repro.models.partitioning import shard_act


class MambaParams(NamedTuple):
    in_proj: jnp.ndarray  # (d, 2*d_in + 2*N + H)
    conv_w: jnp.ndarray  # (W, conv_dim) depthwise
    conv_b: jnp.ndarray  # (conv_dim,)
    dt_bias: jnp.ndarray  # (H,)
    A_log: jnp.ndarray  # (H,)
    D: jnp.ndarray  # (H,)
    norm: jnp.ndarray  # (d_in,) gated RMSNorm scale
    out_proj: jnp.ndarray  # (d_in, d)


def mamba_dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    conv_dim = d_in + 2 * cfg.ssm_state_size
    return d_in, n_heads, conv_dim


def init_mamba_params(key, cfg, dtype) -> MambaParams:
    d_in, n_heads, conv_dim = mamba_dims(cfg)
    ks = jax.random.split(key, 5)
    # inverse-softplus so softplus(dt_bias) spans ~[1e-3, 1e-1]
    dt = jnp.exp(jax.random.uniform(
        ks[0], (n_heads,), minval=jnp.log(1e-3), maxval=jnp.log(1e-1)))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    a_init = jax.random.uniform(ks[1], (n_heads,), minval=1.0, maxval=16.0)
    return MambaParams(
        in_proj=dense_init(ks[2], (cfg.d_model, 2 * d_in
                                   + 2 * cfg.ssm_state_size + n_heads),
                           dtype=dtype),
        conv_w=(jax.random.normal(ks[3], (cfg.ssm_conv_width, conv_dim))
                / jnp.sqrt(cfg.ssm_conv_width)).astype(dtype),
        conv_b=jnp.zeros((conv_dim,), dtype),
        dt_bias=dt_bias.astype(jnp.float32),
        A_log=jnp.log(a_init).astype(jnp.float32),
        D=jnp.ones((n_heads,), jnp.float32),
        norm=jnp.zeros((d_in,), dtype),
        out_proj=dense_init(ks[4], (d_in, cfg.d_model), dtype=dtype),
    )


def causal_depthwise_conv(x, w, b, state=None):
    """x (B,S,C), w (W,C) depthwise causal; state (B,W-1,C) optional history.

    Returns (y (B,S,C), new_state (B,W-1,C)).
    """
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)  # (B, S+W-1, C)
    y = sum(xx[:, i: i + x.shape[1], :] * w[i][None, None, :]
            for i in range(width)) + b
    new_state = xx[:, -(width - 1):, :] if width > 1 else state
    return y, new_state


def _segsum_decay(dA_chunk):
    """dA_chunk (..., L) log-decays -> (..., L, L) matrix exp(cs_i - cs_j)
    masked to j <= i (else 0)."""
    cs = jnp.cumsum(dA_chunk, axis=-1)  # inclusive
    diff = cs[..., :, None] - cs[..., None, :]  # (..., i, j)
    l = dA_chunk.shape[-1]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_chunked(x, dt, A_log, B, C, D, chunk: int):
    """Chunked SSD forward (training / prefill).

    x (b,s,h,p); dt (b,s,h) positive; A_log (h,); B,C (b,s,n); D (h,).
    Returns (y (b,s,h,p), final_state (b,h,p,n)).
    Sequences not divisible by ``chunk`` are zero-padded: dt=0 makes padded
    steps exact identities (decay exp(0)=1, contribution dt*x=0).
    """
    b, s_orig, h, p = x.shape
    n = B.shape[-1]
    pad = (-s_orig) % chunk
    if pad:
        padf = lambda a: jnp.pad(  # noqa: E731
            a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, dt, B, C = padf(x), padf(dt), padf(B), padf(C)
    s = s_orig + pad
    nc, l = s // chunk, chunk

    f32 = jnp.float32
    a = -jnp.exp(A_log.astype(f32))  # (h,) negative
    dt = dt.astype(f32)
    dA = dt * a[None, None, :]  # (b,s,h) log decay

    xr = x.reshape(b, nc, l, h, p)
    dtr = dt.reshape(b, nc, l, h)
    dAr = dA.reshape(b, nc, l, h).transpose(0, 1, 3, 2)  # (b,nc,h,l)
    Br = B.reshape(b, nc, l, n)
    Cr = C.reshape(b, nc, l, n)

    # ---- intra-chunk (quadratic attention form, MXU-friendly) ----
    decay = _segsum_decay(dAr)  # (b,nc,h,l,l)
    cb = jnp.einsum("bcin,bcjn->bcij", Cr.astype(f32), Br.astype(f32))
    scores = cb[:, :, None] * decay  # (b,nc,h,i,j)
    xdt = xr.astype(f32) * dtr[..., None]  # (b,nc,l,h,p)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", scores, xdt)

    # ---- chunk states ----
    cs = jnp.cumsum(dAr, axis=-1)  # (b,nc,h,l) inclusive
    total = cs[..., -1]  # (b,nc,h)
    decay_to_end = jnp.exp(total[..., None] - cs)  # (b,nc,h,l)
    # S_chunk = sum_j decay_to_end_j * dt_j * x_j B_j^T  -> (b,nc,h,p,n)
    s_chunk = jnp.einsum("bchj,bcjhp,bcjn->bchpn", decay_to_end, xdt, Br)

    # ---- inter-chunk recurrence over nc (sequential scan) ----
    def step(carry, inp):
        s_prev = carry  # (b,h,p,n) state BEFORE this chunk
        tot, s_c = inp
        s_next = jnp.exp(tot)[..., None, None] * s_prev + s_c
        return s_next, s_prev

    init = jnp.zeros((b, h, p, n), f32)
    final_state, s_before = jax.lax.scan(
        step, init,
        (total.transpose(1, 0, 2), s_chunk.transpose(1, 0, 2, 3, 4)))
    s_before = s_before.transpose(1, 0, 2, 3, 4)  # (b,nc,h,p,n)

    # ---- inter-chunk contribution ----
    in_decay = jnp.exp(cs)  # (b,nc,h,l) decay from chunk start to i
    y_inter = jnp.einsum("bchi,bcin,bchpn->bcihp", in_decay, Cr.astype(f32),
                         s_before)

    y = y_intra + y_inter + xr.astype(f32) * D[None, None, None, :, None]
    y = y.reshape(b, s, h, p)[:, :s_orig]
    return y.astype(x.dtype), final_state


def ssd_decode_step(x, dt, A_log, B, C, D, state):
    """One-token recurrent update. x (b,1,h,p); state (b,h,p,n)."""
    f32 = jnp.float32
    a = -jnp.exp(A_log.astype(f32))
    dt = dt.astype(f32)[:, 0]  # (b,h)
    dA = jnp.exp(dt * a[None, :])  # (b,h)
    xb = jnp.einsum("bhp,bn->bhpn", x[:, 0].astype(f32) * dt[..., None],
                    B[:, 0].astype(f32))
    new_state = dA[..., None, None] * state + xb
    y = jnp.einsum("bhpn,bn->bhp", new_state, C[:, 0].astype(f32))
    y = y + x[:, 0].astype(f32) * D[None, :, None]
    return y[:, None].astype(x.dtype), new_state


def mamba_block(p: MambaParams, x, cfg, *, ssm_state=None, conv_state=None,
                decode: bool = False):
    """Full Mamba2 block. x (B,S,d) -> (y (B,S,d), (ssm_state, conv_state)).

    Training/prefill: decode=False, states returned are final states.
    Decode: decode=True, S must be 1, states are required.
    """
    d_in, n_heads, conv_dim = mamba_dims(cfg)
    n = cfg.ssm_state_size
    b, s, _ = x.shape

    zxbcdt = jnp.einsum("bsd,de->bse", x, p.in_proj)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in: d_in + conv_dim]
    dt_raw = zxbcdt[..., d_in + conv_dim:]  # (b,s,H)

    xbc, new_conv_state = causal_depthwise_conv(
        xbc, p.conv_w, p.conv_b, state=conv_state)
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(xbc.dtype)

    xs = xbc[..., :d_in].reshape(b, s, n_heads, cfg.ssm_head_dim)
    xs = shard_act(xs, ("batch", "seq", "ssm_heads", "hd"))
    B = xbc[..., d_in: d_in + n]
    C = xbc[..., d_in + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p.dt_bias[None, None, :])

    if decode:
        y, new_ssm = ssd_decode_step(xs, dt, p.A_log, B, C, p.D, ssm_state)
    else:
        y, new_ssm = ssd_chunked(xs, dt, p.A_log, B, C, p.D, cfg.ssm_chunk)

    y = y.reshape(b, s, d_in)
    gated = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    gated = rms_norm(gated, p.norm, cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", gated, p.out_proj)
    return out, (new_ssm, new_conv_state)
