"""Backbone assembler: one init/forward pair covering all ten assigned
architectures.

Key structural decisions (they determine compile time and shardability):

* **Scan over layers with stacked parameters.** All per-layer params are
  stacked on a leading L axis and the depth loop is one ``jax.lax.scan`` —
  a 64-layer grok-1 lowers to the same HLO size as a 2-layer model.
* **Per-layer heterogeneity rides scan-xs**, not Python branching: window
  sizes (gemma 1:1 and 5:1 local:global alternation) and rope thetas are
  (L,)-arrays consumed as traced scalars by the layer body.
* **KV caches are scan xs/ys**: each layer reads its cache slice and emits
  the updated slice; the stacked cache (L, B, S, KV, hd) shards over the
  mesh (S over `data` for batch-1 long context, KV-heads over `model`).
* **Hybrid (zamba2)** is a scan over super-blocks: ``shared_attn_every``
  Mamba2 trunk layers + one application of the *shared-weight* attention
  block (closure params — the defining Zamba2 trick), with a scanned tail
  for the remainder.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN, GLOBAL, MAMBA, ModelConfig
from repro.models import attention as attn_mod
from repro.models.attention import (
    AttnParams,
    CrossAttnParams,
    cross_attend,
    cross_kv,
    encoder_self_attend,
    init_attn_params,
    init_cross_attn_params,
)
from repro.models.layers import dense_init, rms_norm, softcap, swiglu
from repro.models.partitioning import shard_act
from repro.models.moe import MoEParams, init_moe_params, moe_ffn
from repro.models.ssm import (
    MambaParams,
    init_mamba_params,
    mamba_block,
    mamba_dims,
)

PyTree = Any


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------
def _init_mlp(key, d, ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d, ff), dtype=dtype),
        "w_up": dense_init(k2, (d, ff), dtype=dtype),
        "w_down": dense_init(k3, (ff, d), dtype=dtype),
    }


def _init_attn_layer(cfg: ModelConfig, dtype, with_cross: bool):
    def init_one(key):
        ka, km, kc = jax.random.split(key, 3)
        layer = {
            "ln1": jnp.zeros((cfg.d_model,), dtype),
            "attn": init_attn_params(ka, cfg, dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
        }
        if cfg.is_moe:
            layer["moe"] = init_moe_params(km, cfg, dtype)
        else:
            layer["mlp"] = _init_mlp(km, cfg.d_model, cfg.d_ff, dtype)
        if cfg.use_post_norm:
            layer["post_ln1"] = jnp.zeros((cfg.d_model,), dtype)
            layer["post_ln2"] = jnp.zeros((cfg.d_model,), dtype)
        if with_cross:
            layer["ln_cross"] = jnp.zeros((cfg.d_model,), dtype)
            layer["cross"] = init_cross_attn_params(kc, cfg, dtype)
        return layer

    return init_one


def _init_mamba_layer(cfg: ModelConfig, dtype):
    def init_one(key):
        return {
            "ln": jnp.zeros((cfg.d_model,), dtype),
            "mamba": init_mamba_params(key, cfg, dtype),
        }

    return init_one


def init_params(cfg: ModelConfig, key) -> PyTree:
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    params: dict = {}

    params["embed"] = (
        jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model)) * 0.02
    ).astype(dtype)
    params["final_norm"] = jnp.zeros((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab_size),
                                       dtype=dtype)

    kinds = cfg.layer_kinds()
    n_layers = cfg.num_layers
    layer_keys = jax.random.split(keys[2], n_layers)

    if all(k == ATTN for k in kinds):
        init_one = _init_attn_layer(cfg, dtype, with_cross=cfg.is_encoder_decoder)
        params["layers"] = jax.vmap(init_one)(layer_keys)
    elif all(k == MAMBA for k in kinds):
        init_one = _init_mamba_layer(cfg, dtype)
        params["layers"] = jax.vmap(init_one)(layer_keys)
    else:
        raise ValueError(f"mixed per-layer patterns unsupported: {cfg.name}")

    if cfg.shared_attn_every:
        # zamba2: ONE shared-weight attention+MLP block
        shared_cfg_key = keys[3]
        ka, km = jax.random.split(shared_cfg_key)
        params["shared_attn"] = {
            "ln1": jnp.zeros((cfg.d_model,), dtype),
            "attn": init_attn_params(ka, cfg, dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "mlp": _init_mlp(km, cfg.d_model, cfg.d_ff, dtype),
        }

    if cfg.is_encoder_decoder:
        enc_keys = jax.random.split(keys[4], cfg.enc_layers)
        enc_init = _init_attn_layer(cfg, dtype, with_cross=False)
        params["encoder"] = {
            "layers": jax.vmap(enc_init)(enc_keys),
            "norm": jnp.zeros((cfg.d_model,), dtype),
        }
    return params


# ---------------------------------------------------------------------------
# Per-layer metadata (windows / thetas) — static numpy, becomes scan xs
# ---------------------------------------------------------------------------
def layer_windows_thetas(cfg: ModelConfig) -> tuple[np.ndarray, np.ndarray]:
    n_attn = sum(1 for k in cfg.layer_kinds() if k == ATTN)
    wp = cfg.window_pattern
    windows = np.array([wp[i % len(wp)] for i in range(max(n_attn, 1))],
                       np.int32)
    windows = np.where(windows == GLOBAL, 0, windows)  # 0 => global
    theta_g = cfg.rope_theta_global or cfg.rope_theta
    thetas = np.where(windows == 0, theta_g, cfg.rope_theta).astype(np.float32)
    return windows, thetas


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def _embed_in(params, cfg: ModelConfig, tokens=None, embeds=None):
    adt = jnp.dtype(cfg.activation_dtype)
    if embeds is not None:
        x = embeds.astype(adt)
    else:
        x = params["embed"][tokens].astype(adt)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), adt)
    return shard_act(x, ("batch", "seq", "embed"))


def _unembed(params, cfg: ModelConfig, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    logits = shard_act(logits, ("batch", "seq", "vocab"))
    if cfg.final_logit_softcap is not None:
        logits = softcap(logits, cfg.final_logit_softcap)
    return logits


# ---------------------------------------------------------------------------
# Attention-family layer body (train / prefill / decode), scan-compatible
# ---------------------------------------------------------------------------
def _attn_layer(cfg: ModelConfig, mode: str):
    """Returns body(x, xs) -> (x, ys). xs carries layer params + metadata +
    cache slices; ys carries updated cache slices + moe aux."""

    def body(x, xs):
        lp = xs["layer"]
        window, theta = xs["window"], xs["theta"]
        ap = AttnParams(*lp["attn"]) if not isinstance(
            lp["attn"], AttnParams) else lp["attn"]

        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        ys = {}
        if mode == "full":
            a_out = attn_mod.attend_full(ap, h, cfg, window=window, theta=theta)
        elif mode == "prefill":
            a_out, k, v = attn_mod.prefill(
                ap, h, cfg, window=window, theta=theta,
                cache_len=xs["cache_len"])
            ys["k"], ys["v"] = k, v
        elif mode == "decode":
            a_out, k, v = attn_mod.decode_step(
                ap, h, xs["k"], xs["v"], xs["cache_pos"], cfg,
                window=window, theta=theta)
            ys["k"], ys["v"] = k, v
        else:
            raise ValueError(mode)
        if cfg.use_post_norm:
            a_out = rms_norm(a_out, lp["post_ln1"], cfg.norm_eps)
        x = x + a_out

        if cfg.is_encoder_decoder:
            cp = CrossAttnParams(*lp["cross"]) if not isinstance(
                lp["cross"], CrossAttnParams) else lp["cross"]
            hc = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
            x = x + cross_attend(cp, hc, xs["cross_k"], xs["cross_v"], cfg)

        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            mp = MoEParams(*lp["moe"]) if not isinstance(
                lp["moe"], MoEParams) else lp["moe"]
            m_out, aux = moe_ffn(mp, h2, cfg.num_experts,
                                 cfg.experts_per_token, cfg.router_aux_coef,
                                 cfg.moe_capacity_factor)
        else:
            mlp = lp["mlp"]
            m_out = swiglu(h2, mlp["w_gate"], mlp["w_up"], mlp["w_down"])
            aux = jnp.zeros((), jnp.float32)
        if cfg.use_post_norm:
            m_out = rms_norm(m_out, lp["post_ln2"], cfg.norm_eps)
        x = x + m_out
        ys["aux"] = aux
        return x, ys

    return body


def _scan_attn_layers(params, cfg, x, mode, *, cache=None, cache_pos=None,
                      cache_len=None, cross=None, remat=False):
    windows, thetas = layer_windows_thetas(cfg)
    xs = {
        "layer": params["layers"],
        "window": jnp.asarray(windows),
        "theta": jnp.asarray(thetas),
    }
    if mode == "decode":
        xs["k"], xs["v"] = cache["k"], cache["v"]
        xs["cache_pos"] = jnp.broadcast_to(
            jnp.asarray(cache_pos, jnp.int32), (cfg.num_layers,))
    if cross is not None:
        xs["cross_k"], xs["cross_v"] = cross

    body = _attn_layer(cfg, mode)
    if mode == "prefill":
        # cache_len is a *static* python int (defines cache shapes): closure
        body_inner = body

        def body(x, xs_):  # noqa: F811
            xs_ = dict(xs_)
            xs_["cache_len"] = cache_len
            return body_inner(x, xs_)

    if remat:
        body = jax.checkpoint(body)
    x, ys = jax.lax.scan(body, x, xs)
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"k": ys["k"], "v": ys["v"]}
        if cross is not None:
            new_cache["cross_k"], new_cache["cross_v"] = cross
    return x, new_cache, jnp.sum(ys["aux"])


# ---------------------------------------------------------------------------
# Ring-cache decode for periodic local:global patterns (gemma2/3 — §Perf)
# ---------------------------------------------------------------------------
def _ring_split(cfg: ModelConfig):
    """(period, n_super, tail, local positions-in-period, global positions)."""
    p = len(cfg.window_pattern)
    n_super = cfg.num_layers // p
    tail = cfg.num_layers - n_super * p
    local_js = [j for j, w in enumerate(cfg.window_pattern) if w > 0]
    global_js = [j for j, w in enumerate(cfg.window_pattern) if w <= 0]
    return p, n_super, tail, local_js, global_js


def uses_ring_cache(cfg: ModelConfig) -> bool:
    return (cfg.ring_cache and not cfg.is_encoder_decoder
            and not cfg.shared_attn_every
            and all(k == ATTN for k in cfg.layer_kinds())
            and any(w > 0 for w in cfg.window_pattern)
            and len(cfg.window_pattern) <= cfg.num_layers)


def _mlp_and_residual(cfg, lp, x, a_out):
    if cfg.use_post_norm:
        a_out = rms_norm(a_out, lp["post_ln1"], cfg.norm_eps)
    x = x + a_out
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        mp = MoEParams(*lp["moe"]) if not isinstance(
            lp["moe"], MoEParams) else lp["moe"]
        m_out, _ = moe_ffn(mp, h2, cfg.num_experts, cfg.experts_per_token,
                           cfg.router_aux_coef, cfg.moe_capacity_factor)
    else:
        mlp = lp["mlp"]
        m_out = swiglu(h2, mlp["w_gate"], mlp["w_up"], mlp["w_down"])
    if cfg.use_post_norm:
        m_out = rms_norm(m_out, lp["post_ln2"], cfg.norm_eps)
    return x + m_out


def _ring_layer(cfg, lp, x, kind_window, theta, k_cache, v_cache,
                cache_pos):
    """One unrolled decode layer; window > 0 -> ring cache semantics."""
    ap = AttnParams(*lp["attn"]) if not isinstance(
        lp["attn"], AttnParams) else lp["attn"]
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if kind_window > 0:
        a_out, k_cache, v_cache = attn_mod.ring_decode_step(
            ap, h, k_cache, v_cache, cache_pos, cfg,
            window=kind_window, theta=theta)
    else:
        a_out, k_cache, v_cache = attn_mod.decode_step(
            ap, h, k_cache, v_cache, cache_pos, cfg,
            window=jnp.asarray(0, jnp.int32), theta=theta)
    x = _mlp_and_residual(cfg, lp, x, a_out)
    return x, k_cache, v_cache


def _scan_ring_decode(params, cfg, x, cache, cache_pos):
    """Decode scan over super-blocks with per-position static windows:
    local layers carry (n_super, n_loc, B, W, KV, hd) ring caches, global
    layers full-length caches. Tail layers (L % period) unroll outside."""
    p, n_super, tail, local_js, global_js = _ring_split(cfg)
    windows, thetas = layer_windows_thetas(cfg)

    def slice_fold(tree, start, count, fold):
        out = jax.tree.map(lambda a: a[start:start + count], tree)
        if fold:
            out = jax.tree.map(
                lambda a: a.reshape((n_super, p) + a.shape[1:]), out)
        return out

    super_params = slice_fold(params["layers"], 0, n_super * p, True)
    loc_of_j = {j: i for i, j in enumerate(local_js)}
    glob_of_j = {j: i for i, j in enumerate(global_js)}

    def super_body(x, xs):
        ring_k, ring_v = xs["ring_k"], xs["ring_v"]  # (n_loc, B, W, KV, hd)
        glob_k, glob_v = xs["glob_k"], xs["glob_v"]  # (n_glob, B, S, KV, hd)
        for j in range(p):  # static unroll over the period
            lp = jax.tree.map(lambda a: a[j], xs["params"])
            w = int(windows[j])
            th = jnp.asarray(float(thetas[j]), jnp.float32)
            if w > 0:
                i = loc_of_j[j]
                x, nk, nv = _ring_layer(cfg, lp, x, w, th, ring_k[i],
                                        ring_v[i], cache_pos)
                ring_k = ring_k.at[i].set(nk)
                ring_v = ring_v.at[i].set(nv)
            else:
                i = glob_of_j[j]
                x, nk, nv = _ring_layer(cfg, lp, x, 0, th, glob_k[i],
                                        glob_v[i], cache_pos)
                glob_k = glob_k.at[i].set(nk)
                glob_v = glob_v.at[i].set(nv)
        return x, {"ring_k": ring_k, "ring_v": ring_v,
                   "glob_k": glob_k, "glob_v": glob_v}

    xs = {"params": super_params,
          "ring_k": cache["ring_k"], "ring_v": cache["ring_v"],
          "glob_k": cache["glob_k"], "glob_v": cache["glob_v"]}
    x, ys = jax.lax.scan(super_body, x, xs)
    new_cache = {k: ys[k] for k in ("ring_k", "ring_v", "glob_k", "glob_v")}

    if tail:
        tail_params = slice_fold(params["layers"], n_super * p, tail, False)
        tk, tv = cache["tail_k"], cache["tail_v"]
        for t in range(tail):
            j = (n_super * p + t) % p
            lp = jax.tree.map(lambda a: a[t], tail_params)
            w = int(windows[n_super * p + t])
            th = jnp.asarray(float(thetas[n_super * p + t]), jnp.float32)
            x, nk, nv = _ring_layer(cfg, lp, x, w, th, tk[t], tv[t],
                                    cache_pos)
            tk = tk.at[t].set(nk)
            tv = tv.at[t].set(nv)
        new_cache["tail_k"], new_cache["tail_v"] = tk, tv
    return x, new_cache, jnp.zeros((), jnp.float32)


def init_ring_cache(cfg: ModelConfig, batch: int, max_seq: int,
                    dtype=None) -> dict:
    """Ring-structured decode cache (see _scan_ring_decode)."""
    dtype = dtype or jnp.dtype(cfg.activation_dtype)
    p, n_super, tail, local_js, global_js = _ring_split(cfg)
    windows, _ = layer_windows_thetas(cfg)
    w_max = max(int(w) for w in windows if w > 0)
    kvh = (cfg.num_kv_heads, cfg.head_dim)
    cache = {
        "ring_k": jnp.zeros((n_super, len(local_js), batch, w_max) + kvh,
                            dtype),
        "ring_v": jnp.zeros((n_super, len(local_js), batch, w_max) + kvh,
                            dtype),
        "glob_k": jnp.zeros((n_super, len(global_js), batch, max_seq) + kvh,
                            dtype),
        "glob_v": jnp.zeros((n_super, len(global_js), batch, max_seq) + kvh,
                            dtype),
    }
    if tail:
        tail_ws = [int(windows[n_super * p + t]) for t in range(tail)]
        t_len = max(w if w > 0 else max_seq for w in tail_ws)
        cache["tail_k"] = jnp.zeros((tail, batch, t_len) + kvh, dtype)
        cache["tail_v"] = jnp.zeros((tail, batch, t_len) + kvh, dtype)
    return cache


def ring_cache_from_full(cfg: ModelConfig, cache: dict, cache_pos: int,
                         batch: int, max_seq: int) -> dict:
    """Convert a standard prefill cache into the ring structure (serving
    pipeline: prefill full, then decode with ring caches)."""
    p, n_super, tail, local_js, global_js = _ring_split(cfg)
    windows, _ = layer_windows_thetas(cfg)
    ring = init_ring_cache(cfg, batch, max_seq, cache["k"].dtype)
    w_max = ring["ring_k"].shape[3]

    def gather_window(full_layer, w):
        # place true positions (pos-w, pos] at slot true_pos % w_max
        slots = jnp.arange(w_max)
        # fill such that slot s holds position q where q % w_max == s
        base = jnp.maximum(cache_pos - w_max, -w_max)
        cand = ((cache_pos // w_max) * w_max) + slots
        q = jnp.where(cand <= cache_pos, cand, cand - w_max)
        q_clamped = jnp.clip(q, 0, max_seq - 1)
        out = full_layer[:, q_clamped]
        valid = (q >= 0) & (q > cache_pos - w_max)
        return out * valid[None, :, None, None].astype(out.dtype)

    for idx in range(cfg.num_layers):
        s, j = divmod(idx, p)
        is_tail = s >= n_super
        k_l, v_l = cache["k"][idx], cache["v"][idx]
        if is_tail:
            t = idx - n_super * p
            if int(windows[idx]) > 0:
                ring["tail_k"] = ring["tail_k"].at[t].set(
                    gather_window(k_l, int(windows[idx])))
                ring["tail_v"] = ring["tail_v"].at[t].set(
                    gather_window(v_l, int(windows[idx])))
            else:
                ring["tail_k"] = ring["tail_k"].at[t].set(k_l)
                ring["tail_v"] = ring["tail_v"].at[t].set(v_l)
            continue
        if int(windows[idx]) > 0:
            i = local_js.index(j)
            ring["ring_k"] = ring["ring_k"].at[s, i].set(
                gather_window(k_l, int(windows[idx])))
            ring["ring_v"] = ring["ring_v"].at[s, i].set(
                gather_window(v_l, int(windows[idx])))
        else:
            i = global_js.index(j)
            ring["glob_k"] = ring["glob_k"].at[s, i].set(k_l)
            ring["glob_v"] = ring["glob_v"].at[s, i].set(v_l)
    return ring


# ---------------------------------------------------------------------------
# Mamba-family (pure SSM) scan
# ---------------------------------------------------------------------------
def _scan_mamba_layers(params, cfg, x, mode, *, cache=None, remat=False):
    decode = mode == "decode"

    def body(x, xs):
        lp = xs["layer"]
        mp = MambaParams(*lp["mamba"]) if not isinstance(
            lp["mamba"], MambaParams) else lp["mamba"]
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        out, (ssm_s, conv_s) = mamba_block(
            mp, h, cfg,
            ssm_state=xs.get("ssm"), conv_state=xs.get("conv"),
            decode=decode)
        return x + out, {"ssm": ssm_s, "conv": conv_s}

    xs = {"layer": params["layers"]}
    if decode:
        xs["ssm"], xs["conv"] = cache["ssm"], cache["conv"]
    if remat:
        body = jax.checkpoint(body)
    x, ys = jax.lax.scan(body, x, xs)
    new_cache = {"ssm": ys["ssm"], "conv": ys["conv"]}
    return x, new_cache, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Hybrid (zamba2): super-blocks of mamba + shared attention
# ---------------------------------------------------------------------------
def _zamba_split(cfg) -> tuple[int, int, int]:
    k = cfg.shared_attn_every
    n_super = cfg.num_layers // k
    tail = cfg.num_layers - n_super * k
    return k, n_super, tail


def _shared_attn_apply(params, cfg, x, mode, k_cache=None, v_cache=None,
                       cache_pos=None, cache_len=None):
    sp = params["shared_attn"]
    ap = AttnParams(*sp["attn"]) if not isinstance(
        sp["attn"], AttnParams) else sp["attn"]
    h = rms_norm(x, sp["ln1"], cfg.norm_eps)
    window = jnp.asarray(0, jnp.int32)  # global
    theta = jnp.asarray(cfg.rope_theta, jnp.float32)
    ys = {}
    if mode == "full":
        a_out = attn_mod.attend_full(ap, h, cfg, window=window, theta=theta)
    elif mode == "prefill":
        a_out, k, v = attn_mod.prefill(ap, h, cfg, window=window, theta=theta,
                                       cache_len=cache_len)
        ys["k"], ys["v"] = k, v
    else:
        a_out, k, v = attn_mod.decode_step(
            ap, h, k_cache, v_cache, cache_pos, cfg, window=window,
            theta=theta)
        ys["k"], ys["v"] = k, v
    x = x + a_out
    h2 = rms_norm(x, sp["ln2"], cfg.norm_eps)
    mlp = sp["mlp"]
    x = x + swiglu(h2, mlp["w_gate"], mlp["w_up"], mlp["w_down"])
    return x, ys


def _run_hybrid(params, cfg, x, mode, *, cache=None, cache_pos=None,
                cache_len=None, remat=False):
    k, n_super, tail = _zamba_split(cfg)
    decode = mode == "decode"
    trunk = params["layers"]

    def slice_layers(tree, start, count, fold):
        """Take layers [start, start+count) and optionally fold into
        (n_super, k, ...)."""
        out = jax.tree.map(lambda a: a[start: start + count], tree)
        if fold:
            out = jax.tree.map(
                lambda a: a.reshape((n_super, k) + a.shape[1:]), out)
        return out

    super_trunk = slice_layers(trunk, 0, n_super * k, fold=True)
    tail_trunk = slice_layers(trunk, n_super * k, tail, fold=False) \
        if tail else None

    def mamba_body(x, xs):
        lp = xs["layer"]
        mp = MambaParams(*lp["mamba"]) if not isinstance(
            lp["mamba"], MambaParams) else lp["mamba"]
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        out, (ssm_s, conv_s) = mamba_block(
            mp, h, cfg, ssm_state=xs.get("ssm"), conv_state=xs.get("conv"),
            decode=decode)
        return x + out, {"ssm": ssm_s, "conv": conv_s}

    if remat:
        mamba_body = jax.checkpoint(mamba_body)

    def super_body(x, xs):
        inner_xs = {"layer": xs["trunk"]}
        if decode:
            inner_xs["ssm"], inner_xs["conv"] = xs["ssm"], xs["conv"]
        x, inner_ys = jax.lax.scan(mamba_body, x, inner_xs)
        x, shared_ys = _shared_attn_apply(
            params, cfg, x, mode,
            k_cache=xs.get("shared_k"), v_cache=xs.get("shared_v"),
            cache_pos=cache_pos, cache_len=cache_len)
        ys = {"ssm": inner_ys["ssm"], "conv": inner_ys["conv"], **shared_ys}
        return x, ys

    xs = {"trunk": super_trunk}
    if decode:
        fold = lambda a: a.reshape((n_super, k) + a.shape[1:])  # noqa: E731
        xs["ssm"] = fold(cache["ssm"][: n_super * k])
        xs["conv"] = fold(cache["conv"][: n_super * k])
        xs["shared_k"], xs["shared_v"] = cache["shared_k"], cache["shared_v"]

    x, ys = jax.lax.scan(super_body, x, xs)

    new_cache = {}
    unfold = lambda a: a.reshape((n_super * k,) + a.shape[2:])  # noqa: E731
    ssm_parts = [unfold(ys["ssm"])]
    conv_parts = [unfold(ys["conv"])]
    if mode in ("prefill", "decode"):
        new_cache["shared_k"], new_cache["shared_v"] = ys["k"], ys["v"]

    if tail:
        tail_xs = {"layer": tail_trunk}
        if decode:
            tail_xs["ssm"] = cache["ssm"][n_super * k:]
            tail_xs["conv"] = cache["conv"][n_super * k:]
        x, tail_ys = jax.lax.scan(mamba_body, x, tail_xs)
        ssm_parts.append(tail_ys["ssm"])
        conv_parts.append(tail_ys["conv"])

    new_cache["ssm"] = jnp.concatenate(ssm_parts, axis=0)
    new_cache["conv"] = jnp.concatenate(conv_parts, axis=0)
    return x, new_cache, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Encoder (whisper)
# ---------------------------------------------------------------------------
def encode(params, cfg: ModelConfig, enc_embeds):
    """Bidirectional encoder over stub frame embeddings (B, S_enc, d)."""
    x = enc_embeds.astype(jnp.dtype(cfg.activation_dtype))

    def body(x, xs):
        lp = xs["layer"]
        ap = AttnParams(*lp["attn"]) if not isinstance(
            lp["attn"], AttnParams) else lp["attn"]
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        x = x + encoder_self_attend(ap, h, cfg)
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        mlp = lp["mlp"]
        x = x + swiglu(h2, mlp["w_gate"], mlp["w_up"], mlp["w_down"])
        return x, None

    x, _ = jax.lax.scan(body, x, {"layer": params["encoder"]["layers"]})
    return rms_norm(x, params["encoder"]["norm"], cfg.norm_eps)


def _stacked_cross_kv(params, cfg, enc_out):
    """Per-decoder-layer cross KV, stacked (L, B, S_enc, KV, hd)."""

    def one(layer):
        cp = CrossAttnParams(*layer["cross"]) if not isinstance(
            layer["cross"], CrossAttnParams) else layer["cross"]
        return cross_kv(cp, enc_out, cfg)

    ks, vs = jax.vmap(one, in_axes=(0,))(params["layers"])
    return ks, vs


# ---------------------------------------------------------------------------
# Cache allocation
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=None) -> PyTree:
    """Pre-allocated decode cache for every family."""
    dtype = dtype or jnp.dtype(cfg.activation_dtype)
    kinds = cfg.layer_kinds()
    cache: dict = {}
    if uses_ring_cache(cfg):
        return init_ring_cache(cfg, batch, max_seq, dtype)
    if cfg.shared_attn_every:  # hybrid
        d_in, n_heads, conv_dim = mamba_dims(cfg)
        k, n_super, tail = _zamba_split(cfg)
        cache["ssm"] = jnp.zeros(
            (cfg.num_layers, batch, n_heads, cfg.ssm_head_dim,
             cfg.ssm_state_size), jnp.float32)
        cache["conv"] = jnp.zeros(
            (cfg.num_layers, batch, cfg.ssm_conv_width - 1, conv_dim), dtype)
        cache["shared_k"] = jnp.zeros(
            (n_super, batch, max_seq, cfg.num_kv_heads, cfg.head_dim), dtype)
        cache["shared_v"] = jnp.zeros_like(cache["shared_k"])
    elif all(kk == MAMBA for kk in kinds):
        d_in, n_heads, conv_dim = mamba_dims(cfg)
        cache["ssm"] = jnp.zeros(
            (cfg.num_layers, batch, n_heads, cfg.ssm_head_dim,
             cfg.ssm_state_size), jnp.float32)
        cache["conv"] = jnp.zeros(
            (cfg.num_layers, batch, cfg.ssm_conv_width - 1, conv_dim), dtype)
    else:
        cache["k"] = jnp.zeros(
            (cfg.num_layers, batch, max_seq, cfg.num_kv_heads, cfg.head_dim),
            dtype)
        cache["v"] = jnp.zeros_like(cache["k"])
        if cfg.is_encoder_decoder:
            cache["cross_k"] = jnp.zeros(
                (cfg.num_layers, batch, cfg.enc_seq_len, cfg.num_kv_heads,
                 cfg.head_dim), dtype)
            cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
    return cache


# ---------------------------------------------------------------------------
# Public forward
# ---------------------------------------------------------------------------
def hidden_states(params, cfg: ModelConfig, tokens=None, embeds=None,
                  enc_embeds=None, remat: bool = False):
    """Final-layer hidden states (pre-unembed) — the frozen-backbone
    embedding interface used by the preference pipeline."""
    x = _embed_in(params, cfg, tokens=tokens, embeds=embeds)
    kinds = cfg.layer_kinds()
    cross = None
    if cfg.is_encoder_decoder:
        enc_out = encode(params, cfg, enc_embeds)
        cross = _stacked_cross_kv(params, cfg, enc_out)
    if cfg.shared_attn_every:
        x, _, aux = _run_hybrid(params, cfg, x, "full", remat=remat)
    elif all(k == MAMBA for k in kinds):
        x, _, aux = _scan_mamba_layers(params, cfg, x, "full", remat=remat)
    else:
        x, _, aux = _scan_attn_layers(params, cfg, x, "full", cross=cross,
                                      remat=remat)
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def forward(params, cfg: ModelConfig, *, tokens=None, embeds=None,
            enc_embeds=None, cache=None, cache_pos=None,
            prefill_len: Optional[int] = None, remat: bool = False):
    """Unified forward.

    Modes:
      * train/full  : cache=None, prefill_len=None -> (logits, None, aux)
      * prefill     : prefill_len=S_max            -> (logits, cache, aux)
      * decode      : cache + cache_pos, S==1      -> (logits, cache, aux)
    """
    x = _embed_in(params, cfg, tokens=tokens, embeds=embeds)
    kinds = cfg.layer_kinds()
    is_mamba = all(k == MAMBA for k in kinds)
    is_hybrid = bool(cfg.shared_attn_every)

    mode = "full"
    if prefill_len is not None:
        mode = "prefill"
    elif cache is not None:
        mode = "decode"

    cross = None
    if cfg.is_encoder_decoder:
        if mode == "decode":
            cross = (cache["cross_k"], cache["cross_v"])
        else:
            enc_out = encode(params, cfg, enc_embeds)
            cross = _stacked_cross_kv(params, cfg, enc_out)

    if mode == "decode" and cache is not None and "ring_k" in cache:
        x, new_cache, aux = _scan_ring_decode(params, cfg, x, cache,
                                              cache_pos)
    elif is_hybrid:
        x, new_cache, aux = _run_hybrid(
            params, cfg, x, mode, cache=cache, cache_pos=cache_pos,
            cache_len=prefill_len, remat=remat)
    elif is_mamba:
        x, new_cache, aux = _scan_mamba_layers(
            params, cfg, x, mode if mode != "prefill" else "full",
            cache=cache, remat=remat)
        # mamba "prefill" == full forward; final states are the cache
    else:
        x, new_cache, aux = _scan_attn_layers(
            params, cfg, x, mode, cache=cache, cache_pos=cache_pos,
            cache_len=prefill_len, cross=cross, remat=remat)

    logits = _unembed(params, cfg, x)
    return logits, new_cache, aux
