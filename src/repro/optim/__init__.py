from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adafactor,
    adam,
    adamw,
    sgd,
    clip_by_global_norm,
)
from repro.optim.schedules import (  # noqa: F401
    constant_schedule,
    cosine_schedule,
    linear_warmup_cosine,
)
