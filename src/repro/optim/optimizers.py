"""Self-contained optimizers (no optax).

An ``Optimizer`` is an (init, update) pair over parameter pytrees; state is
itself a pytree so it shards, checkpoints, and federates like parameters.
The federated runtime keeps one optimizer state per client (paper §4.3 uses
Adam 3e-4 locally).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils.pytree import tree_global_norm

PyTree = Any


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: PyTree
    nu: PyTree


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: PyTree


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]
    """update(grads, state, params) -> (new_params, new_state)"""


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, jnp.ndarray]:
    norm = tree_global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         grad_clip: float = 0.0) -> Optimizer:
    """Adam. ``lr`` is a float or a schedule fn(step)->lr."""
    sched = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return AdamState(step=jnp.zeros((), jnp.int32), mu=z,
                         nu=jax.tree.map(jnp.copy, z))

    def update(grads, state: AdamState, params):
        if grad_clip > 0.0:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        step = state.step + 1
        lr_t = sched(step)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            return (p.astype(jnp.float32) - lr_t * mhat /
                    (jnp.sqrt(vhat) + eps)).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01, grad_clip: float = 0.0) -> Optimizer:
    base = adam(lr, b1, b2, eps, grad_clip)
    sched = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def update(grads, state: AdamState, params):
        new_params, new_state = base.update(grads, state, params)
        lr_t = sched(new_state.step)
        new_params = jax.tree.map(
            lambda np_, p: (np_.astype(jnp.float32)
                            - lr_t * weight_decay * p.astype(jnp.float32)
                            ).astype(p.dtype),
            new_params, params)
        return new_params, new_state

    return Optimizer(init=base.init, update=update)


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    v_row: PyTree  # factored second moment (rows) for >=2D leaves
    v_col: PyTree  # factored second moment (cols)
    v_full: PyTree  # unfactored for <2D leaves


def adafactor(lr, b2_decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0) -> Optimizer:
    """Memory-factored optimizer for the very large backbones: second
    moments of a (..., n, m) leaf are stored as (..., n) + (..., m) — the
    optimizer state for grok-1 shrinks from 2.5 TB (Adam) to ~GBs, which is
    what makes the 314B train_4k dry-run fit 16 GB/chip (DESIGN.md §3)."""
    sched = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def rows(p):
            return (jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p)
                    else jnp.zeros((1,), jnp.float32))

        def cols(p):
            return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if _factored(p) else jnp.zeros((1,), jnp.float32))

        def full(p):
            return (jnp.zeros((1,), jnp.float32) if _factored(p)
                    else jnp.zeros_like(p, dtype=jnp.float32))

        return AdafactorState(
            step=jnp.zeros((), jnp.int32),
            v_row=jax.tree.map(rows, params),
            v_col=jax.tree.map(cols, params),
            v_full=jax.tree.map(full, params))

    def update(grads, state: AdafactorState, params):
        step = state.step + 1
        # decay schedule: 1 - step^{-0.8}
        b2 = 1.0 - jnp.power(step.astype(jnp.float32), -b2_decay)
        lr_t = sched(step)

        def upd(p, g, vr, vc, vf):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if _factored(p):
                vr = b2 * vr + (1 - b2) * jnp.mean(g2, axis=-1)
                vc = b2 * vc + (1 - b2) * jnp.mean(g2, axis=-2)
                r = vr / jnp.maximum(
                    jnp.mean(vr, axis=-1, keepdims=True), eps)
                v = r[..., None] * vc[..., None, :]
            else:
                vf = b2 * vf + (1 - b2) * g2
                v = vf
            u = g32 * jax.lax.rsqrt(v + eps)
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            new_p = (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)
            return new_p, vr, vc, vf

        # flatten-apply-unflatten (params trees contain NamedTuples, so a
        # tuple-returning tree.map cannot be unzipped with is_leaf tricks)
        p_leaves, treedef = jax.tree.flatten(params)
        g_leaves = jax.tree.leaves(grads)
        vr_leaves = jax.tree.leaves(state.v_row)
        vc_leaves = jax.tree.leaves(state.v_col)
        vf_leaves = jax.tree.leaves(state.v_full)
        results = [upd(*t) for t in zip(p_leaves, g_leaves, vr_leaves,
                                        vc_leaves, vf_leaves)]
        unf = lambda i: jax.tree.unflatten(  # noqa: E731
            treedef, [r[i] for r in results])
        return unf(0), AdafactorState(step=step, v_row=unf(1), v_col=unf(2),
                                      v_full=unf(3))

    return Optimizer(init=init, update=update)


def sgd(lr, momentum: float = 0.0, grad_clip: float = 0.0) -> Optimizer:
    sched = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        return SGDState(
            step=jnp.zeros((), jnp.int32),
            momentum=jax.tree.map(
                lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
        )

    def update(grads, state: SGDState, params):
        if grad_clip > 0.0:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        step = state.step + 1
        lr_t = sched(step)
        mom = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32),
            state.momentum, grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr_t * m).astype(p.dtype),
            params, mom)
        return new_params, SGDState(step=step, momentum=mom)

    return Optimizer(init=init, update=update)
