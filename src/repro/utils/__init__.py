from repro.utils import pytree, registry  # noqa: F401
