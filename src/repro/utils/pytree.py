"""Pytree helpers used across the framework.

These are deliberately dependency-free (pure jax) so every layer —
optimizers, FedAvg aggregation, checkpointing — shares one vocabulary for
manipulating parameter trees.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree: PyTree, s) -> PyTree:
    return jax.tree.map(lambda x: x * s, tree)


def tree_axpy(alpha, x: PyTree, y: PyTree) -> PyTree:
    """alpha * x + y, leafwise."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_weighted_sum(trees: list[PyTree], weights) -> PyTree:
    """sum_i weights[i] * trees[i]  — the FedAvg primitive (host-side form)."""
    weights = jnp.asarray(weights)
    acc = tree_scale(trees[0], weights[0])
    for w, t in zip(weights[1:], trees[1:]):
        acc = tree_axpy(w, t, acc)
    return acc


def tree_dot(a: PyTree, b: PyTree):
    leaves = jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b)
    return jax.tree.reduce(jnp.add, leaves)


def tree_sq_norm(tree: PyTree):
    leaves = jax.tree.map(lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), tree)
    return jax.tree.reduce(jnp.add, leaves)


def tree_global_norm(tree: PyTree):
    return jnp.sqrt(tree_sq_norm(tree))


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_count_params(tree: PyTree) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))


def tree_bytes(tree: PyTree) -> int:
    return int(sum(np.prod(x.shape) * x.dtype.itemsize for x in jax.tree.leaves(tree)))


def tree_map_with_path(fn: Callable, tree: PyTree) -> PyTree:
    """fn(path_string, leaf) -> leaf."""

    def _fn(path, leaf):
        return fn(jax.tree_util.keystr(path), leaf)

    return jax.tree_util.tree_map_with_path(_fn, tree)


def tree_any_nan(tree: PyTree):
    leaves = jax.tree.map(lambda x: jnp.any(jnp.isnan(x)), tree)
    return jax.tree.reduce(jnp.logical_or, leaves)


def tree_stack(trees: list[PyTree]) -> PyTree:
    """Stack a list of identically-structured trees along a new leading axis.

    Used to stack per-client parameter sets onto the client axis and
    per-layer parameters for ``lax.scan`` over depth.
    """
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree: PyTree, n: int) -> list[PyTree]:
    return [jax.tree.map(lambda x: x[i], tree) for i in range(n)]


def tree_index(tree: PyTree, i) -> PyTree:
    return jax.tree.map(lambda x: x[i], tree)


def tree_flatten_to_vector(tree: PyTree) -> jnp.ndarray:
    """Concatenate every leaf (f32) into one flat vector. Used by the
    ``fedavg_reduce`` kernel path and by property tests."""
    leaves = jax.tree.leaves(tree)
    return jnp.concatenate([jnp.ravel(x).astype(jnp.float32) for x in leaves])


def tree_ravel_clients(stacked_tree: PyTree) -> jnp.ndarray:
    """Client-stacked tree (leaves (C, ...)) -> (C, P) matrix in one shot.

    A single vmapped ravel over the client axis — the flattening step of
    the ``fedavg_reduce`` kernel contract. Replaces the per-client Python
    loop (C separate gather+concatenate chains) with one program.
    """
    return jax.vmap(tree_flatten_to_vector)(stacked_tree)


def tree_unflatten_from_vector(vec: jnp.ndarray, like: PyTree) -> PyTree:
    leaves, treedef = jax.tree.flatten(like)
    out, off = [], 0
    for leaf in leaves:
        size = int(np.prod(leaf.shape))
        out.append(vec[off : off + size].reshape(leaf.shape).astype(leaf.dtype))
        off += size
    return jax.tree.unflatten(treedef, out)
