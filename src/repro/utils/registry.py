"""Tiny name -> factory registry, used for architectures and trainers."""
from __future__ import annotations

from typing import Callable, Dict, Generic, TypeVar

T = TypeVar("T")


class Registry(Generic[T]):
    def __init__(self, kind: str):
        self.kind = kind
        self._entries: Dict[str, Callable[[], T]] = {}

    def register(self, name: str) -> Callable[[Callable[[], T]], Callable[[], T]]:
        def deco(fn: Callable[[], T]) -> Callable[[], T]:
            if name in self._entries:
                raise ValueError(f"duplicate {self.kind} registration: {name}")
            self._entries[name] = fn
            return fn

        return deco

    def get(self, name: str) -> T:
        if name not in self._entries:
            raise KeyError(
                f"unknown {self.kind} '{name}'. known: {sorted(self._entries)}"
            )
        return self._entries[name]()

    def names(self) -> list[str]:
        return sorted(self._entries)

    def __contains__(self, name: str) -> bool:
        return name in self._entries
