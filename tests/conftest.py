import os

# Tests must see the single real CPU device (the 512-device override is
# strictly dryrun.py's); keep any preset XLA_FLAGS out of the test env.
os.environ.pop("XLA_FLAGS", None)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
