"""Byzantine attack/defense suite (DESIGN.md §13).

Covers the three contracts the §13 layer makes:

* the adversarial client simulator is deterministic per round (same
  byz key → same attacker set, same corrupted rows) and identical
  across the scan, loop, and sharded engines;
* the benign default is BIT-equal to the pre-§13 round — attack off +
  norm_bound off traces the exact same computation, pinned both at the
  numeric level (scan vs loop, run-to-run) and at the compiled wire
  level (the linear family's collective bytes are unchanged whether
  the attack stage is on or off);
* the defenses (krum / multi_krum / geomedian / norm_bound) actually
  reject outliers, the Pallas (C, C) distance kernel matches its
  oracle, and the composition guard fires on the adaptive+DP+defense
  foot-gun.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (
    AdversaryConfig,
    AggConfig,
    CompressionConfig,
    FedConfig,
    GPOConfig,
    PrivacyConfig,
)
from repro.core import adversary as byz
from repro.core.aggregation import (
    geometric_median_flat,
    krum_scores,
    make_aggregator,
)
from repro.core.federated import (
    FederatedGPO,
    _make_local_train,
    make_sharded_round,
)
from repro.core.fedavg import broadcast_to_clients, normalize_weights
from repro.core.gpo import init_gpo_params
from repro.core.pipeline import STAGE_NAMES, make_pipeline
from repro.data.surveys import SurveyConfig, make_survey_data
from repro.kernels import agg_pairwise_dists
from repro.kernels.ref import ref_pairwise_sq_dists
from repro.optim import adam
from repro.utils.pytree import tree_sub

GCFG = GPOConfig(d_embed=4, d_model=8, num_layers=1, num_heads=1, d_ff=16)


def _data(groups=6, questions=12, d_embed=4):
    return make_survey_data(SurveyConfig(
        num_groups=groups, num_questions=questions, d_embed=d_embed,
        seed=0))


def _run(fcfg, engine, data, rounds=3):
    groups = np.arange(fcfg.num_clients)
    fed = FederatedGPO(GCFG, fcfg, data, groups, groups)
    return fed.run(rounds=rounds, engine=engine)


# ---------------------------------------------------------------------------
# simulator determinism
# ---------------------------------------------------------------------------
def test_byz_key_folds_out_of_round_key():
    k = jax.random.PRNGKey(3)
    bk = byz.fold_byz_key(k)
    assert not np.array_equal(np.asarray(bk), np.asarray(k))
    np.testing.assert_array_equal(np.asarray(bk),
                                  np.asarray(byz.fold_byz_key(k)))


def test_attacker_mask_exact_count_and_determinism():
    bk = byz.fold_byz_key(jax.random.PRNGKey(0))
    for c, f in [(8, 3), (5, 0), (4, 9)]:
        m = byz.attacker_mask(bk, c, f)
        assert m.shape == (c,) and m.dtype == jnp.bool_.dtype
        assert int(m.sum()) == min(f, c)
        np.testing.assert_array_equal(np.asarray(m),
                                      np.asarray(byz.attacker_mask(bk, c, f)))
    # a different round key re-draws the population
    bk2 = byz.fold_byz_key(jax.random.PRNGKey(1))
    masks = [np.asarray(byz.attacker_mask(k, 64, 16)) for k in (bk, bk2)]
    assert not np.array_equal(*masks)


def test_attack_rows_bit_identical_under_subsampling():
    """Client g's corrupted row depends only on (byz_key, g): computing
    the attack over the full population or over any gid subset yields
    byte-identical rows for the shared clients — the scan/loop/sharded
    replay contract."""
    c, p = 8, 17
    bk = byz.fold_byz_key(jax.random.PRNGKey(5))
    vecs = jax.random.normal(jax.random.PRNGKey(6), (c, p))
    adv = AdversaryConfig(kind="gaussian", num_attackers=3)
    mask = byz.attacker_mask(bk, c, adv.num_attackers)
    full = byz.apply_attack(vecs, mask, adv, bk, jnp.arange(c))
    sub = jnp.asarray([1, 4, 6])
    part = byz.apply_attack(vecs[sub], mask[sub], adv, bk, sub)
    np.testing.assert_array_equal(np.asarray(full)[np.asarray(sub)],
                                  np.asarray(part))


def test_attack_semantics_on_flat_rows():
    c, p = 6, 5
    bk = byz.fold_byz_key(jax.random.PRNGKey(2))
    vecs = jax.random.normal(jax.random.PRNGKey(3), (c, p))
    mask = jnp.asarray([True, False, True, False, False, False])
    gids = jnp.arange(c)

    out = byz.apply_attack(vecs, mask, AdversaryConfig(
        kind="sign_flip", num_attackers=2), bk, gids)
    np.testing.assert_allclose(np.asarray(out[0]), -np.asarray(vecs[0]))
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(vecs[1]))

    out = byz.apply_attack(vecs, mask, AdversaryConfig(
        kind="scaled", num_attackers=2, scale=7.0), bk, gids)
    np.testing.assert_allclose(np.asarray(out[2]),
                               7.0 * np.asarray(vecs[2]), rtol=1e-6)

    # ALIE rows collapse onto mean + z*std of the HONEST rows only
    adv = AdversaryConfig(kind="alie", num_attackers=2)
    out = byz.apply_attack(vecs, mask, adv, bk, gids)
    mean, std = byz.honest_stats(vecs.astype(jnp.float32), mask)
    np.testing.assert_allclose(
        np.asarray(out[0]),
        np.asarray(mean + adv.alie_z * std), rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out[2]))

    # disabled / data-level attacks are the identity on the wire
    for adv in (AdversaryConfig(),
                AdversaryConfig(kind="label_flip", num_attackers=2)):
        np.testing.assert_array_equal(
            np.asarray(byz.apply_attack(vecs, mask, adv, bk, gids)),
            np.asarray(vecs))


def test_flip_preferences_stays_on_simplex_and_reverses_order():
    a = 4
    key = jax.random.PRNGKey(9)
    logits = jax.random.normal(key, (5, a))
    y = jax.nn.softmax(logits, axis=-1)  # rows on the simplex
    flipped = byz.flip_preferences(y.reshape(-1), a).reshape(5, a)
    np.testing.assert_allclose(np.asarray(flipped.sum(-1)),
                               np.ones(5), rtol=1e-5)
    assert np.all(np.asarray(flipped) >= 0)
    # exactly reversed preference ordering per question
    np.testing.assert_array_equal(
        np.argsort(np.asarray(y), axis=-1),
        np.argsort(np.asarray(flipped), axis=-1)[:, ::-1])
    for q in range(5):
        assert (np.argmax(np.asarray(y)[q])
                == np.argmin(np.asarray(flipped)[q]))
        assert (np.argmin(np.asarray(y)[q])
                == np.argmax(np.asarray(flipped)[q]))


# ---------------------------------------------------------------------------
# defenses
# ---------------------------------------------------------------------------
def test_krum_selects_honest_row_against_outliers():
    c, p, f = 9, 11, 3
    honest = jax.random.normal(jax.random.PRNGKey(0), (c - f, p))
    bad = 50.0 * jnp.ones((f, p))
    vecs = jnp.concatenate([honest, bad], axis=0)
    w = jnp.full((c,), 1.0 / c)
    scores = krum_scores(vecs, w, f)
    assert int(jnp.argmin(scores)) < c - f  # never an outlier
    # the fused-kernel scores agree with the jnp path
    np.testing.assert_allclose(
        np.asarray(scores),
        np.asarray(krum_scores(vecs, w, f, use_pallas=True)),
        rtol=1e-4, atol=1e-4)


def test_geomedian_rejects_outliers_mean_does_not():
    c, p, f = 10, 7, 3
    honest = jax.random.normal(jax.random.PRNGKey(1), (c - f, p))
    vecs = jnp.concatenate([honest, 50.0 * jnp.ones((f, p))], axis=0)
    w = jnp.full((c,), 1.0 / c)
    gm = geometric_median_flat(vecs, w, iters=50, eps=1e-6)
    m_honest = jnp.mean(honest, axis=0)
    m_all = jnp.average(vecs, axis=0, weights=w)
    d_gm = float(jnp.linalg.norm(gm - m_honest))
    d_mean = float(jnp.linalg.norm(m_all - m_honest))
    assert d_gm < 0.2 * d_mean  # the mean is dragged ~f/c * 50, gm is not


def test_norm_clip_rows_bounds_and_preserves_small():
    vecs = jnp.asarray([[3.0, 4.0], [0.3, 0.4], [0.0, 0.0]])
    out = np.asarray(byz.norm_clip_rows(vecs, 1.0))
    np.testing.assert_allclose(np.linalg.norm(out[0]), 1.0, rtol=1e-6)
    np.testing.assert_allclose(out[1], [0.3, 0.4], rtol=1e-6)
    np.testing.assert_array_equal(out[2], [0.0, 0.0])


def test_pairwise_kernel_matches_oracle():
    x = jax.random.normal(jax.random.PRNGKey(4), (6, 33))
    ref = np.asarray(ref_pairwise_sq_dists(x))
    out = np.asarray(agg_pairwise_dists(x, interpret=True))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_defense_composition_guard():
    base = dict(num_clients=4, rounds=1,
                adversary=AdversaryConfig(kind="sign_flip",
                                          num_attackers=1),
                privacy=PrivacyConfig(clip_norm=1.0, noise_multiplier=0.5))
    ok = FedConfig(agg=AggConfig(name="krum", num_malicious=1), **base)
    byz.check_defense_composition(ok)  # loss-free defense: silent

    bad = FedConfig(agg=AggConfig(name="adaptive"), strict_privacy=False,
                    **base)
    with pytest.warns(UserWarning, match="attacker-steerable"):
        byz.check_defense_composition(bad)

    strict = FedConfig(agg=AggConfig(name="adaptive"), strict_privacy=True,
                       **base)
    with pytest.raises(ValueError, match="attacker-steerable"):
        byz.check_defense_composition(strict)


# ---------------------------------------------------------------------------
# stage pipeline: every engine assembles the same declared stage list
# ---------------------------------------------------------------------------
def test_stage_list_shared_across_engines():
    fcfg = FedConfig(num_clients=6,
                     adversary=AdversaryConfig(kind="scaled",
                                               num_attackers=2),
                     privacy=PrivacyConfig(clip_norm=1.0),
                     compression=CompressionConfig(kind="int8"),
                     agg=AggConfig(name="krum", num_malicious=2))
    agg = make_aggregator(fcfg.agg, num_clients=6)
    pipe = make_pipeline(fcfg, agg=agg, num_clients=6)
    assert tuple(n for n, _ in pipe.stages()) == STAGE_NAMES
    assert all(on for _, on in pipe.stages())
    assert pipe.restructured

    off = FedConfig(num_clients=6)
    pipe_off = make_pipeline(off, agg=make_aggregator(off.agg,
                                                      num_clients=6),
                             num_clients=6)
    assert [n for n, on in pipe_off.stages() if on] == ["local_train",
                                                        "aggregate"]
    assert not pipe_off.restructured  # benign default: pre-§13 trace


# ---------------------------------------------------------------------------
# engine equivalence (scan == loop == sharded) under attack
# ---------------------------------------------------------------------------
def test_attack_off_round_is_deterministic_and_engine_invariant():
    """The benign default pins the pre-§13 numerics: scan and loop agree
    bit-for-bit, and reruns reproduce exactly."""
    data = _data()
    fcfg = FedConfig(num_clients=6, rounds=3, local_epochs=2,
                     num_context=3, num_target=3, eval_every=10)
    h_scan = _run(fcfg, "scan", data)
    h_loop = _run(fcfg, "loop", data)
    np.testing.assert_array_equal(h_scan.round_loss, h_loop.round_loss)
    np.testing.assert_array_equal(h_scan.round_loss,
                                  _run(fcfg, "scan", data).round_loss)


@pytest.mark.parametrize("kind,aggname", [
    ("sign_flip", "krum"),
    ("alie", "geomedian"),
    ("label_flip", "multi_krum"),
])
def test_attacked_round_scan_matches_loop(kind, aggname):
    data = _data()
    fcfg = FedConfig(num_clients=6, rounds=3, local_epochs=2,
                     num_context=3, num_target=3, eval_every=10,
                     adversary=AdversaryConfig(kind=kind,
                                               num_attackers=2),
                     agg=AggConfig(name=aggname, num_malicious=2,
                                   multi_krum_m=3))
    h_scan = _run(fcfg, "scan", data)
    h_loop = _run(fcfg, "loop", data)
    np.testing.assert_array_equal(h_scan.round_loss, h_loop.round_loss)
    # the attack visibly perturbed the trajectory
    clean = FedConfig(num_clients=6, rounds=3, local_epochs=2,
                      num_context=3, num_target=3, eval_every=10)
    assert not np.array_equal(h_scan.round_loss,
                              _run(clean, "scan", data).round_loss)


@pytest.mark.parametrize("adv,aggcfg", [
    (AdversaryConfig(kind="sign_flip", num_attackers=2),
     AggConfig(name="krum", num_malicious=2)),
    (AdversaryConfig(kind="alie", num_attackers=2),
     AggConfig(name="geomedian", norm_bound=2.0)),
    (AdversaryConfig(kind="label_flip", num_attackers=2),
     AggConfig(name="multi_krum", num_malicious=2, multi_krum_m=3)),
])
def test_sharded_attacked_round_matches_stacked(adv, aggcfg):
    """One full attacked round through ``make_sharded_round`` on a
    1-device mesh lands on the stacked pipeline's update (the ALIE
    psum'd honest stats, the replicated byz key, and the all-gathered
    robust reduce all agree with their stacked counterparts)."""
    c = 5
    gcfg = GPOConfig(d_embed=8, d_model=8, num_layers=1, num_heads=1,
                     d_ff=16)
    data = _data(groups=c, questions=24, d_embed=8)
    fcfg = FedConfig(num_clients=c, local_epochs=2, lr=1e-3,
                     num_context=4, num_target=4, adversary=adv,
                     agg=aggcfg)
    opt = adam(fcfg.lr)
    agg = make_aggregator(fcfg.agg, num_clients=c)
    params = init_gpo_params(gcfg, jax.random.PRNGKey(0))
    server_state = agg.init(params)
    groups = jnp.arange(c, dtype=jnp.int32)
    weights = normalize_weights(data.sizes[groups])
    k_round = jax.random.PRNGKey(7)
    keys = jax.random.split(k_round, c)
    bk = byz.fold_byz_key(k_round)
    client_params = broadcast_to_clients(params, c)
    opt_states = jax.vmap(opt.init)(client_params)

    pipe = make_pipeline(fcfg, agg=agg, num_clients=c)
    local_train = _make_local_train(gcfg, fcfg, data, opt)
    if pipe.flip_data:
        att = pipe.attacked_flags(bk, groups)
        cp_ref, _, losses = jax.jit(jax.vmap(local_train))(
            client_params, opt_states, keys, groups, att)
    else:
        cp_ref, _, losses = jax.jit(jax.vmap(local_train))(
            client_params, opt_states, keys, groups)
    deltas = tree_sub(cp_ref, client_params)
    global_ref, _, _ = pipe.reduce_apply(
        server_state, params, deltas, weights, keys, losses=losses,
        idx=groups, resid=None, byz_key=bk)

    mesh = jax.make_mesh((1,), ("data",))
    round_fn = make_sharded_round(gcfg, fcfg, data, mesh, opt=opt,
                                  agg=agg)
    cp_s, _, _, _ = jax.jit(round_fn)(
        client_params, opt_states, keys, groups, weights, server_state,
        bk)
    for a, b in zip(jax.tree.leaves(global_ref), jax.tree.leaves(cp_s)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b)[0],
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# compiled wire: the linear family's collectives are attack-invariant
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_attack_stage_keeps_linear_collective_bytes():
    """hlo_cost acceptance pin (DESIGN.md §13): turning the attack stage
    on must not change the compiled collective schedule of the linear
    family — same single parameter-sized all-reduce, byte-identical.
    Subprocess because the 8-device host-platform override is
    process-global."""
    code = """
import json
from repro.launch.dryrun import lower_gpo_round
out = {}
for attack in ("none", "sign_flip"):
    r = lower_gpo_round("fedavg", clients=8, attack=attack, attackers=2,
                        verbose=False)
    out[attack] = r["collective_bytes_by_kind"]
print(json.dumps(out))
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + sys.path))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["sign_flip"] == out["none"]
    assert out["none"].get("all-reduce", 0) > 0
