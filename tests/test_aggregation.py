"""The pluggable server-aggregation subsystem (DESIGN.md §7).

Four contracts, each tested across the registry:

1. degeneracy — every strategy with trivial hyperparameters (zero
   momentum, beta2=1/tau=1 moments, mu=0 prox, zero trim, zero
   fairness temperature) reproduces the paper's Eq. 2-3 FedAvg;
2. engine equivalence — scan and loop drivers agree per strategy, with
   the server-optimizer state riding the fused scan carry;
3. sharded equivalence — ``make_sharded_round`` on a client mesh equals
   the stacked reference per strategy (delta psum for the linear family,
   all-gather + rank-trim for the robust family);
4. unit semantics — trim/median order statistics, adaptive weights,
   FedProx proximal pull.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import AggConfig, FedConfig, GPOConfig
from repro.core import (
    AGGREGATORS,
    FederatedGPO,
    broadcast_to_clients,
    make_aggregator,
    normalize_weights,
)
from repro.core.aggregation import trimmed_mean_reduce_flat
from repro.core.federated import _make_local_train, make_sharded_round
from repro.core.gpo import init_gpo_params
from repro.data import SurveyConfig, make_survey_data, split_groups
from repro.optim import adam
from repro.utils.pytree import tree_sub

GCFG = GPOConfig(d_embed=8, d_model=16, num_layers=1, num_heads=2, d_ff=32)

# hyperparameters that degenerate each strategy to exact FedAvg
TRIVIAL = {
    "fedavg": {},
    "fedprox": {"prox_mu": 0.0},
    "fedavgm": {"momentum": 0.0, "server_lr": 1.0},
    # beta2=1 freezes v at its zero init; tau=1 makes the denominator 1
    "fedadam": {"beta1": 0.0, "beta2": 1.0, "tau": 1.0, "server_lr": 1.0},
    "fedyogi": {"beta1": 0.0, "beta2": 1.0, "tau": 1.0, "server_lr": 1.0},
    "trimmed_mean": {"trim_frac": 0.0},
    "adaptive": {"fair_temp": 0.0},
}

# hyperparameters that exercise each strategy's actual mechanism
ACTIVE = {
    "fedavg": {},
    "fedprox": {"prox_mu": 0.1},
    "fedavgm": {"momentum": 0.9},
    "fedadam": {"beta1": 0.9, "beta2": 0.99, "tau": 1e-2,
                "server_lr": 1e-1},
    "fedyogi": {"beta1": 0.9, "beta2": 0.99, "tau": 1e-2,
                "server_lr": 1e-1},
    "trimmed_mean": {"trim_frac": 0.2},
    "median": {},
    "adaptive": {"fair_temp": 1.0, "fair_decay": 0.5},
}


def _make_fed(agg_cfg=AggConfig(), use_pallas=False, seed=3):
    data = make_survey_data(SurveyConfig(
        num_groups=6, num_questions=24, d_embed=8, seed=seed))
    tr, ev = split_groups(data, seed=seed)
    fcfg = FedConfig(num_clients=len(tr), rounds=3, local_epochs=2,
                     eval_every=2, num_context=4, num_target=4,
                     use_pallas_aggregation=use_pallas, agg=agg_cfg,
                     seed=seed)
    return FederatedGPO(GCFG, fcfg, data, tr, ev)


def _assert_close(fed_a, fed_b, hist_a, hist_b, rtol=1e-4, atol=1e-6):
    np.testing.assert_allclose(hist_a.round_loss, hist_b.round_loss,
                               rtol=rtol, atol=atol)
    for a, b in zip(jax.tree.leaves(fed_a.global_params),
                    jax.tree.leaves(fed_b.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=rtol, atol=atol)


def test_registry_lists_the_full_family():
    assert {"fedavg", "fedavgm", "fedadam", "fedyogi", "fedprox",
            "trimmed_mean", "median", "adaptive"} <= set(AGGREGATORS.names())
    with pytest.raises(KeyError):
        make_aggregator(AggConfig(name="nope"), num_clients=4)


@pytest.mark.parametrize("name", sorted(TRIVIAL))
def test_trivial_hyperparams_reproduce_fedavg(name):
    """Degenerate configs collapse every strategy to Eq. 2-3 FedAvg."""
    fed_ref = _make_fed()
    hist_ref = fed_ref.run(rounds=3)
    fed = _make_fed(AggConfig(name=name, **TRIVIAL[name]))
    hist = fed.run(rounds=3)
    _assert_close(fed_ref, fed, hist_ref, hist)


@pytest.mark.parametrize("name", sorted(ACTIVE))
def test_scan_matches_loop_with_server_state(name):
    """Both drivers agree per strategy — the server-optimizer state in
    the fused scan carry advances exactly like the per-round loop's."""
    cfg = AggConfig(name=name, **ACTIVE[name])
    fed_scan = _make_fed(cfg)
    hist_scan = fed_scan.run(rounds=3, engine="scan")
    fed_loop = _make_fed(cfg)
    hist_loop = fed_loop.run(rounds=3, engine="loop")
    _assert_close(fed_scan, fed_loop, hist_scan, hist_loop, rtol=1e-3)
    for a, b in zip(jax.tree.leaves(fed_scan.server_state),
                    jax.tree.leaves(fed_loop.server_state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)
    assert int(jax.tree.leaves(fed_scan.server_state.step)[0]) == 3


@pytest.mark.parametrize("name", sorted(ACTIVE))
def test_sharded_round_matches_stacked(name):
    """make_sharded_round on a 1-device client mesh equals the stacked
    engine's round for every strategy (delta psum / all-gather trim)."""
    C = 5
    data = make_survey_data(SurveyConfig(
        num_groups=C, num_questions=24, d_embed=8, seed=0))
    fcfg = FedConfig(num_clients=C, local_epochs=2, lr=1e-3,
                     num_context=4, num_target=4,
                     agg=AggConfig(name=name, **ACTIVE[name]))
    opt = adam(fcfg.lr)
    agg = make_aggregator(fcfg.agg, num_clients=C)
    params = init_gpo_params(GCFG, jax.random.PRNGKey(0))
    server_state = agg.init(params)
    groups = jnp.arange(C, dtype=jnp.int32)
    weights = normalize_weights(data.sizes[groups])
    keys = jax.random.split(jax.random.PRNGKey(1), C)
    client_params = broadcast_to_clients(params, C)
    opt_states = jax.vmap(opt.init)(client_params)

    # stacked reference: vmap local train + the aggregator's own step
    local_train = _make_local_train(GCFG, fcfg, data, opt)
    cp_ref, _, losses_ref = jax.jit(jax.vmap(local_train))(
        client_params, opt_states, keys, groups)
    deltas = tree_sub(cp_ref, client_params)
    global_ref, srv_ref = agg.step(server_state, params, deltas, weights,
                                   losses=losses_ref,
                                   idx=jnp.arange(C))

    mesh = jax.make_mesh((1,), ("data",))
    round_fn = make_sharded_round(GCFG, fcfg, data, mesh, opt=opt, agg=agg)
    cp_s, _, losses_s, srv_s = jax.jit(round_fn)(
        client_params, opt_states, keys, groups, weights, server_state)

    np.testing.assert_allclose(np.asarray(losses_ref), np.asarray(losses_s),
                               rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(global_ref), jax.tree.leaves(cp_s)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b)[0],
                                   rtol=1e-4, atol=1e-5)
    for a, b in zip(jax.tree.leaves(srv_ref), jax.tree.leaves(srv_s)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("name", ["fedavg", "fedavgm", "trimmed_mean",
                                  "median"])
def test_pallas_aggregation_matches_jnp(name):
    """use_pallas_aggregation routes the reductions through the kernels
    in kernels/agg_reduce.py; metrics must match the jnp reference."""
    cfg = AggConfig(name=name, **ACTIVE.get(name, {}))
    fed_jnp = _make_fed(cfg)
    hist_jnp = fed_jnp.run(rounds=3)
    fed_pal = _make_fed(cfg, use_pallas=True)
    hist_pal = fed_pal.run(rounds=3)
    _assert_close(fed_jnp, fed_pal, hist_jnp, hist_pal, rtol=1e-4)
    for a, b in zip(jax.tree.leaves(fed_jnp.server_state),
                    jax.tree.leaves(fed_pal.server_state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# unit semantics
# ---------------------------------------------------------------------------
def test_trimmed_mean_ignores_outlier_client():
    key = jax.random.PRNGKey(0)
    vecs = jax.random.normal(key, (8, 64))
    vecs = vecs.at[3].set(1e6)  # one poisoned client
    w = jnp.full((8,), 1.0 / 8)
    out = trimmed_mean_reduce_flat(vecs, w, k=1)
    assert float(jnp.max(jnp.abs(out))) < 100.0
    # untrimmed mean is dominated by the outlier
    assert float(jnp.max(jnp.abs(trimmed_mean_reduce_flat(
        vecs, w, k=0)))) > 1e4


def test_median_matches_numpy_median_for_uniform_weights():
    key = jax.random.PRNGKey(1)
    vecs = jax.random.normal(key, (7, 33))
    w = jnp.full((7,), 1.0 / 7)
    out = trimmed_mean_reduce_flat(vecs, w, k=3)  # (C-1)//2 == median
    np.testing.assert_allclose(np.asarray(out),
                               np.median(np.asarray(vecs), axis=0),
                               rtol=1e-6, atol=1e-7)


def test_adaptive_weights_upweight_high_loss_groups():
    agg = make_aggregator(AggConfig(name="adaptive", fair_temp=1.0),
                          num_clients=4)
    state = agg.init({"w": jnp.zeros((3,))})
    state = state._replace(scores={
        "ema": jnp.array([0.1, 0.1, 0.1, 2.0]), "seen": jnp.ones((4,))})
    base = jnp.full((4,), 0.25)
    w = agg.weigh(state, base, None)
    assert float(jnp.sum(w)) == pytest.approx(1.0, abs=1e-6)
    assert float(w[3]) > float(w[0])  # worst-served group upweighted
    # temperature 0 returns the base weights untouched (exact)
    agg0 = make_aggregator(AggConfig(name="adaptive", fair_temp=0.0),
                           num_clients=4)
    assert agg0.weigh(state, base, None) is base


def test_adaptive_seeds_ema_and_neutral_weights_for_unseen_clients():
    """First observation seeds the EMA (no decay from the zero init);
    clients not yet sampled sit at the observed mean in weigh(), so
    partial participation never down-weights them by default."""
    agg = make_aggregator(AggConfig(name="adaptive", fair_temp=1.0,
                                    fair_decay=0.9), num_clients=4)
    g = {"w": jnp.zeros((3,))}
    state = agg.init(g)
    # rounds advance the step, then clients 0 and 1 are first observed
    state = state._replace(step=jnp.asarray(5, jnp.int32))
    _, state = agg.apply(state, g, {"w": jnp.zeros((3,))},
                         losses=jnp.array([2.0, 4.0]),
                         idx=jnp.array([0, 1]))
    np.testing.assert_allclose(np.asarray(state.scores["ema"][:2]),
                               [2.0, 4.0])  # seeded, not 0.1*loss
    # unseen clients 2/3 weigh as if at the observed mean (3.0): their
    # effective weight matches a hypothetical client with score 3.0
    w = agg.weigh(state, jnp.full((4,), 0.25), None)
    assert float(w[1]) > float(w[2]) > float(w[0])
    # second observation applies the EMA decay
    _, state = agg.apply(state, g, {"w": jnp.zeros((3,))},
                         losses=jnp.array([3.0]), idx=jnp.array([0]))
    np.testing.assert_allclose(float(state.scores["ema"][0]),
                               0.9 * 2.0 + 0.1 * 3.0, rtol=1e-6)


def test_fedprox_mu_pulls_local_models_toward_anchor():
    data = make_survey_data(SurveyConfig(
        num_groups=4, num_questions=24, d_embed=8, seed=2))
    params = init_gpo_params(GCFG, jax.random.PRNGKey(0))
    drift = {}
    for mu in (0.0, 10.0):
        fcfg = FedConfig(num_clients=4, local_epochs=4, num_context=4,
                         num_target=4, agg=AggConfig(name="fedprox",
                                                     prox_mu=mu))
        opt = adam(fcfg.lr)
        local_train = _make_local_train(GCFG, fcfg, data, opt)
        new_p, _, _ = jax.jit(local_train)(
            params, opt.init(params), jax.random.PRNGKey(1),
            jnp.asarray(0, jnp.int32))
        drift[mu] = float(sum(
            jnp.sum(jnp.square(a - b)) for a, b in
            zip(jax.tree.leaves(new_p), jax.tree.leaves(params))))
    assert drift[10.0] < drift[0.0]


def test_backbone_trainers_reject_client_side_prox():
    """prox_mu only exists in the GPO engine's local objective; the
    backbone/LoRA trainers must fail loudly rather than silently run
    FedAvg under the name fedprox."""
    from repro.configs import get_arch, smoke_variant
    from repro.core import make_backbone_fedavg_round, make_fedlora_round

    cfg = smoke_variant(get_arch("qwen2-0.5b"))
    agg = make_aggregator(AggConfig(name="fedprox", prox_mu=0.1),
                          num_clients=2)
    with pytest.raises(ValueError, match="prox_mu"):
        make_backbone_fedavg_round(cfg, adam(1e-3), 1, agg=agg)
    with pytest.raises(ValueError, match="prox_mu"):
        make_fedlora_round(cfg, {}, adam(1e-3), 1, agg=agg)


def test_median_of_identical_clients_is_identity():
    agg = make_aggregator(AggConfig(name="median"), num_clients=5)
    single = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 3))}
    deltas = broadcast_to_clients(single, 5)
    w = normalize_weights(jnp.arange(1.0, 6.0))
    out = agg.reduce(deltas, w)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(single["w"]), rtol=1e-6)
