"""Fault-tolerant asynchronous federation (DESIGN.md §11).

Contracts:

1. degeneracy — the benign ``AvailabilityConfig()`` default disables the
   fault layer *statically*: both drivers trace the exact pre-fault
   computation, BIT-equal to a default run (the privacy/compression
   degeneracy-pin style), and no fault state exists;
2. determinism — the failure schedule is a pure function of
   (seed, round, client index): same seed ⇒ identical schedules,
   survivor counts, and final parameters across the scan and loop
   drivers (bit-equal) and the sharded engine (float-tolerance, the
   tests/test_sharded_fedavg.py convention);
3. degraded modes — weight renormalization over survivors, trim depths
   that shrink with the realized survivor count, and a zero-survivor
   round that is a verified no-op on params, ``AggState``, and the EF
   residual;
4. lifecycle — straggler buffering (busy while in flight, arrival at
   the due round with the right staleness), crash-rejoin gating, and
   EF21 residual rows frozen for clients whose release was lost;
5. composition — fedbuff(buffer_k=1) at full participation degenerates
   to fedavg bit-for-bit; the RDP accountant's sampling rate reflects
   realized participation (availability ∧ sampling); the sharded
   engine's collective schedule keeps the fault-free byte counts
   (pinned via ``lower_gpo_round`` in a forked-device subprocess).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (
    AggConfig,
    AvailabilityConfig,
    CompressionConfig,
    FedConfig,
    GPOConfig,
    PrivacyConfig,
)
from repro.core import (
    FederatedGPO,
    make_aggregator,
    normalize_weights,
)
from repro.core import availability as av
from repro.core.aggregation import trimmed_mean_reduce_flat
from repro.core.federated import make_sharded_round
from repro.core.gpo import init_gpo_params
from repro.core.fedavg import broadcast_to_clients
from repro.data import SurveyConfig, make_survey_data, split_groups
from repro.optim import adam
from repro.utils.pytree import tree_count_params

GCFG = GPOConfig(d_embed=8, d_model=16, num_layers=1, num_heads=2, d_ff=32)

FAULTY = AvailabilityConfig(online_prob=0.7, crash_prob=0.15,
                            straggler_prob=0.3, max_staleness=3,
                            rejoin_rounds=1)


def _make_fed(avail=AvailabilityConfig(), agg=AggConfig(),
              privacy=PrivacyConfig(), compression=CompressionConfig(
                  kind="none", error_feedback=False),
              batch_groups=0, seed=3, rounds=4):
    data = make_survey_data(SurveyConfig(
        num_groups=6, num_questions=24, d_embed=8, seed=seed))
    tr, ev = split_groups(data, seed=seed)
    fcfg = FedConfig(num_clients=len(tr), rounds=rounds, local_epochs=2,
                     eval_every=2, num_context=4, num_target=4,
                     batch_groups=batch_groups, agg=agg, privacy=privacy,
                     compression=compression, avail=avail, seed=seed)
    return FederatedGPO(GCFG, fcfg, data, tr, ev)


# ---------------------------------------------------------------------------
# schedule unit tests (no training)
# ---------------------------------------------------------------------------
def test_schedule_deterministic_and_disjoint():
    cfg = AvailabilityConfig(online_prob=0.6, crash_prob=0.3,
                             straggler_prob=0.4, max_staleness=4)
    fkey = av.fold_fault_key(jax.random.PRNGKey(42))
    state = av.init_fault_state(64, 3)
    s1 = av.round_schedule(fkey, state, cfg, 64)
    s2 = av.round_schedule(fkey, state, cfg, 64)
    for a, b in zip(s1, s2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    fresh, crashed, strag = (np.asarray(s1.fresh), np.asarray(s1.crashed),
                             np.asarray(s1.straggle))
    avail_ = np.asarray(s1.available)
    # disjoint partition of the available set
    assert not (fresh & crashed).any()
    assert not (fresh & strag).any()
    assert not (crashed & strag).any()
    np.testing.assert_array_equal(fresh | crashed | strag, avail_)
    # the probabilities actually bite at C=64
    assert 0 < avail_.sum() < 64 and crashed.any() and strag.any()
    d = np.asarray(s1.delay)
    assert (d >= 1).all() and (d <= 4).all()
    # a different round key reshuffles the schedule
    s3 = av.round_schedule(av.fold_fault_key(jax.random.PRNGKey(43)),
                           state, cfg, 64)
    assert (np.asarray(s3.available) != avail_).any()


def test_straggler_buffer_lifecycle():
    """Send → busy while in flight → arrive with the right staleness →
    slot cleared."""
    cfg = AvailabilityConfig(straggler_prob=0.5, max_staleness=4)
    C, P = 3, 2
    state = av.init_fault_state(C, P)
    t = jnp.array([True, False, False])
    f = jnp.zeros((C,), bool)
    sched = av.RoundSchedule(
        available=t, fresh=~t, crashed=f, straggle=t, arrive=f,
        delay=jnp.full((C,), 2, jnp.int32), staleness=jnp.zeros((C,),
                                                               jnp.int32))
    sent = jnp.arange(C * P, dtype=jnp.float32).reshape(C, P)
    w = jnp.array([0.5, 0.25, 0.25])
    state = av.advance_fault_state(state, sched, sent, w)
    assert int(state.round) == 1
    np.testing.assert_array_equal(np.asarray(state.pending[0]),
                                  np.asarray(sent[0]))
    assert int(state.pending_due[0]) == 2  # sent at r=0, delay 2
    assert float(state.pending_weight[0]) == 0.5
    assert int(state.pending_birth[0]) == 0
    assert int(state.pending_due[1]) == int(av.NO_PENDING)

    # r=1: in flight — busy (not available), not arriving
    fkey = av.fold_fault_key(jax.random.PRNGKey(0))
    s1 = av.round_schedule(fkey, state, cfg, C)
    assert not bool(s1.available[0]) and not bool(s1.arrive[0])

    # r=2: the upload lands, two rounds stale
    state2 = state._replace(round=jnp.asarray(2, jnp.int32))
    s2 = av.round_schedule(fkey, state2, cfg, C)
    assert bool(s2.arrive[0]) and int(s2.staleness[0]) == 2
    state3 = av.advance_fault_state(state2, s2, jnp.zeros((C, P)),
                                    jnp.zeros((C,)))
    assert int(state3.pending_due[0]) == int(av.NO_PENDING)
    assert not np.asarray(state3.pending[0]).any()
    assert float(state3.pending_weight[0]) == 0.0


def test_crash_rejoin_gate():
    cfg = AvailabilityConfig(crash_prob=0.5, rejoin_rounds=2)
    C = 2
    state = av.init_fault_state(C, 1)
    t = jnp.array([True, False])
    f = jnp.zeros((C,), bool)
    z = jnp.zeros((C,), jnp.int32)
    sched = av.RoundSchedule(available=t, fresh=f, crashed=t, straggle=f,
                             arrive=f, delay=z + 1, staleness=z)
    state = av.advance_fault_state(state, sched, jnp.zeros((C, 1)),
                                   jnp.zeros((C,)), cfg.rejoin_rounds)
    # crashed at r=0 with 2 extra rounds offline: back at r=3
    assert int(state.offline_until[0]) == 3
    benign = AvailabilityConfig(online_prob=1.0, crash_prob=0.0)
    fkey = av.fold_fault_key(jax.random.PRNGKey(1))
    for r, avail_expected in ((1, False), (2, False), (3, True)):
        s = av.round_schedule(
            fkey, state._replace(round=jnp.asarray(r, jnp.int32)),
            benign, C)
        assert bool(s.available[0]) == avail_expected


def test_staleness_discount():
    tau = jnp.array([0, 1, 3], jnp.int32)
    np.testing.assert_allclose(
        np.asarray(av.staleness_discount(tau, 0.5)),
        [1.0, 1.0 / np.sqrt(2.0), 0.5], rtol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(av.staleness_discount(tau, 0.0)), np.ones(3))


def test_masked_mean_weights():
    w = jnp.array([1.0, 2.0, 3.0, 4.0])
    m = jnp.array([True, False, True, False])
    np.testing.assert_allclose(np.asarray(av.masked_mean_weights(w, m)),
                               [0.25, 0.0, 0.75, 0.0], rtol=1e-6)
    zero = av.masked_mean_weights(w, jnp.zeros((4,), bool))
    np.testing.assert_array_equal(np.asarray(zero), np.zeros(4))


def test_normalize_weights_all_zero_sizes_is_finite():
    """Regression: an all-zero size vector — every sampled client lost
    its data, the empty-survivor edge the availability simulator can
    produce — once divided by zero in ``normalize_weights``. The clamped
    denominator returns all-zero weights (a no-op round), and any real
    population is bit-unaffected by the clamp."""
    from repro.core import normalize_weights

    w = normalize_weights(jnp.zeros((4,)))
    assert np.isfinite(np.asarray(w)).all()
    np.testing.assert_array_equal(np.asarray(w), np.zeros(4))
    w2 = normalize_weights(jnp.array([1.0, 3.0]))
    np.testing.assert_allclose(np.asarray(w2), [0.25, 0.75], rtol=1e-6)


@pytest.mark.parametrize("name,frac", [("median", 0.0),
                                       ("trimmed_mean", 0.25)])
def test_masked_robust_reduce_matches_dense_on_survivors(name, frac):
    """The masked rank-trim with a traced survivor count must equal the
    static-C reduce run on the compacted surviving rows."""
    rng = np.random.default_rng(0)
    vecs = jnp.asarray(rng.normal(size=(6, 5)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.5, 1.5, size=6).astype(np.float32))
    mask = jnp.array([True, False, True, True, False, True])
    got = av.masked_robust_reduce_flat(vecs, w, mask, name=name,
                                       trim_frac=frac)
    n = int(mask.sum())
    k = (n - 1) // 2 if name == "median" else min(int(frac * n),
                                                 (n - 1) // 2)
    want = trimmed_mean_reduce_flat(vecs[mask], w[mask], k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


def test_masked_robust_reduce_zero_survivors_is_zero():
    vecs = jnp.ones((4, 3))
    out = av.masked_robust_reduce_flat(vecs, jnp.ones((4,)),
                                       jnp.zeros((4,), bool), name="median")
    np.testing.assert_array_equal(np.asarray(out), np.zeros(3))


def test_availability_config_validation():
    with pytest.raises(ValueError, match="online_prob"):
        AvailabilityConfig(online_prob=1.5).validate()
    with pytest.raises(ValueError, match="max_staleness >= 1"):
        AvailabilityConfig(straggler_prob=0.2).validate()
    FAULTY.validate()  # the canonical faulty config is well-formed


# ---------------------------------------------------------------------------
# degeneracy pin: the disabled default is bit-equal (both drivers)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ["scan", "loop"])
def test_disabled_faults_is_bit_equal(engine):
    """A benign AvailabilityConfig must not perturb a single bit of the
    default run — the fault layer is statically traced out, and the
    inert knobs (max_staleness, rejoin_rounds) change nothing while
    every probability stays benign."""
    fed_ref = _make_fed()
    hist_ref = fed_ref.run(rounds=3, engine=engine)
    benign = AvailabilityConfig(online_prob=1.0, crash_prob=0.0,
                                straggler_prob=0.0, max_staleness=4,
                                rejoin_rounds=2)
    assert not benign.enabled
    fed = _make_fed(avail=benign)
    hist = fed.run(rounds=3, engine=engine)
    assert hist_ref.round_loss == hist.round_loss  # floats, bit-for-bit
    np.testing.assert_array_equal(np.stack(hist_ref.eval_scores),
                                  np.stack(hist.eval_scores))
    for a, b in zip(jax.tree.leaves(fed_ref.global_params),
                    jax.tree.leaves(fed.global_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert fed.fault_state is None  # no fault state exists when disabled
    assert hist.round_survivors == []


# ---------------------------------------------------------------------------
# deterministic replay across engines
# ---------------------------------------------------------------------------
def test_fault_replay_bit_equal_across_drivers():
    """Same seed ⇒ the same failure schedule, survivor counts, losses,
    parameters, and carried fault state in the scan and loop drivers."""
    runs = {}
    for engine in ("scan", "loop"):
        fed = _make_fed(avail=FAULTY, seed=7)
        hist = fed.run(rounds=6, engine=engine)
        runs[engine] = (fed, hist)
    fed_s, hist_s = runs["scan"]
    fed_l, hist_l = runs["loop"]
    assert hist_s.round_survivors == hist_l.round_survivors
    assert len(hist_s.round_survivors) == 6
    assert hist_s.round_loss == hist_l.round_loss  # bit-for-bit
    for a, b in zip(jax.tree.leaves(fed_s.global_params),
                    jax.tree.leaves(fed_l.global_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(fed_s.fault_state, fed_l.fault_state):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # faults actually fired for this seed (the run is a real fault trace)
    assert min(hist_s.round_survivors) < len(fed_s.train_groups)


def test_fault_replay_with_subsampling_privacy_and_compression():
    """The full stack composes: subsampled cohorts, DP release, int8+EF
    transport, and the failure schedule all replay bit-identically."""
    kw = dict(avail=FAULTY, batch_groups=4, seed=9,
              privacy=PrivacyConfig(clip_norm=1.0, noise_multiplier=0.3),
              compression=CompressionConfig(kind="int8"))
    fed_a = _make_fed(**kw)
    hist_a = fed_a.run(rounds=5, engine="scan")
    fed_b = _make_fed(**kw)
    hist_b = fed_b.run(rounds=5, engine="loop")
    assert hist_a.round_loss == hist_b.round_loss
    assert hist_a.round_survivors == hist_b.round_survivors
    np.testing.assert_array_equal(np.asarray(fed_a.ef_resid),
                                  np.asarray(fed_b.ef_resid))
    for a, b in zip(jax.tree.leaves(fed_a.global_params),
                    jax.tree.leaves(fed_b.global_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# degraded modes
# ---------------------------------------------------------------------------
def test_zero_survivor_rounds_are_noop():
    """online_prob=0: every round has zero survivors and must leave the
    params, the AggState, and the EF residual bit-untouched."""
    avail = AvailabilityConfig(online_prob=0.0)
    fed = _make_fed(avail=avail, agg=AggConfig(name="fedavgm"),
                    compression=CompressionConfig(kind="int8"))
    params0 = [np.array(x) for x in jax.tree.leaves(fed.global_params)]
    srv0 = [np.array(x) for x in jax.tree.leaves(fed.server_state)]
    resid0 = np.array(fed.ef_resid)
    hist = fed.run(rounds=3, engine="scan")
    assert hist.round_survivors == [0, 0, 0]
    for a, b in zip(params0, jax.tree.leaves(fed.global_params)):
        np.testing.assert_array_equal(a, np.asarray(b))
    for a, b in zip(srv0, jax.tree.leaves(fed.server_state)):
        np.testing.assert_array_equal(a, np.asarray(b))
    np.testing.assert_array_equal(resid0, np.asarray(fed.ef_resid))


@pytest.mark.parametrize("name", ["trimmed_mean", "median", "fedbuff"])
def test_faulty_runs_stay_finite_per_strategy(name):
    """Robust and buffered strategies run under heavy faults without
    NaNs and still make progress on the surviving updates."""
    agg = AggConfig(name=name, trim_frac=0.2, buffer_k=2)
    fed = _make_fed(avail=FAULTY, agg=agg, seed=5)
    hist = fed.run(rounds=6, engine="scan")
    assert np.isfinite(np.asarray(hist.round_loss)).all()
    assert all(np.isfinite(s).all() for s in hist.eval_scores)
    assert max(hist.round_survivors) > 0


# ---------------------------------------------------------------------------
# EF-freeze: lost clients' residual rows do not advance
# ---------------------------------------------------------------------------
def test_ef_residual_frozen_for_lost_clients():
    avail = AvailabilityConfig(online_prob=0.8, crash_prob=0.4)
    fed = _make_fed(avail=avail, seed=3,
                    compression=CompressionConfig(kind="int8"))
    fed.run(rounds=1, engine="loop")
    # host replay of the round's schedule (same key chain as the driver)
    key = jax.random.PRNGKey(fed.fed_cfg.seed + 1)
    _, k_round, _ = jax.random.split(key, 3)
    fkey = av.fold_fault_key(k_round)
    C = len(fed.train_groups)
    sched = av.round_schedule(
        fkey, av.init_fault_state(C, 1), avail, C)
    keep = np.asarray(sched.fresh | sched.straggle)
    assert keep.any() and not keep.all()  # both cases occur at seed 3
    resid = np.asarray(fed.ef_resid)
    row_active = np.abs(resid).max(axis=1) > 0
    # releasing clients accumulated quantization error; lost clients'
    # rows are exactly the zeros they started from
    np.testing.assert_array_equal(row_active, keep)


# ---------------------------------------------------------------------------
# fedbuff degeneracy + accountant composition
# ---------------------------------------------------------------------------
def test_fedbuff_bufferk1_full_participation_is_fedavg():
    fed_avg = _make_fed(agg=AggConfig(name="fedavg"))
    h_avg = fed_avg.run(rounds=4, engine="scan")
    fed_buf = _make_fed(agg=AggConfig(name="fedbuff", buffer_k=1))
    h_buf = fed_buf.run(rounds=4, engine="scan")
    assert h_avg.round_loss == h_buf.round_loss  # bit-for-bit
    for a, b in zip(jax.tree.leaves(fed_avg.global_params),
                    jax.tree.leaves(fed_buf.global_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_accountant_uses_realized_participation():
    assert AvailabilityConfig(online_prob=0.8,
                              crash_prob=0.25).release_rate() == 0.8 * 0.75
    assert AvailabilityConfig().release_rate() == 1.0
    priv = PrivacyConfig(clip_norm=1.0, noise_multiplier=0.8)
    fed_full = _make_fed(privacy=priv, batch_groups=4)
    fed_faulty = _make_fed(privacy=priv, batch_groups=4, avail=FAULTY)
    q_full = fed_full._accountant.sampling_rate
    q_faulty = fed_faulty._accountant.sampling_rate
    np.testing.assert_allclose(q_faulty,
                               q_full * FAULTY.release_rate(), rtol=1e-12)
    # fewer realized releases ⇒ a strictly smaller epsilon
    assert fed_faulty._accountant.epsilon(100) \
        < fed_full._accountant.epsilon(100)


# ---------------------------------------------------------------------------
# sharded engine: same failure trace, same collective schedule
# ---------------------------------------------------------------------------
def test_sharded_fault_round_matches_stacked_engine():
    """Driving make_sharded_round (1-device 'data' mesh) with the loop
    driver's key chain must replay the exact failure schedule and land
    on the same parameters and fault state (float tolerance — the
    tests/test_sharded_fedavg.py convention for separately-compiled
    programs)."""
    C = 4
    data = make_survey_data(SurveyConfig(
        num_groups=C + 1, num_questions=24, d_embed=8, seed=0))
    tr = jnp.arange(C, dtype=jnp.int32)
    ev = jnp.arange(C, C + 1, dtype=jnp.int32)
    fcfg = FedConfig(num_clients=C, rounds=3, local_epochs=2,
                     num_context=4, num_target=4, eval_every=100,
                     avail=FAULTY, seed=11)
    fed = FederatedGPO(GCFG, fcfg, data, tr, ev)
    hist = fed.run(rounds=3, engine="loop")

    mesh = jax.make_mesh((1,), ("data",))
    round_fn = jax.jit(make_sharded_round(GCFG, fcfg, data, mesh,
                                          opt=adam(fcfg.lr)))
    agg = make_aggregator(fcfg.agg, num_clients=C)
    params = init_gpo_params(GCFG, jax.random.PRNGKey(fcfg.seed))
    cp = broadcast_to_clients(params, C)
    opt_states = jax.vmap(adam(fcfg.lr).init)(cp)
    srv = agg.init(params)
    fault = av.init_fault_state(C, tree_count_params(params))
    weights = normalize_weights(data.sizes[tr])
    key = jax.random.PRNGKey(fcfg.seed + 1)
    for _ in range(3):
        key, k_round, _ = jax.random.split(key, 3)
        _, k_train = jax.random.split(k_round)
        keys = jax.random.split(k_train, C)
        fkey = av.fold_fault_key(k_round)
        cp, opt_states, _, srv, fault = round_fn(
            cp, opt_states, keys, tr, weights, srv, fault, fkey)
    # identical integer fault trace, same params to float tolerance
    for a, b in zip(fed.fault_state, fault):
        if np.asarray(a).dtype.kind == "i":
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(fed.global_params),
                    jax.tree.leaves(cp)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b)[0],
                                   rtol=1e-5, atol=1e-6)
    assert min(hist.round_survivors) < C  # the trace exercised faults


@pytest.mark.slow
def test_sharded_fault_round_keeps_collective_bytes():
    """Masking survivors must not change the wire: the fault-aware
    linear round compiles to the SAME single parameter-sized all-reduce
    (byte-identical) as the fault-free round. Runs in a subprocess — the
    8-device host-platform override is process-global."""
    code = """
import json
from repro.launch.dryrun import lower_gpo_round
out = {}
for faults in (False, True):
    r = lower_gpo_round("fedavg", clients=8, faults=faults, verbose=False)
    out[str(faults)] = r["collective_bytes_by_kind"]
print(json.dumps(out))
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + sys.path))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["True"] == out["False"]
    assert out["True"].get("all-reduce", 0) > 0
