"""Checkpoint roundtrip tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_checkpoint, restore_checkpoint, save_checkpoint
from repro.optim import adam


def test_roundtrip(tmp_path, rng):
    tree = {"layer": {"w": jax.random.normal(rng, (4, 3)),
                      "b": jnp.zeros((3,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}
    path = save_checkpoint(str(tmp_path), 7, tree, metadata={"loss": 1.0})
    restored = restore_checkpoint(path, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest(tmp_path, rng):
    tree = {"w": jnp.ones((2,))}
    save_checkpoint(str(tmp_path), 3, tree)
    save_checkpoint(str(tmp_path), 12, tree)
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_checkpoint(str(tmp_path)).endswith("ckpt_00000012.npz")
    assert latest_checkpoint(str(tmp_path / "missing")) is None


def test_shape_mismatch_raises(tmp_path, rng):
    path = save_checkpoint(str(tmp_path), 0, {"w": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(path, {"w": jnp.zeros((3, 3))})
    with pytest.raises(KeyError):
        restore_checkpoint(path, {"other": jnp.zeros((2, 2))})


def test_torn_write_leaves_previous_checkpoint(tmp_path, rng, monkeypatch):
    """A crash mid-save (simulated by failing the final rename) must
    leave the previous checkpoint fully restorable and never expose a
    torn .npz under the ckpt_* name."""
    import os

    tree = {"w": jnp.arange(6.0).reshape(2, 3)}
    good = save_checkpoint(str(tmp_path), 1, tree)

    real_replace = os.replace

    def torn_replace(src, dst):
        if dst.endswith(".npz"):
            raise OSError("simulated crash before rename")
        return real_replace(src, dst)

    monkeypatch.setattr(os, "replace", torn_replace)
    with pytest.raises(OSError):
        save_checkpoint(str(tmp_path), 2, {"w": jnp.full((2, 3), 9.0)})
    monkeypatch.undo()

    # the failed step-2 save left no ckpt_*.npz and no stray tmp files
    assert latest_checkpoint(str(tmp_path)) == good
    assert not [f for f in os.listdir(str(tmp_path)) if f.endswith(".tmp")]
    restored = restore_checkpoint(good, {"w": jnp.zeros((2, 3))})
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(6.0).reshape(2, 3))


def test_torn_write_mid_serialize(tmp_path, rng, monkeypatch):
    """Crash DURING serialization (fsync fails before the rename): the
    half-written temp bytes must never land under the final name, and a
    re-save after 'restart' wins cleanly."""
    import os

    tree = {"w": jnp.ones((3,))}
    save_checkpoint(str(tmp_path), 5, tree)

    def boom(fd):
        raise OSError("simulated disk-full during fsync")

    monkeypatch.setattr(os, "fsync", boom)
    with pytest.raises(OSError):
        save_checkpoint(str(tmp_path), 6, {"w": jnp.full((3,), 2.0)})
    monkeypatch.undo()

    latest = latest_checkpoint(str(tmp_path))
    assert latest.endswith("ckpt_00000005.npz")
    # restart: the same step-6 save now succeeds and becomes latest
    save_checkpoint(str(tmp_path), 6, {"w": jnp.full((3,), 2.0)})
    restored = restore_checkpoint(latest_checkpoint(str(tmp_path)),
                                  {"w": jnp.zeros((3,))})
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.full((3,), 2.0, np.float32))


def test_flipped_byte_fails_checksum(tmp_path, rng):
    """Silent bit rot after a durable save: one flipped byte in the
    stored payload must fail the CRC32 content check with ValueError
    (the type launch/serve.py's --restore path already converts to an
    actionable SystemExit) — never restore corrupted weights."""
    import os

    tree = {"w": jax.random.normal(rng, (16, 16)),
            "b": jnp.arange(8.0)}
    path = save_checkpoint(str(tmp_path), 1, tree)
    like = jax.tree.map(jnp.zeros_like, tree)
    restore_checkpoint(path, like)  # pristine file passes

    raw = bytearray(open(path, "rb").read())
    # flip a byte inside the stored array payload (zip local headers sit
    # at the front; the middle of the file is leaf bytes for this size)
    raw[len(raw) // 2] ^= 0xFF
    bad = str(tmp_path / "ckpt_corrupt.npz")
    with open(bad, "wb") as f:
        f.write(bytes(raw))
    with pytest.raises(ValueError):
        restore_checkpoint(bad, like)

    # truncation (a partial copy) must also surface as ValueError, not a
    # leaked zipfile.BadZipFile
    trunc = str(tmp_path / "ckpt_trunc.npz")
    with open(trunc, "wb") as f:
        f.write(bytes(raw[: len(raw) // 3]))
    with pytest.raises((ValueError, KeyError)):
        restore_checkpoint(trunc, like)
    assert os.path.exists(path)


def test_pre_checksum_checkpoint_still_loads(tmp_path, rng):
    """Checkpoints written before the __crc32__ entry existed (or by
    other tools) must keep loading — the checksum is verified only when
    present."""
    tree = {"w": jnp.full((3, 3), 2.0)}
    legacy = str(tmp_path / "ckpt_00000001.npz")
    np.savez(legacy, **{"['w']": np.full((3, 3), 2.0, np.float32)})
    restored = restore_checkpoint(legacy, {"w": jnp.zeros((3, 3))})
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_optimizer_state_roundtrip(tmp_path, rng):
    params = {"w": jax.random.normal(rng, (5, 5))}
    opt = adam(1e-3)
    state = opt.init(params)
    grads = jax.tree.map(jnp.ones_like, params)
    params2, state2 = opt.update(grads, state, params)
    path = save_checkpoint(str(tmp_path), 1,
                           {"params": params2, "opt": state2})
    like = {"params": jax.tree.map(jnp.zeros_like, params2),
            "opt": opt.init(params)}
    restored = restore_checkpoint(path, like)
    np.testing.assert_array_equal(np.asarray(restored["opt"].mu["w"]),
                                  np.asarray(state2.mu["w"]))
