"""The compressed client-delta transport (DESIGN.md §10).

Five contracts:

1. degeneracy — ``CompressionConfig(kind="none")`` disables the stage
   and every engine (scan / loop / sharded) traces the exact
   pre-compression computation: histories and parameters are BIT-equal
   to a default run;
2. codec semantics — int8 quantization round-trips within one level,
   scales bound the error, top-k keeps at least k entries with disjoint
   residual support, and error feedback carries exactly the codec error;
3. kernel oracle — the fused ``agg_quant_clip_reduce`` and
   ``agg_topk_reduce`` kernels match the explicit ``ref.py`` formulas
   across ragged client counts, non-uniform weights, clip/noise/EF
   combinations, and interpret modes;
4. engine equivalence — scan == loop == sharded per compression mode ×
   aggregator strategy, the Pallas transport matches the jnp transport,
   and composition with the §9 privacy pipeline leaves ε accounting
   untouched;
5. trainers + config — backbone/LoRA rounds grow the documented
   resid/key signature, compression without an aggregator is rejected,
   and bad configs fail validation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (
    AggConfig,
    CompressionConfig,
    FedConfig,
    GPOConfig,
    PrivacyConfig,
)
from repro.core import (
    FederatedGPO,
    broadcast_to_clients,
    client_uniform,
    dequantize_int8,
    make_aggregator,
    normalize_weights,
    quantize_int8,
    sparsify_topk,
    topk_thresholds,
    transport_delta_flat,
)
from repro.core import compression as cx
from repro.core.federated import _make_local_train, make_sharded_round
from repro.core.gpo import init_gpo_params
from repro.data import SurveyConfig, make_survey_data, split_groups
from repro.kernels import agg_quant_clip_reduce, agg_topk_reduce
from repro.kernels.ref import ref_quant_clip_reduce, ref_topk_reduce
from repro.optim import adam
from repro.utils.pytree import (
    tree_count_params,
    tree_ravel_clients,
    tree_sub,
    tree_unflatten_from_vector,
)

GCFG = GPOConfig(d_embed=8, d_model=16, num_layers=1, num_heads=2, d_ff=32)
# single-Pallas-block model (P <= 2048): the kernel's blockwise norm /
# absmax accumulation is then the same single reduction as the jnp path,
# so quantization decisions cannot flip on float reassociation at a
# rounding boundary — the pallas==jnp engine tests rely on this.
GCFG_SMALL = GPOConfig(d_embed=8, d_model=8, num_layers=1, num_heads=2,
                       d_ff=16)

INT8 = CompressionConfig(kind="int8")
TOPK = CompressionConfig(kind="topk", topk_frac=0.05)


def _make_fed(comp=CompressionConfig(), priv=PrivacyConfig(),
              agg=AggConfig(), use_pallas=False, batch_groups=0, seed=3,
              gcfg=GCFG):
    data = make_survey_data(SurveyConfig(
        num_groups=6, num_questions=24, d_embed=8, seed=seed))
    tr, ev = split_groups(data, seed=seed)
    fcfg = FedConfig(num_clients=len(tr), rounds=3, local_epochs=2,
                     eval_every=2, num_context=4, num_target=4,
                     batch_groups=batch_groups, agg=agg,
                     use_pallas_aggregation=use_pallas, privacy=priv,
                     compression=comp, seed=seed)
    return FederatedGPO(gcfg, fcfg, data, tr, ev)


# ---------------------------------------------------------------------------
# 1. degeneracy: kind == "none" is the exact pre-compression trace
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ["scan", "loop"])
def test_disabled_compression_is_bit_equal(engine):
    """kind='none' must not perturb a single bit of the default run —
    the stage is statically traced out, and toggling EF while disabled
    changes nothing either."""
    fed_ref = _make_fed()
    hist_ref = fed_ref.run(rounds=3, engine=engine)
    fed = _make_fed(CompressionConfig(kind="none", error_feedback=False))
    hist = fed.run(rounds=3, engine=engine)
    assert hist_ref.round_loss == hist.round_loss  # bit-for-bit
    np.testing.assert_array_equal(np.stack(hist_ref.eval_scores),
                                  np.stack(hist.eval_scores))
    for a, b in zip(jax.tree.leaves(fed_ref.global_params),
                    jax.tree.leaves(fed.global_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert fed.ef_resid is None  # no residual state exists when disabled


def test_disabled_compression_is_bit_equal_in_sharded_round():
    C = 4
    data = make_survey_data(SurveyConfig(
        num_groups=C, num_questions=24, d_embed=8, seed=0))
    opt = adam(1e-3)
    params = init_gpo_params(GCFG, jax.random.PRNGKey(0))
    groups = jnp.arange(C, dtype=jnp.int32)
    weights = normalize_weights(data.sizes[groups])
    keys = jax.random.split(jax.random.PRNGKey(1), C)
    cp = broadcast_to_clients(params, C)
    opt_states = jax.vmap(opt.init)(cp)
    mesh = jax.make_mesh((1,), ("data",))
    outs = []
    for comp in (CompressionConfig(),
                 CompressionConfig(kind="none", error_feedback=False)):
        fcfg = FedConfig(num_clients=C, local_epochs=2, lr=1e-3,
                         num_context=4, num_target=4, compression=comp)
        agg = make_aggregator(fcfg.agg, num_clients=C)
        round_fn = make_sharded_round(GCFG, fcfg, data, mesh, opt=opt,
                                      agg=agg)
        out = jax.jit(round_fn)(cp, opt_states, keys, groups, weights,
                                agg.init(params))
        assert len(out) == 4  # disabled => seed signature, no resid slot
        outs.append(out)
    for a, b in zip(jax.tree.leaves(outs[0][0]),
                    jax.tree.leaves(outs[1][0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_compression_config_validation():
    with pytest.raises(ValueError, match="kind"):
        CompressionConfig(kind="int4").validate()
    with pytest.raises(ValueError, match="topk_frac"):
        CompressionConfig(kind="topk", topk_frac=0.0).validate()
    with pytest.raises(ValueError, match="topk_frac"):
        CompressionConfig(kind="topk", topk_frac=1.5).validate()
    CompressionConfig(kind="topk", topk_frac=1.0).validate()  # boundary ok
    assert not CompressionConfig().enabled
    assert CompressionConfig(kind="int8").needs_rng
    assert not CompressionConfig(kind="int8", stochastic=False).needs_rng
    assert not CompressionConfig(kind="topk").needs_rng


# ---------------------------------------------------------------------------
# 2. codec semantics
# ---------------------------------------------------------------------------
def test_int8_roundtrip_error_bounded_by_scale():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (5, 300)) * jnp.asarray(
        [[0.01], [1.0], [100.0], [1e-6], [3.0]])
    q, s = quantize_int8(x)  # round-to-nearest
    assert q.dtype == jnp.int8
    t = dequantize_int8(q, s)
    err = np.max(np.abs(np.asarray(t - x)), axis=1)
    # nearest rounding: |error| <= s/2 per element (plus fp slack)
    assert np.all(err <= np.asarray(s) * 0.5 * (1 + 1e-4))
    # stochastic rounding: |error| < s
    keys = jax.random.split(key, 5)
    u = client_uniform(keys, x.shape)
    q2, s2 = quantize_int8(x, uniform=u)
    err2 = np.max(np.abs(np.asarray(dequantize_int8(q2, s2) - x)), axis=1)
    assert np.all(err2 <= np.asarray(s2) * (1 + 1e-4))


def test_int8_zero_row_stays_zero():
    x = jnp.zeros((2, 64)).at[1].set(1.0)
    q, s = quantize_int8(x)
    np.testing.assert_array_equal(np.asarray(q[0]), np.zeros(64))
    np.testing.assert_array_equal(
        np.asarray(dequantize_int8(q, s)[0]), np.zeros(64))


def test_topk_keeps_at_least_k_with_disjoint_residual():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (4, 200))
    frac = 0.1
    t, tau = sparsify_topk(x, frac)
    k = cx.topk_count(200, frac)
    kept = np.asarray(jnp.sum(t != 0.0, axis=1))
    assert np.all(kept >= k)
    # kept entries are exactly the top magnitudes; residual support is
    # disjoint from the transmitted support
    r = np.asarray(x - t)
    assert np.all(np.asarray(t) * r == 0.0)
    np.testing.assert_array_equal(
        np.asarray(tau), np.sort(np.abs(np.asarray(x)), axis=1)[:, -k])


def test_error_feedback_residual_is_exact_codec_error():
    key = jax.random.PRNGKey(2)
    vecs = jax.random.normal(key, (3, 128))
    resid = 0.3 * jax.random.normal(jax.random.fold_in(key, 1), (3, 128))
    keys = jax.random.split(jax.random.fold_in(key, 2), 3)
    for comp in (INT8, TOPK):
        t, new_r = cx.ef_compress_flat(vecs, keys, comp, resid)
        np.testing.assert_allclose(np.asarray(t + new_r),
                                   np.asarray(vecs + resid),
                                   rtol=1e-5, atol=1e-6)
        # determinism: same inputs -> same transmitted values + residual
        t2, new_r2 = cx.ef_compress_flat(vecs, keys, comp, resid)
        np.testing.assert_array_equal(np.asarray(t), np.asarray(t2))
        np.testing.assert_array_equal(np.asarray(new_r), np.asarray(new_r2))


# ---------------------------------------------------------------------------
# 3. kernel == oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("c,p", [(2, 100), (5, 1000), (9, 2048),
                                 (16, 4097)])
@pytest.mark.parametrize("variant", ["plain", "clip", "clip_noise_ef",
                                     "ef_stochastic"])
def test_quant_clip_reduce_kernel_matches_ref(c, p, variant):
    """Fused kernel vs the explicit formula across ragged client counts,
    non-uniform weights, and every operand combination. Multi-block
    shapes (p > 2048) use a level-sized tolerance: blockwise norm/absmax
    accumulation may differ from the oracle's one-shot reduction by a
    ulp, which can legally flip a rounding decision by one level."""
    key = jax.random.PRNGKey(5)
    stacked = jax.random.normal(key, (c, p)) * 3.0
    stacked = stacked.at[::2].mul(10.0)
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (c,)))
    keys = jax.random.split(jax.random.fold_in(key, 2), c)
    clip = float(jnp.median(jnp.linalg.norm(stacked, axis=1)))
    noise = 0.3 * jax.random.normal(jax.random.fold_in(key, 3), (c, p))
    resid = 0.5 * jax.random.normal(jax.random.fold_in(key, 4), (c, p))
    uniform = client_uniform(keys, (c, p))
    kw = {
        "plain": dict(),
        "clip": dict(clip=clip),
        "clip_noise_ef": dict(clip=clip, noise=noise, resid=resid),
        "ef_stochastic": dict(uniform=uniform, resid=resid),
    }[variant]
    out, er = agg_quant_clip_reduce(stacked, w, **kw)
    ref_out, ref_er = ref_quant_clip_reduce(stacked, w, **kw)
    # one flipped level moves one coordinate by w_c * s_c at most
    s_max = float(jnp.max(jnp.abs(stacked)) / 127.0)
    tol = dict(rtol=2e-5, atol=2e-5 + (s_max if p > 2048 else 0.0))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out), **tol)
    if er is not None:
        np.testing.assert_allclose(np.asarray(er), np.asarray(ref_er),
                                   **tol)


@pytest.mark.parametrize("interpret", [True, None])
def test_quant_clip_reduce_interpret_modes(interpret):
    """Explicit interpret=True and the backend default agree (on CPU the
    default IS interpret; on TPU this pins native == interpret)."""
    key = jax.random.PRNGKey(6)
    stacked = jax.random.normal(key, (5, 300)) * 4.0
    w = jnp.full((5,), 0.2)
    out, _ = agg_quant_clip_reduce(stacked, w, clip=1.0,
                                   interpret=interpret)
    ref, _ = ref_quant_clip_reduce(stacked, w, clip=1.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_quant_kernel_rejects_noise_without_clip():
    stacked = jnp.ones((3, 8))
    w = jnp.full((3,), 1.0 / 3)
    with pytest.raises(ValueError, match="clip"):
        agg_quant_clip_reduce(stacked, w, noise=jnp.zeros((3, 8)))


@pytest.mark.parametrize("c,p,frac", [(2, 100, 0.5), (5, 1000, 0.01),
                                      (9, 4097, 0.1)])
@pytest.mark.parametrize("with_residual", [False, True])
def test_topk_kernel_matches_ref(c, p, frac, with_residual):
    key = jax.random.PRNGKey(7)
    stacked = jax.random.normal(key, (c, p)) * 2.0
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (c,)))
    tau = topk_thresholds(stacked, frac)
    out, er = agg_topk_reduce(stacked, w, tau, with_residual=with_residual)
    ref_out, ref_er = ref_topk_reduce(stacked, w, frac=frac)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=2e-5, atol=2e-5)
    if with_residual:
        np.testing.assert_allclose(np.asarray(er), np.asarray(ref_er),
                                   rtol=2e-5, atol=2e-5)
    else:
        assert er is None


def test_topk_kernel_handles_zero_rows():
    """An all-zero client has threshold 0; every (zero) entry 'survives'
    with value 0 and the padded columns never perturb the reduce."""
    stacked = jnp.zeros((3, 130)).at[1].set(
        jax.random.normal(jax.random.PRNGKey(8), (130,)))
    w = jnp.full((3,), 1.0 / 3)
    tau = topk_thresholds(stacked, 0.1)
    out, er = agg_topk_reduce(stacked, w, tau, with_residual=True)
    ref_out, ref_er = ref_topk_reduce(stacked, w, frac=0.1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(er), np.asarray(ref_er),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# 4. engine equivalence per compression mode × aggregator strategy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("comp", [INT8, TOPK], ids=["int8", "topk"])
@pytest.mark.parametrize("name", ["fedavg", "fedavgm", "median",
                                  "adaptive"])
def test_scan_matches_loop_per_mode_and_strategy(comp, name):
    """Both drivers derive per-round (and hence per-client rounding)
    keys identically, so compressed runs agree to float tolerance for
    every codec × strategy combination."""
    fed_scan = _make_fed(comp, agg=AggConfig(name=name))
    hist_scan = fed_scan.run(rounds=3, engine="scan")
    fed_loop = _make_fed(comp, agg=AggConfig(name=name))
    hist_loop = fed_loop.run(rounds=3, engine="loop")
    np.testing.assert_allclose(hist_scan.round_loss, hist_loop.round_loss,
                               rtol=1e-3, atol=1e-5)
    for a, b in zip(jax.tree.leaves(fed_scan.global_params),
                    jax.tree.leaves(fed_loop.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(np.asarray(fed_scan.ef_resid),
                               np.asarray(fed_loop.ef_resid),
                               rtol=1e-3, atol=1e-5)


@pytest.mark.parametrize("comp", [INT8, TOPK], ids=["int8", "topk"])
@pytest.mark.parametrize("name", ["fedavg", "median"])
@pytest.mark.parametrize("private", [False, True])
def test_sharded_compressed_round_matches_stacked(comp, name, private):
    """make_sharded_round under compression == the stacked reference
    with the same per-client keys: the codec (and any DP release) runs
    shard-locally before the collective, and rounding uniforms fold
    out of the shared keys, so the transmitted values are identical by
    construction."""
    C = 5
    priv = (PrivacyConfig(clip_norm=0.3, noise_multiplier=0.8) if private
            else PrivacyConfig())
    data = make_survey_data(SurveyConfig(
        num_groups=C, num_questions=24, d_embed=8, seed=0))
    fcfg = FedConfig(num_clients=C, local_epochs=2, lr=1e-3,
                     num_context=4, num_target=4, agg=AggConfig(name=name),
                     privacy=priv, compression=comp)
    opt = adam(fcfg.lr)
    agg = make_aggregator(fcfg.agg, num_clients=C)
    params = init_gpo_params(GCFG, jax.random.PRNGKey(0))
    server_state = agg.init(params)
    groups = jnp.arange(C, dtype=jnp.int32)
    weights = normalize_weights(data.sizes[groups])
    keys = jax.random.split(jax.random.PRNGKey(1), C)
    cp = broadcast_to_clients(params, C)
    opt_states = jax.vmap(opt.init)(cp)
    resid = jnp.zeros((C, tree_count_params(params)), jnp.float32)

    local_train = _make_local_train(GCFG, fcfg, data, opt)
    cp_ref, _, losses = jax.jit(jax.vmap(local_train))(
        cp, opt_states, keys, groups)
    vecs = tree_ravel_clients(tree_sub(cp_ref, cp))
    delta_vec, new_r = transport_delta_flat(vecs, weights, keys, priv,
                                            comp, agg, resid)
    delta = tree_unflatten_from_vector(delta_vec, params)
    global_ref, _ = agg.apply(server_state, params, delta, losses=losses,
                              idx=None)

    mesh = jax.make_mesh((1,), ("data",))
    round_fn = make_sharded_round(GCFG, fcfg, data, mesh, opt=opt, agg=agg)
    cp_s, _, _, _, r_s = jax.jit(round_fn)(cp, opt_states, keys, groups,
                                           weights, server_state, resid)
    for a, b in zip(jax.tree.leaves(global_ref), jax.tree.leaves(cp_s)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b)[0],
                                   rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(new_r), np.asarray(r_s),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.slow
@pytest.mark.parametrize("comp", [INT8, TOPK], ids=["int8", "topk"])
@pytest.mark.parametrize("name", ["fedavg", "median"])
def test_compressed_pallas_engine_matches_jnp(comp, name):
    """use_pallas_aggregation routes the linear family through the fused
    quantized-transport (or top-k scatter) kernel and the robust family
    through jnp codec + trim kernel; metrics must match the jnp
    reference for both. Uses the single-Pallas-block model so blockwise
    reductions cannot flip a rounding decision (see GCFG_SMALL note)."""
    assert tree_count_params(
        init_gpo_params(GCFG_SMALL, jax.random.PRNGKey(0))) <= 2048
    fed_jnp = _make_fed(comp, agg=AggConfig(name=name), gcfg=GCFG_SMALL)
    hist_jnp = fed_jnp.run(rounds=3)
    fed_pal = _make_fed(comp, agg=AggConfig(name=name), use_pallas=True,
                        gcfg=GCFG_SMALL)
    hist_pal = fed_pal.run(rounds=3)
    np.testing.assert_allclose(hist_jnp.round_loss, hist_pal.round_loss,
                               rtol=1e-4, atol=1e-6)
    for a, b in zip(jax.tree.leaves(fed_jnp.global_params),
                    jax.tree.leaves(fed_pal.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(fed_jnp.ef_resid),
                               np.asarray(fed_pal.ef_resid),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.slow
def test_compression_composes_with_privacy_accounting():
    """Compression after the DP release leaves ε untouched: the round_eps
    stream of a privacy+compression run equals the privacy-only run
    (the accountant never sees the codec), and both runs actually
    diverge in their training metrics (the codec does something)."""
    priv = PrivacyConfig(clip_norm=0.5, noise_multiplier=1.0)
    hist_priv = _make_fed(priv=priv).run(rounds=3)
    hist_both = _make_fed(INT8, priv=priv).run(rounds=3)
    np.testing.assert_allclose(hist_priv.round_eps, hist_both.round_eps,
                               rtol=1e-12)
    assert hist_priv.round_loss != hist_both.round_loss


def test_same_seed_reproduces_compressed_run_with_subsampling():
    """Rounding uniforms fold out of the per-client training keys, so
    same-seed runs under partial participation reproduce exactly and
    non-sampled clients' EF residual rows stay untouched."""
    hist_a = _make_fed(INT8, batch_groups=2).run(rounds=3)
    hist_b = _make_fed(INT8, batch_groups=2).run(rounds=3)
    assert hist_a.round_loss == hist_b.round_loss
    fed = _make_fed(INT8, batch_groups=2)
    assert np.all(np.asarray(fed.ef_resid) == 0.0)
    fed.run(rounds=1)
    resid = np.asarray(fed.ef_resid)
    touched = np.any(resid != 0.0, axis=1)
    assert touched.sum() == 2  # exactly the sampled clients


def test_error_feedback_improves_topk_convergence():
    """The reason EF exists: with an aggressive top-k the biased codec
    plus error feedback must end at a lower loss than the same codec
    with the residual thrown away."""
    comp_ef = CompressionConfig(kind="topk", topk_frac=0.02,
                                error_feedback=True)
    comp_no = CompressionConfig(kind="topk", topk_frac=0.02,
                                error_feedback=False)
    hist_ef = _make_fed(comp_ef, seed=5).run(rounds=3)
    hist_no = _make_fed(comp_no, seed=5).run(rounds=3)
    assert hist_ef.round_loss[-1] < hist_no.round_loss[-1]


# ---------------------------------------------------------------------------
# 5. backbone/LoRA trainers + config plumbing
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_backbone_round_applies_compression():
    """make_backbone_fedavg_round with compression grows the documented
    (..., resid, round_key) signature, returns the updated residual, and
    produces a different aggregate than the plain round while leaving
    local training untouched."""
    from repro.configs import get_arch, smoke_variant
    from repro.core import make_backbone_fedavg_round
    from repro.data import LMDataConfig, synthetic_lm_batches
    from repro.models import init_params

    cfg = smoke_variant(get_arch("qwen2-0.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adam(1e-3)
    c = 2
    agg = make_aggregator(AggConfig(), num_clients=c)
    it = synthetic_lm_batches(LMDataConfig(
        vocab_size=cfg.vocab_size, seq_len=16, global_batch=2, seed=0))
    batches = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[jax.tree.map(lambda *ys: jnp.stack(ys), *[next(it)])
          for _ in range(c)])
    weights = jnp.full((c,), 0.5)
    cp = broadcast_to_clients(params, c)
    opt_states = jax.vmap(opt.init)(cp)
    server_state = agg.init(params)
    resid = jnp.zeros((c, tree_count_params(params)), jnp.float32)

    rnd_plain = make_backbone_fedavg_round(cfg, opt, 1, agg=agg)
    out_plain, _, losses_plain, _ = jax.jit(rnd_plain)(
        cp, opt_states, batches, weights, server_state)

    rnd_comp = make_backbone_fedavg_round(cfg, opt, 1, agg=agg,
                                          compression=INT8)
    out_comp, _, losses_comp, _, new_resid = jax.jit(rnd_comp)(
        cp, opt_states, batches, weights, server_state, resid,
        jax.random.PRNGKey(9))
    np.testing.assert_allclose(np.asarray(losses_plain),
                               np.asarray(losses_comp), rtol=1e-6)
    diffs = [float(jnp.max(jnp.abs(a - b))) for a, b in
             zip(jax.tree.leaves(out_plain), jax.tree.leaves(out_comp))]
    assert max(diffs) > 0.0
    assert new_resid.shape == resid.shape
    assert float(jnp.max(jnp.abs(new_resid))) > 0.0

    # deterministic top-k without EF keeps the (..., server_state)
    # signature — no resid, no key
    rnd_topk = make_backbone_fedavg_round(
        cfg, opt, 1, agg=agg,
        compression=CompressionConfig(kind="topk", topk_frac=0.1,
                                      error_feedback=False))
    out_topk = jax.jit(rnd_topk)(cp, opt_states, batches, weights,
                                 server_state)
    assert len(out_topk) == 4


def test_compressed_round_requires_aggregator():
    from repro.configs import get_arch, smoke_variant
    from repro.core import make_backbone_fedavg_round

    cfg = smoke_variant(get_arch("qwen2-0.5b"))
    with pytest.raises(ValueError, match="ServerAggregator"):
        make_backbone_fedavg_round(cfg, adam(1e-3), 1, agg=None,
                                   compression=INT8)


def test_transport_rejects_disabled_kind():
    agg = make_aggregator(AggConfig(), num_clients=2)
    with pytest.raises(ValueError, match="kind"):
        transport_delta_flat(jnp.ones((2, 8)), jnp.full((2,), 0.5), None,
                             PrivacyConfig(), CompressionConfig(), agg,
                             None)
