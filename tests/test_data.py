"""Data pipeline tests: surveys, embeddings, LM batches."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import (
    LMDataConfig,
    StubEmbedder,
    SurveyConfig,
    make_survey_data,
    sample_icl_batch,
    split_groups,
    synthetic_lm_batches,
)


def test_survey_structure():
    cfg = SurveyConfig(num_groups=12, num_questions=50, num_options=5,
                       d_embed=32, seed=7)
    data = make_survey_data(cfg)
    assert data.prefs.shape == (12, 50, 5)
    np.testing.assert_allclose(np.asarray(data.prefs.sum(-1)),
                               np.ones((12, 50)), rtol=1e-5)
    assert data.phi.shape == (50, 5, 32)
    assert bool(jnp.all(data.sizes >= 8))
    # determinism
    data2 = make_survey_data(cfg)
    np.testing.assert_array_equal(np.asarray(data.prefs),
                                  np.asarray(data2.prefs))


def test_group_split_disjoint():
    data = make_survey_data(SurveyConfig(num_groups=17))
    tr, ev = split_groups(data, train_frac=0.6, seed=0)
    assert len(tr) == 10 and len(ev) == 7
    assert set(tr).isdisjoint(ev)
    assert set(tr) | set(ev) == set(range(17))


def test_icl_batch_shapes_and_options():
    data = make_survey_data(SurveyConfig(num_questions=60, d_embed=16))
    b = sample_icl_batch(jax.random.PRNGKey(0), data, group=2,
                         num_context=8, num_target=4)
    a = data.num_options
    assert b.ctx_x.shape == (8 * a, 16)
    assert b.tgt_y.shape == (4 * a,)
    # each context question's options sum to 1
    np.testing.assert_allclose(
        np.asarray(b.ctx_y.reshape(8, a).sum(-1)), np.ones(8), rtol=1e-5)
    # target question ids repeat per option
    qids = np.asarray(b.tgt_q.reshape(4, a))
    assert (qids == qids[:, :1]).all()


def test_icl_sampling_respects_group_mask():
    data = make_survey_data(SurveyConfig(num_questions=40, seed=3))
    g = 1
    answered = set(np.nonzero(np.asarray(data.mask[g]))[0].tolist())
    for s in range(5):
        b = sample_icl_batch(jax.random.PRNGKey(s), data, group=g,
                             num_context=6, num_target=6)
        qs = set(np.asarray(b.tgt_q).tolist())
        assert qs <= answered


def test_stub_embedder_deterministic_unit_norm():
    e = StubEmbedder(d_embed=24, seed=1)
    v1 = e.embed_qa("q1", "a1")
    v2 = e.embed_qa("q1", "a1")
    v3 = e.embed_qa("q1", "a2")
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2))
    assert not np.allclose(np.asarray(v1), np.asarray(v3))
    np.testing.assert_allclose(float(jnp.linalg.norm(v1)), 1.0, rtol=1e-5)


def test_lm_batches_shapes_and_shift():
    cfg = LMDataConfig(vocab_size=128, seq_len=16, global_batch=4, seed=0)
    it = synthetic_lm_batches(cfg)
    b1 = next(it)
    assert b1["tokens"].shape == (4, 16)
    assert b1["labels"].shape == (4, 16)
    # labels are next tokens
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["labels"][:, :-1]))
    assert int(b1["tokens"].max()) < 128
    # deterministic restart
    b1b = next(synthetic_lm_batches(cfg))
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b1b["tokens"]))
