"""Incremental decode == teacher-forced forward, for every architecture.

This is the strongest single invariant in the system: it exercises KV
caches, ring/window masking, SSD chunked-vs-recurrent duality (Mamba2),
the hybrid shared-attention cache (Zamba2), and cross-attention caches
(Whisper) in one assertion.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, get_arch, smoke_variant
from repro.models import forward, init_params

pytestmark = pytest.mark.slow  # full-zoo sweep, ~1 min on CPU

B, S, P = 2, 16, 8


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_forward(arch):
    key = jax.random.PRNGKey(1)
    cfg = smoke_variant(get_arch(arch))
    params = init_params(cfg, key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.is_encoder_decoder:
        kw["enc_embeds"] = jax.random.normal(
            key, (B, cfg.enc_seq_len, cfg.d_model))
    full_logits, _, _ = forward(params, cfg, tokens=tokens, **kw)
    _, cache, _ = forward(params, cfg, tokens=tokens[:, :P],
                          prefill_len=S, **kw)
    outs = []
    for t in range(P, S):
        lg, cache, _ = forward(params, cfg, tokens=tokens[:, t:t + 1],
                               cache=cache,
                               cache_pos=jnp.asarray(t, jnp.int32))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    ref = full_logits[:, P:S]
    rel = float(jnp.max(jnp.abs(dec - ref))) / (
        float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 1e-3, f"{arch}: rel={rel}"
