"""Alignment / fairness metric unit tests (paper Eqs. 4-6)."""
import jax.numpy as jnp
import numpy as np

from repro.core.fairness import (
    alignment_score,
    coefficient_of_variation,
    convergence_round,
    fairness_index,
    js_distance,
)


def test_jsd_identical_is_zero():
    p = jnp.array([[0.2, 0.3, 0.5]])
    assert float(js_distance(p, p)[0]) < 1e-6


def test_jsd_disjoint_is_one():
    p = jnp.array([[1.0, 0.0]])
    q = jnp.array([[0.0, 1.0]])
    assert abs(float(js_distance(p, q)[0]) - 1.0) < 1e-3


def test_jsd_symmetry():
    p = jnp.array([[0.7, 0.2, 0.1]])
    q = jnp.array([[0.1, 0.1, 0.8]])
    assert abs(float(js_distance(p, q)[0]) -
               float(js_distance(q, p)[0])) < 1e-7


def test_alignment_score_range_and_perfect():
    p = jnp.array([[0.2, 0.8], [0.6, 0.4]])
    assert abs(float(alignment_score(p, p)) - 1.0) < 1e-6
    q = jnp.array([[0.8, 0.2], [0.4, 0.6]])
    s = float(alignment_score(p, q))
    assert 0.0 <= s <= 1.0


def test_cov_and_fi_known_values():
    equal = jnp.array([0.5, 0.5, 0.5])
    assert float(coefficient_of_variation(equal)) < 1e-7
    assert abs(float(fairness_index(equal)) - 1.0) < 1e-6
    scores = jnp.array([0.2, 0.4, 0.6])
    mu, sigma = 0.4, np.sqrt(((0.2 - 0.4) ** 2 + 0 + (0.6 - 0.4) ** 2) / 3)
    cov = sigma / mu
    np.testing.assert_allclose(float(coefficient_of_variation(scores)),
                               cov, rtol=1e-5)
    np.testing.assert_allclose(float(fairness_index(scores)),
                               1.0 / (1.0 + cov ** 2), rtol=1e-5)


def test_convergence_round_95pct():
    # descent from 1.0 to 0.0: 95% of descent reached at value 0.05
    losses = np.linspace(1.0, 0.0, 101)
    r = convergence_round(losses, frac=0.95)
    assert r == 95
    # non-monotone tail: threshold = 1.0 - 0.95*(1.0-0.04) = 0.088,
    # first value <= 0.088 is index 3 (0.06)
    losses2 = np.array([1.0, 0.5, 0.2, 0.06, 0.04, 0.05, 0.04])
    assert convergence_round(losses2) == 3
