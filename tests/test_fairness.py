"""Alignment / fairness metric unit tests (paper Eqs. 4-6), including
the degenerate inputs the metrics must stay finite on: zero-mass
"distributions", identical distributions, single-group score vectors,
all-zero scores, and non-monotone / constant / empty loss curves."""
import jax.numpy as jnp
import numpy as np

from repro.core.fairness import (
    alignment_score,
    coefficient_of_variation,
    convergence_round,
    fairness_index,
    js_distance,
    kl_divergence,
)


def test_jsd_identical_is_zero():
    p = jnp.array([[0.2, 0.3, 0.5]])
    assert float(js_distance(p, p)[0]) < 1e-6


def test_jsd_disjoint_is_one():
    p = jnp.array([[1.0, 0.0]])
    q = jnp.array([[0.0, 1.0]])
    assert abs(float(js_distance(p, q)[0]) - 1.0) < 1e-3


def test_jsd_symmetry():
    p = jnp.array([[0.7, 0.2, 0.1]])
    q = jnp.array([[0.1, 0.1, 0.8]])
    assert abs(float(js_distance(p, q)[0]) -
               float(js_distance(q, p)[0])) < 1e-7


def test_alignment_score_range_and_perfect():
    p = jnp.array([[0.2, 0.8], [0.6, 0.4]])
    assert abs(float(alignment_score(p, p)) - 1.0) < 1e-6
    q = jnp.array([[0.8, 0.2], [0.4, 0.6]])
    s = float(alignment_score(p, q))
    assert 0.0 <= s <= 1.0


def test_cov_and_fi_known_values():
    equal = jnp.array([0.5, 0.5, 0.5])
    assert float(coefficient_of_variation(equal)) < 1e-7
    assert abs(float(fairness_index(equal)) - 1.0) < 1e-6
    scores = jnp.array([0.2, 0.4, 0.6])
    mu, sigma = 0.4, np.sqrt(((0.2 - 0.4) ** 2 + 0 + (0.6 - 0.4) ** 2) / 3)
    cov = sigma / mu
    np.testing.assert_allclose(float(coefficient_of_variation(scores)),
                               cov, rtol=1e-5)
    np.testing.assert_allclose(float(fairness_index(scores)),
                               1.0 / (1.0 + cov ** 2), rtol=1e-5)


# ---------------------------------------------------------------------------
# edge cases: the metrics must be total functions on degenerate inputs
# ---------------------------------------------------------------------------
def test_zero_mass_distributions_are_finite():
    """All-zero 'distributions' hit the eps clipping, not log(0)/0-div:
    every metric stays finite, and two zero vectors look identical."""
    z = jnp.zeros((1, 4))
    assert np.isfinite(float(kl_divergence(z, z)[0]))
    assert float(js_distance(z, z)[0]) < 1e-6  # identical -> distance 0
    assert np.isfinite(float(alignment_score(z, z)))
    p = jnp.array([[0.25, 0.25, 0.25, 0.25]])
    d = float(js_distance(p, z)[0])
    assert np.isfinite(d) and 0.0 <= d <= 1.0 + 1e-6


def test_partial_zero_mass_options_are_finite():
    """Distributions with zero-probability options (the common case for
    survey answers nobody picked) must not produce NaN/inf."""
    p = jnp.array([[0.5, 0.5, 0.0, 0.0]])
    q = jnp.array([[0.0, 0.0, 0.5, 0.5]])
    d = float(js_distance(p, q)[0])
    assert np.isfinite(d)
    assert abs(d - 1.0) < 1e-3  # disjoint support -> max distance
    assert np.isfinite(float(alignment_score(p, q)))


def test_identical_distributions_alignment_is_exactly_top():
    key_probs = jnp.array([[0.1, 0.2, 0.3, 0.4], [0.7, 0.1, 0.1, 0.1]])
    assert abs(float(alignment_score(key_probs, key_probs)) - 1.0) < 1e-6
    assert float(js_distance(key_probs, key_probs).max()) < 1e-6


def test_single_group_fairness_index_is_one():
    """K=1 eval groups: sigma is 0 by definition, so CoV=0 and FI=1 —
    no 0/0 from the single-element mean."""
    one = jnp.array([0.73])
    assert float(coefficient_of_variation(one)) == 0.0
    assert float(fairness_index(one)) == 1.0


def test_zero_scores_cov_hits_eps_floor_not_division_by_zero():
    """All-zero alignment scores: mu=0 triggers the eps guard; CoV and
    FI must come back finite (FI=1: zero spread, however degenerate)."""
    zero = jnp.zeros((5,))
    assert np.isfinite(float(coefficient_of_variation(zero)))
    assert np.isfinite(float(fairness_index(zero)))
    assert float(fairness_index(zero)) == 1.0


def test_convergence_round_95pct():
    # descent from 1.0 to 0.0: 95% of descent reached at value 0.05
    losses = np.linspace(1.0, 0.0, 101)
    r = convergence_round(losses, frac=0.95)
    assert r == 95
    # non-monotone tail: threshold = 1.0 - 0.95*(1.0-0.04) = 0.088,
    # first value <= 0.088 is index 3 (0.06)
    losses2 = np.array([1.0, 0.5, 0.2, 0.06, 0.04, 0.05, 0.04])
    assert convergence_round(losses2) == 3


def test_convergence_round_degenerate_curves():
    # empty history: 0, not an index error
    assert convergence_round(np.array([])) == 0
    # single point: already "converged" at round 0
    assert convergence_round(np.array([1.0])) == 0
    # constant loss: zero descent, threshold == start, hit at round 0
    assert convergence_round(np.full(10, 0.5)) == 0
    # loss that INCREASES: final > start means no 95%-descent round
    # exists — it must report the LAST round ("never converged"), not
    # round 0 (the old threshold sat above losses[0], so a diverging run
    # claimed instant convergence)
    assert convergence_round(np.linspace(0.1, 1.0, 20)) == 19
    # a curve that doubles then plateaus is still divergent end-to-end
    assert convergence_round(np.array([1.0, 2.0, 2.0, 2.0])) == 3


def test_convergence_round_non_monotone_never_reaches_threshold():
    """A curve that dips then ends HIGHER than its minimum: if no prefix
    point crosses the threshold the last index is returned."""
    losses = np.array([1.0, 0.9, 0.95, 0.95, 0.96])
    r = convergence_round(losses, frac=0.95)
    assert r in (len(losses) - 1, int(np.argmin(losses)))
    # spiky curve: first crossing wins even if later values bounce back
    spiky = np.array([1.0, 0.04, 0.9, 0.05, 0.0])
    assert convergence_round(spiky) == 1
