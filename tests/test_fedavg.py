"""FedAvg aggregation math (Eq. 2-3) + the federated/centralized engines."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FedConfig, GPOConfig
from repro.core import (
    CentralizedGPO,
    FederatedGPO,
    broadcast_to_clients,
    fedavg_flat,
    fedavg_stacked,
    normalize_weights,
)
from repro.data import SurveyConfig, make_survey_data, split_groups


def _tree(key, c):
    return {
        "w": jax.random.normal(key, (c, 4, 3)),
        "b": jax.random.normal(jax.random.fold_in(key, 1), (c, 5)),
    }


def test_weights_normalize():
    w = normalize_weights(jnp.array([10.0, 30.0, 60.0]))
    np.testing.assert_allclose(np.asarray(w), [0.1, 0.3, 0.6], rtol=1e-6)
    assert abs(float(w.sum()) - 1.0) < 1e-6


def test_aggregate_identical_clients_is_identity():
    key = jax.random.PRNGKey(0)
    single = {"w": jax.random.normal(key, (4, 3))}
    stacked = broadcast_to_clients(single, 5)
    w = normalize_weights(jnp.arange(1.0, 6.0))
    agg = fedavg_stacked(stacked, w)
    np.testing.assert_allclose(np.asarray(agg["w"]),
                               np.asarray(single["w"]), rtol=1e-6)


def test_aggregate_linearity_and_flat_equivalence():
    key = jax.random.PRNGKey(1)
    stacked = _tree(key, 3)
    w = jnp.array([0.2, 0.3, 0.5])
    agg = fedavg_stacked(stacked, w)
    manual = jax.tree.map(
        lambda leaf: (w[:, None, None] * leaf).sum(0)
        if leaf.ndim == 3 else (w[:, None] * leaf).sum(0), stacked)
    for a, b in zip(jax.tree.leaves(agg), jax.tree.leaves(manual)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    flat = fedavg_flat(stacked, w)
    for a, b in zip(jax.tree.leaves(agg), jax.tree.leaves(flat)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_fedavg_flat_ignores_stale_module_cache():
    """Regression: ``fedavg_flat`` once cached a module-level aggregator
    (built on first use with num_clients=0, never invalidated), so stale
    strategy state injected into the module leaked into every later
    call. The helper must build its registry aggregator per call — a
    poisoned module-level cache attribute has no effect, and the result
    is the exact weighted mean."""
    from repro.configs import AggConfig
    from repro.core import fedavg as fedavg_mod
    from repro.core.aggregation import make_aggregator

    key = jax.random.PRNGKey(2)
    stacked = _tree(key, 3)
    w = jnp.array([0.2, 0.3, 0.5])
    # poison the pre-fix cache slot with a non-linear strategy: if
    # fedavg_flat consults it, the result is a coordinate median, not
    # the weighted mean
    fedavg_mod._FEDAVG_AGG = make_aggregator(AggConfig(name="median"),
                                             num_clients=3)
    try:
        got = fedavg_flat(stacked, w)
    finally:
        del fedavg_mod._FEDAVG_AGG
    want = fedavg_stacked(stacked, w)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6)


def test_federated_learns_and_evaluates():
    data = make_survey_data(SurveyConfig(
        num_groups=8, num_questions=40, d_embed=24, seed=1))
    tr, ev = split_groups(data, seed=1)
    gcfg = GPOConfig(d_embed=24, d_model=48, num_layers=2, num_heads=4,
                     d_ff=96)
    fcfg = FedConfig(num_clients=len(tr), rounds=15, local_epochs=2,
                     eval_every=5, num_context=6, num_target=6)
    fed = FederatedGPO(gcfg, fcfg, data, tr, ev)
    hist = fed.run(rounds=15)
    assert hist.round_loss[-1] < hist.round_loss[0]
    assert len(hist.eval_mean_as) >= 3
    assert all(0.0 <= s <= 1.0 for s in hist.eval_mean_as)
    assert all(0.0 < f <= 1.0 for f in hist.eval_fi)


def test_centralized_baseline_learns():
    data = make_survey_data(SurveyConfig(
        num_groups=8, num_questions=40, d_embed=24, seed=2))
    tr, ev = split_groups(data, seed=2)
    gcfg = GPOConfig(d_embed=24, d_model=48, num_layers=2, num_heads=4,
                     d_ff=96)
    fcfg = FedConfig(num_clients=len(tr), rounds=15, eval_every=5,
                     num_context=6, num_target=6)
    cen = CentralizedGPO(gcfg, fcfg, data, tr, ev)
    hist = cen.run(epochs=15)
    assert hist.round_loss[-1] < hist.round_loss[0]


def test_fed_round_redistributes_global_model():
    data = make_survey_data(SurveyConfig(
        num_groups=6, num_questions=30, d_embed=16, seed=3))
    tr, ev = split_groups(data, seed=3)
    gcfg = GPOConfig(d_embed=16, d_model=32, num_layers=1, num_heads=2,
                     d_ff=32)
    fcfg = FedConfig(num_clients=len(tr), rounds=2, local_epochs=1,
                     num_context=6, num_target=6)
    fed = FederatedGPO(gcfg, fcfg, data, tr, ev)
    g0 = fed.global_params
    fed.run(rounds=2)
    g1 = fed.global_params
    # aggregation changed the global model
    assert any(bool(jnp.any(a != b)) for a, b in zip(
        jax.tree.leaves(g0), jax.tree.leaves(g1)))
