"""GPO preference-predictor invariants (paper §3.1 / GPO)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import GPOConfig
from repro.core import gpo_apply, gpo_loss, init_gpo_params, predict_preferences
from repro.optim import adam

CFG = GPOConfig(d_embed=16, d_model=32, num_layers=2, num_heads=4, d_ff=64)


def _data(key, m=6, t=10):
    kx, ky, kt = jax.random.split(key, 3)
    ctx_x = jax.random.normal(kx, (m, CFG.d_embed))
    ctx_y = jax.random.uniform(ky, (m,))
    tgt_x = jax.random.normal(kt, (t, CFG.d_embed))
    return ctx_x, ctx_y, tgt_x


def test_output_shape():
    key = jax.random.PRNGKey(0)
    params = init_gpo_params(CFG, key)
    ctx_x, ctx_y, tgt_x = _data(key)
    mu, log_sigma = gpo_apply(params, CFG, ctx_x, ctx_y, tgt_x)
    assert mu.shape == (10,)
    assert log_sigma is None


def test_target_conditional_independence():
    """Eq. 1: target i's prediction may not depend on target j != i —
    the neural-process mask must prevent cross-target leakage."""
    key = jax.random.PRNGKey(1)
    params = init_gpo_params(CFG, key)
    ctx_x, ctx_y, tgt_x = _data(key)
    mu1, _ = gpo_apply(params, CFG, ctx_x, ctx_y, tgt_x)
    tgt_x2 = tgt_x.at[3].set(jax.random.normal(jax.random.fold_in(key, 9),
                                               (CFG.d_embed,)))
    mu2, _ = gpo_apply(params, CFG, ctx_x, ctx_y, tgt_x2)
    others = jnp.delete(jnp.arange(10), 3)
    np.testing.assert_allclose(np.asarray(mu1[others]),
                               np.asarray(mu2[others]), rtol=1e-5, atol=1e-6)
    assert not np.allclose(float(mu1[3]), float(mu2[3]))


def test_context_permutation_invariance():
    """No positional encoding: the context is a SET."""
    key = jax.random.PRNGKey(2)
    params = init_gpo_params(CFG, key)
    ctx_x, ctx_y, tgt_x = _data(key)
    mu1, _ = gpo_apply(params, CFG, ctx_x, ctx_y, tgt_x)
    perm = jax.random.permutation(jax.random.fold_in(key, 3), 6)
    mu2, _ = gpo_apply(params, CFG, ctx_x[perm], ctx_y[perm], tgt_x)
    np.testing.assert_allclose(np.asarray(mu1), np.asarray(mu2),
                               rtol=1e-4, atol=1e-5)


def test_loss_decreases_with_training():
    key = jax.random.PRNGKey(3)
    params = init_gpo_params(CFG, key)
    # learnable synthetic mapping y = sigmoid(<w, x>)
    w = jax.random.normal(jax.random.fold_in(key, 1), (CFG.d_embed,))

    def batch(k):
        x = jax.random.normal(k, (20, CFG.d_embed))
        y = jax.nn.sigmoid(x @ w)
        return x[:8], y[:8], x[8:], y[8:]

    opt = adam(3e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, k):
        cx, cy, tx, ty = batch(k)
        loss, grads = jax.value_and_grad(gpo_loss)(params, CFG, cx, cy,
                                                   tx, ty)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    losses = []
    for i in range(60):
        params, state, loss = step(params, state,
                                   jax.random.fold_in(key, 100 + i))
        losses.append(float(loss))
    assert np.mean(losses[-10:]) < 0.5 * np.mean(losses[:10])


def test_predict_preferences_simplex():
    key = jax.random.PRNGKey(4)
    params = init_gpo_params(CFG, key)
    ctx_x, ctx_y, _ = _data(key)
    tgt_x = jax.random.normal(key, (3 * 5, CFG.d_embed))
    pred = predict_preferences(params, CFG, ctx_x, ctx_y, tgt_x,
                               num_options=5)
    assert pred.shape == (3, 5)
    np.testing.assert_allclose(np.asarray(pred.sum(-1)), np.ones(3),
                               rtol=1e-5)
    assert bool(jnp.all(pred >= 0))


def test_pallas_attention_path_matches_jnp():
    """Serving with the Pallas neural-process kernel == jnp path."""
    import dataclasses

    key = jax.random.PRNGKey(6)
    params = init_gpo_params(CFG, key)
    ctx_x, ctx_y, tgt_x = _data(key, m=6, t=10)
    mu_ref, _ = gpo_apply(params, CFG, ctx_x, ctx_y, tgt_x)
    cfg_k = dataclasses.replace(CFG, use_pallas_attention=True)
    mu_ker, _ = gpo_apply(params, cfg_k, ctx_x, ctx_y, tgt_x)
    np.testing.assert_allclose(np.asarray(mu_ref), np.asarray(mu_ker),
                               rtol=1e-4, atol=1e-5)


def test_learned_sigma_head():
    cfg = GPOConfig(d_embed=16, d_model=32, num_layers=1, num_heads=2,
                    d_ff=32, learn_sigma=True)
    key = jax.random.PRNGKey(5)
    params = init_gpo_params(cfg, key)
    ctx_x, ctx_y, tgt_x = _data(key)
    mu, log_sigma = gpo_apply(params, cfg, ctx_x, ctx_y, tgt_x)
    assert log_sigma is not None and log_sigma.shape == mu.shape
    loss = gpo_loss(params, cfg, ctx_x, ctx_y, tgt_x,
                    jnp.zeros_like(mu))
    assert jnp.isfinite(loss)
