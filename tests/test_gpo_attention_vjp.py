"""Gradient equivalence for the banded GPO-attention custom VJP
(DESIGN.md §8): raw dq/dk/dv against the ref.py oracles, jax.grad of
gpo_loss against the dense jnp path, and the runtime plumbing that puts
the kernel on the training hot path of every engine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FedConfig, GPOConfig
from repro.core import gpo_loss, init_gpo_params
from repro.kernels import gpo_attention
from repro.kernels.ref import ref_gpo_attention_grads

CFG = GPOConfig(d_embed=16, d_model=32, num_layers=2, num_heads=4, d_ff=64)


def _qkv(key, s, h=4, hd=32):
    q = jax.random.normal(key, (s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (s, h, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (s, h, hd))
    do = jax.random.normal(jax.random.fold_in(key, 3), (s, h, hd))
    return q, k, v, do


@pytest.mark.parametrize("s,m,b", [
    (64, 13, 16),    # num_ctx not a multiple of the k-block
    (100, 20, 16),   # S not a multiple of the block (wrapper pads)
    # t >> m: the training/eval regime the band targets (full fwd+bwd
    # grids in interpret mode — the expensive case, fast suite skips it)
    pytest.param(512, 8, 32, marks=pytest.mark.slow),
    (48, 40, 16),    # context dominates (band covers most of the grid)
    (32, 30, 32),    # band saturates -> wrapper falls back to full grid
])
@pytest.mark.parametrize("banded", [True, False])
def test_gpo_attention_vjp_matches_oracle(s, m, b, banded):
    """dq/dk/dv from the pair of backward Pallas kernels == the textbook
    softmax-gradient oracle, banded and full grids."""
    key = jax.random.PRNGKey(0)
    q, k, v, do = _qkv(key, s)

    def attn(q, k, v):
        return gpo_attention(q, k, v, num_ctx=m, bq=b, bk=b, banded=banded)

    out, vjp = jax.vjp(attn, q, k, v)
    dq, dk, dv = vjp(do)
    rdq, rdk, rdv = ref_gpo_attention_grads(
        q.transpose(1, 0, 2), k.transpose(1, 0, 2), v.transpose(1, 0, 2),
        do.transpose(1, 0, 2), num_ctx=m)
    for got, ref, name in [(dq, rdq, "dq"), (dk, rdk, "dk"), (dv, rdv, "dv")]:
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref.transpose(1, 0, 2)),
            rtol=1e-4, atol=1e-4, err_msg=name)


def test_gpo_attention_grad_under_vmap():
    """The training layout: clients vmapped over the kernel's grad."""
    key = jax.random.PRNGKey(1)
    qs, ks, vs, _ = _qkv(key, 64)
    q = jnp.stack([qs, qs * 0.5, qs + 1.0])
    k, v = jnp.stack([ks] * 3), jnp.stack([vs] * 3)

    def loss(q, k, v):
        return jnp.sum(gpo_attention(q, k, v, num_ctx=8, bq=16, bk=16) ** 2)

    got = jax.vmap(jax.grad(loss))(q, k, v)

    def ref_one(q, k, v):
        o, vjp_fn = jax.vjp(
            lambda q: gpo_attention(q, k, v, num_ctx=8, bq=16, bk=16), q)
        return vjp_fn(2.0 * o)[0]

    ref = jnp.stack([ref_one(q[i], k[i], v[i]) for i in range(3)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("learn_sigma", [False, True])
@pytest.mark.parametrize("m,t", [
    (6, 10),    # neither divides the 16-wide block the wrapper picks
    (16, 16),   # aligned
    (13, 51),   # t >> m, ragged
    (30, 2),    # band saturates the padded grid -> full-grid fallback
])
def test_grad_gpo_loss_pallas_matches_dense(learn_sigma, m, t):
    """jax.grad(gpo_loss) with use_pallas_attention=True runs (the
    kernel is no longer forward-only) and matches the dense masked-
    softmax reference to <= 1e-4."""
    cfg = dataclasses.replace(CFG, num_layers=1, learn_sigma=learn_sigma)
    key = jax.random.PRNGKey(2)
    params = init_gpo_params(cfg, key)
    kx, ky, kt, kty = jax.random.split(key, 4)
    ctx_x = jax.random.normal(kx, (m, cfg.d_embed))
    ctx_y = jax.random.uniform(ky, (m,))
    tgt_x = jax.random.normal(kt, (t, cfg.d_embed))
    tgt_y = jax.random.uniform(kty, (t,))

    g_ref = jax.grad(gpo_loss)(params, cfg, ctx_x, ctx_y, tgt_x, tgt_y)
    cfg_k = dataclasses.replace(cfg, use_pallas_attention=True)
    g_ker = jax.grad(gpo_loss)(params, cfg_k, ctx_x, ctx_y, tgt_x, tgt_y)
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_ker)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_bwd_tile_counts_below_dense_grid():
    """The backward grids keep the banded work bound: dq walks the
    forward's band, dk/dv walks its transpose (context tiles sweep all
    q-rows, pure-target tiles only their diagonal)."""
    from repro.kernels.gpo_attention import (
        gpo_tile_counts,
        gpo_tile_counts_bwd,
    )

    s, m, b = 512, 8, 32
    nq = s // b
    banded, full = gpo_tile_counts_bwd(s, m, b, b)
    assert full == 2 * nq * nq
    # dq: ctx block + diagonal step per q-row; dk/dv: one full q sweep
    # for the single ctx k-tile + one diagonal tile per target k-tile
    assert banded == nq * 2 + (nq + (nq - 1))
    assert banded < full
    # fwd+bwd combined stays strictly below the dense grid too
    fwd_banded, fwd_full = gpo_tile_counts(s, m, b, b)
    assert fwd_banded + banded < fwd_full + full
    # saturated band: both degenerate to the full grid
    assert gpo_tile_counts_bwd(32, 30, 32, 32) == (2, 2)


def test_fedconfig_attention_override_plumbing():
    """FedConfig.use_pallas_attention=None defers to GPOConfig; a bool
    forces the resolved model config every engine traces with."""
    fcfg = FedConfig()
    assert fcfg.resolve_gpo(CFG) is CFG
    forced = dataclasses.replace(fcfg, use_pallas_attention=True)
    assert forced.resolve_gpo(CFG).use_pallas_attention is True
    off = dataclasses.replace(fcfg, use_pallas_attention=False)
    cfg_on = dataclasses.replace(CFG, use_pallas_attention=True)
    assert off.resolve_gpo(cfg_on).use_pallas_attention is False


@pytest.mark.slow
def test_centralized_trainer_pallas_attention_matches_dense():
    """The centralized baseline trains through the custom-VJP kernel
    when the runtime override is set, to float tolerance of the dense
    path (same ops, tiled schedule)."""
    from repro.core.centralized import CentralizedGPO
    from repro.data import SurveyConfig, make_survey_data, split_groups

    data = make_survey_data(SurveyConfig(
        num_groups=6, num_questions=24, d_embed=16, seed=3))
    tr, ev = split_groups(data, seed=3)
    gcfg = GPOConfig(d_embed=16, d_model=32, num_layers=1, num_heads=2,
                     d_ff=32)
    fcfg = FedConfig(num_clients=len(tr), rounds=2, local_epochs=1,
                     num_context=4, num_target=4, seed=3)
    hist_ref = CentralizedGPO(gcfg, fcfg, data, tr, ev).run(epochs=2)
    fcfg_k = dataclasses.replace(fcfg, use_pallas_attention=True)
    cen_k = CentralizedGPO(gcfg, fcfg_k, data, tr, ev)
    assert cen_k.gpo_cfg.use_pallas_attention  # plumbing reached the cfg
    hist_ker = cen_k.run(epochs=2)
    np.testing.assert_allclose(hist_ref.round_loss, hist_ker.round_loss,
                               rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(hist_ref.eval_mean_as, hist_ker.eval_mean_as,
                               rtol=2e-4, atol=1e-4)
