"""Two-level client→edge→server aggregation (DESIGN.md §14).

Contracts:

1. degeneracy — ``HierarchyConfig()`` (num_edges=1) disables the
   topology *statically*: the pipeline is not restructured,
   ``hier_reduce_flat`` is the flat ``agg.reduce_flat``, and a run with
   an explicit E=1 config is BIT-equal to a default run;
2. linear exactness — for the linear family the edge partial sums
   (against globally-normalized weights) add up to the flat weighted
   mean, so E>1 matches E=1 to reassociation tolerance, both at the
   reduce level and over a full training run;
3. robust semantics — each edge pre-reduces its OWN rows with the
   configured rule (trim depth derived from the C/E edge population),
   then the rule re-runs over the E candidates weighted by edge mass:
   identical rows are a fixed point for every strategy, and the
   two-cluster case lands on the hand-computed server value;
4. engine consistency — scan and loop trace the same hierarchy pipeline
   (bit-equal histories and parameters at E=2);
5. validation — num_edges < 1, non-divisible populations, composition
   with the §11 fault simulator, and a sharded mesh without a matching
   leading edge axis are all rejected eagerly;
6. wire (slow, subprocess) — the compiled sharded schedule's per-op
   collectives show the §14 shrink: robust cross-edge all-gather bytes
   drop from O(C·P) to O(E·P) (4x again with the §10 int8 codec on the
   cross-edge hop), while the linear family's all-reduce total is
   unchanged.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (
    AggConfig,
    AvailabilityConfig,
    CompressionConfig,
    FedConfig,
    GPOConfig,
    HierarchyConfig,
    PrivacyConfig,
)
from repro.configs.base import AdversaryConfig
from repro.core import FederatedGPO, make_aggregator
from repro.core.federated import make_sharded_round
from repro.core.pipeline import RoundPipeline
from repro.data import SurveyConfig, make_survey_data, split_groups

GCFG = GPOConfig(d_embed=8, d_model=16, num_layers=1, num_heads=2, d_ff=32)

NOCOMP = CompressionConfig(kind="none", error_feedback=False)


def _make_fed(hierarchy=HierarchyConfig(), agg=AggConfig(),
              avail=AvailabilityConfig(), seed=3, rounds=3):
    data = make_survey_data(SurveyConfig(
        num_groups=6, num_questions=24, d_embed=8, seed=seed))
    tr, ev = split_groups(data, seed=seed)  # 4 train groups: E | 4
    fcfg = FedConfig(num_clients=len(tr), rounds=rounds, local_epochs=2,
                     eval_every=2, num_context=4, num_target=4, agg=agg,
                     compression=NOCOMP, avail=avail, hierarchy=hierarchy,
                     seed=seed)
    return FederatedGPO(GCFG, fcfg, data, tr, ev)


def _pipe(agg_cfg=AggConfig(), num_edges=1, num_clients=8):
    return RoundPipeline(
        adversary=AdversaryConfig(), privacy=PrivacyConfig(),
        compression=NOCOMP,
        agg=make_aggregator(agg_cfg, num_clients=num_clients),
        num_clients=num_clients,
        hierarchy=HierarchyConfig(num_edges=num_edges))


# ---------------------------------------------------------------------------
# config + static structure
# ---------------------------------------------------------------------------
def test_hierarchy_config_flags_and_validation():
    assert HierarchyConfig().enabled is False
    assert HierarchyConfig(num_edges=2).enabled is True
    HierarchyConfig(num_edges=2).validate(8)  # divisible: fine
    with pytest.raises(ValueError):
        HierarchyConfig(num_edges=0).validate()
    with pytest.raises(ValueError):
        HierarchyConfig(num_edges=3).validate(8)


def test_e1_is_statically_disabled():
    """num_edges=1 must not restructure the pipeline (the flat fused
    trace keeps riding) and hier_reduce_flat must BE the flat reduce."""
    pipe = _pipe(num_edges=1)
    assert not pipe.restructured
    assert _pipe(num_edges=2).restructured
    vecs = jax.random.normal(jax.random.PRNGKey(0), (8, 7))
    w = jnp.full((8,), 1.0 / 8)
    np.testing.assert_array_equal(
        np.asarray(pipe.hier_reduce_flat(vecs, w)),
        np.asarray(pipe.agg.reduce_flat(vecs, w)))


# ---------------------------------------------------------------------------
# reduce-level semantics
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("num_edges", [2, 4])
def test_linear_edge_partials_sum_to_flat_mean(num_edges):
    """Linear family: edge partial sums against globally-normalized
    weights add up to the exact flat weighted mean (Eq. 2)."""
    key = jax.random.PRNGKey(1)
    vecs = jax.random.normal(key, (8, 11))
    sizes = jnp.arange(1.0, 9.0)
    w = sizes / sizes.sum()
    got = _pipe(num_edges=num_edges).hier_reduce_flat(vecs, w)
    want = np.asarray(w)[:, None] * np.asarray(vecs)
    np.testing.assert_allclose(np.asarray(got), want.sum(0),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize(
    "name", ["median", "trimmed_mean", "krum", "multi_krum", "geomedian"])
def test_identical_rows_are_a_fixed_point(name):
    """Every strategy maps C copies of the same row to that row, through
    both hops — edge candidates equal the row, and so does the server
    rule over the candidates."""
    row = jax.random.normal(jax.random.PRNGKey(2), (9,))
    vecs = jnp.broadcast_to(row, (8, 9))
    w = jnp.full((8,), 1.0 / 8)
    got = _pipe(AggConfig(name=name), num_edges=2).hier_reduce_flat(vecs, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(row),
                               rtol=1e-5, atol=1e-6)


def test_median_two_cluster_server_value():
    """E=2 with each edge internally unanimous: the edge candidates are
    the cluster rows a and b, and the server rule over two equal-mass
    candidates (trim depth k=(2-1)//2=0) is their mean."""
    a = jnp.arange(5.0)
    b = -2.0 * jnp.arange(5.0) + 1.0
    vecs = jnp.concatenate([jnp.broadcast_to(a, (4, 5)),
                            jnp.broadcast_to(b, (4, 5))])
    w = jnp.full((8,), 1.0 / 8)
    got = _pipe(AggConfig(name="median"), num_edges=2).hier_reduce_flat(
        vecs, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray((a + b) / 2.0),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# engine-level degeneracy + equivalence
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ["scan", "loop"])
def test_e1_run_is_bit_equal_to_default(engine):
    """Explicit num_edges=1 must change NOTHING: same trace, bit-equal
    history and parameters vs. the default config."""
    fed_ref = _make_fed()
    hist_ref = fed_ref.run(rounds=3, engine=engine)
    fed = _make_fed(hierarchy=HierarchyConfig(num_edges=1))
    hist = fed.run(rounds=3, engine=engine)
    assert hist_ref.round_loss == hist.round_loss  # floats, bit-for-bit
    for a, b in zip(jax.tree.leaves(fed_ref.global_params),
                    jax.tree.leaves(fed.global_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_linear_hier_run_matches_flat():
    """FedAvg with E=2 edges reassociates the same weighted sum — a full
    training run stays within float tolerance of the flat run."""
    hist_flat = _make_fed().run(rounds=3, engine="loop")
    fed = _make_fed(hierarchy=HierarchyConfig(num_edges=2))
    hist = fed.run(rounds=3, engine="loop")
    np.testing.assert_allclose(hist.round_loss, hist_flat.round_loss,
                               rtol=1e-4)


def test_scan_loop_bit_equal_with_hierarchy():
    """Both stacked engines trace the same §14 pipeline: E=2 median runs
    are bit-equal across scan and loop."""
    fed_s = _make_fed(hierarchy=HierarchyConfig(num_edges=2),
                      agg=AggConfig(name="median"))
    hist_s = fed_s.run(rounds=3, engine="scan")
    fed_l = _make_fed(hierarchy=HierarchyConfig(num_edges=2),
                      agg=AggConfig(name="median"))
    hist_l = fed_l.run(rounds=3, engine="loop")
    assert hist_s.round_loss == hist_l.round_loss
    for a, b in zip(jax.tree.leaves(fed_s.global_params),
                    jax.tree.leaves(fed_l.global_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_hier_median_run_trains():
    """End-to-end E=2 median: the hierarchical round still learns."""
    fed = _make_fed(hierarchy=HierarchyConfig(num_edges=2),
                    agg=AggConfig(name="median"), rounds=4)
    hist = fed.run(rounds=4, engine="loop")
    assert len(hist.round_loss) == 4
    assert all(np.isfinite(hist.round_loss))


# ---------------------------------------------------------------------------
# eager rejection
# ---------------------------------------------------------------------------
def test_non_divisible_population_rejected():
    with pytest.raises(ValueError, match="divide"):
        _make_fed(hierarchy=HierarchyConfig(num_edges=3))  # 4 clients


def test_hierarchy_does_not_compose_with_faults():
    faulty = AvailabilityConfig(online_prob=0.7, crash_prob=0.15,
                                straggler_prob=0.3, max_staleness=3)
    with pytest.raises(ValueError, match="fault"):
        _make_fed(hierarchy=HierarchyConfig(num_edges=2), avail=faulty)


def test_sharded_round_requires_edge_axis():
    """hierarchy.num_edges>1 on a mesh without a matching leading edge
    axis must fail at build time, not mis-aggregate silently."""
    data = make_survey_data(SurveyConfig(
        num_groups=5, num_questions=24, d_embed=8, seed=0))
    fcfg = FedConfig(num_clients=4, rounds=1, local_epochs=1,
                     num_context=4, num_target=4, compression=NOCOMP,
                     hierarchy=HierarchyConfig(num_edges=2))
    with pytest.raises(ValueError, match="edge"):
        make_sharded_round(GCFG, fcfg, data,
                           jax.make_mesh((1,), ("data",)))
    with pytest.raises(ValueError, match="edge"):
        make_sharded_round(GCFG, fcfg, data,
                           jax.make_mesh((1, 1), ("edge", "data")),
                           client_axes=("edge", "data"))


def test_client_axes_helper_orders_edge_first():
    from repro.launch.mesh import client_axes
    mesh = jax.make_mesh((1, 1), ("edge", "data"))
    assert client_axes(mesh) == ("edge", "data")
    assert client_axes(jax.make_mesh((1,), ("data",))) == ("data",)


# ---------------------------------------------------------------------------
# compiled two-hop wire (subprocess: forked device count)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_two_hop_collective_bytes():
    """The §14 wire contract, read off the optimized HLO per-op:

    * robust flat: ONE all-gather of C·P floats; edges=4 splits it into
      an intra-edge all-gather of (C/E)·P and a cross-edge all-gather of
      E·P — every hop strictly smaller than the flat gather, and the
      cross-edge hop is E/C of it;
    * robust + int8: the cross-edge hop carries the §10 wire layout —
      4x fewer bytes again (multiplicative with the topology win);
    * linear: the weighted psum over both axes is the SAME total
      all-reduce bytes as the flat psum (a torus all-reduce already IS
      the composed two-hop schedule);
    * edges=1 through the CLI path is byte-identical to flat.
    """
    code = """
import json
from repro.launch.dryrun import lower_gpo_round

def gathers(r):
    # payload gathers only — the per-client weight/mass side-gathers
    # are a few bytes and not part of the O(C*P) claim
    return sorted(b * m for k, b, m in r["collective_ops"]
                  if k == "all-gather" and b * m >= 1024)

out = {}
med_flat = lower_gpo_round("median", clients=8, verbose=False)
med_hier = lower_gpo_round("median", clients=8, edges=4, verbose=False)
med_e1 = lower_gpo_round("median", clients=8, edges=1, verbose=False)
int8_hier = lower_gpo_round("median", clients=8, edges=4,
                            compress="int8", verbose=False)
avg_flat = lower_gpo_round("fedavg", clients=8, verbose=False)
avg_hier = lower_gpo_round("fedavg", clients=8, edges=4, verbose=False)
out["med_flat_ag"] = gathers(med_flat)
out["med_hier_ag"] = gathers(med_hier)
out["med_e1_by_kind"] = med_e1["collective_bytes_by_kind"]
out["med_flat_by_kind"] = med_flat["collective_bytes_by_kind"]
out["int8_hier_ops"] = int8_hier["collective_ops"]
out["avg_flat_ar"] = avg_flat["collective_bytes_by_kind"].get(
    "all-reduce", 0)
out["avg_hier_ar"] = avg_hier["collective_bytes_by_kind"].get(
    "all-reduce", 0)
print(json.dumps(out))
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + sys.path))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])

    # flat robust: one C·P gather; hierarchical: intra (C/E)·P + cross E·P
    [flat_ag] = out["med_flat_ag"]
    hier_ags = out["med_hier_ag"]
    assert len(hier_ags) == 2
    intra, cross = hier_ags
    assert cross == pytest.approx(flat_ag * 4 / 8)  # E/C of the flat hop
    assert intra == pytest.approx(flat_ag * 2 / 8)  # (C/E)/C of it
    assert max(hier_ags) < flat_ag
    # the whole two-hop schedule moves fewer bytes than the flat gather
    assert sum(hier_ags) < 0.8 * flat_ag

    # int8 codec rides the cross-edge hop: an int8 gather at 1/4 the
    # f32 cross-edge payload (plus a tiny f32 scale gather)
    int8_ags = sorted(b * m for k, b, m in out["int8_hier_ops"]
                      if k == "all-gather" and b * m >= 1024)
    assert any(b == pytest.approx(cross / 4) for b in int8_ags)
    assert max(int8_ags) <= intra  # cross-edge no longer dominates

    # linear family: total all-reduce unchanged by the edge mesh
    assert out["avg_hier_ar"] == pytest.approx(out["avg_flat_ar"])
    assert out["avg_flat_ar"] > 0

    # edges=1 through the CLI is the flat schedule, byte-identical
    assert out["med_e1_by_kind"] == out["med_flat_by_kind"]
