"""Per-kernel allclose tests: shape/dtype sweeps against the ref.py
pure-jnp oracles (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    agg_momentum_reduce,
    agg_trimmed_reduce,
    fedavg_reduce,
    fedavg_reduce_tree,
    flash_attention,
    gpo_attention,
    ssd_scan,
)
from repro.kernels.ref import (
    ref_attention,
    ref_fedavg_flat,
    ref_gpo_attention,
    ref_momentum_reduce_flat,
    ref_ssd,
    ref_trimmed_flat,
)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("s", [64, 100, 257])
@pytest.mark.parametrize("h,kv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(s, h, kv, dtype):
    key = jax.random.PRNGKey(0)
    b, hd = 2, 64
    q = jax.random.normal(key, (b, s, h, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, hd), dtype)
    out = flash_attention(q, k, v, causal=True, bq=32, bk=32)
    ref = ref_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [1, 7, 64])
@pytest.mark.parametrize("softcap", [None, 20.0])
def test_flash_attention_window_softcap(window, softcap):
    key = jax.random.PRNGKey(3)
    b, s, h, kv, hd = 1, 128, 4, 2, 64
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, hd))
    out = flash_attention(q, k, v, causal=True, window=window,
                          softcap=softcap, bq=32, bk=32)
    ref = ref_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True, window=window,
        softcap=softcap).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("s,m", [(64, 16), (100, 20), (48, 40)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gpo_attention_sweep(s, m, dtype):
    key = jax.random.PRNGKey(1)
    h, hd = 4, 32
    q = jax.random.normal(key, (s, h, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (s, h, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (s, h, hd), dtype)
    out = gpo_attention(q, k, v, num_ctx=m, bq=16, bk=16)
    ref = ref_gpo_attention(
        q.transpose(1, 0, 2), k.transpose(1, 0, 2),
        v.transpose(1, 0, 2), num_ctx=m).transpose(1, 0, 2)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("s,m,b", [
    (64, 13, 16),    # num_ctx not a multiple of the k-block
    (257, 16, 32),   # S not a multiple of the block (wrapper pads)
    (512, 8, 32),    # t >> m: the eval regime the banded grid targets
    (48, 40, 16),    # context dominates (band covers most of the grid)
    (33, 1, 16),     # single context point, padded S
])
def test_gpo_attention_banded_grid_cases(s, m, b):
    """Banded grid (ctx band + diagonal k-step) vs the jnp oracle AND the
    legacy full predicated grid."""
    key = jax.random.PRNGKey(7)
    h, hd = 4, 32
    q = jax.random.normal(key, (s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (s, h, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (s, h, hd))
    banded = gpo_attention(q, k, v, num_ctx=m, bq=b, bk=b)
    ref = ref_gpo_attention(
        q.transpose(1, 0, 2), k.transpose(1, 0, 2),
        v.transpose(1, 0, 2), num_ctx=m).transpose(1, 0, 2)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    full = gpo_attention(q, k, v, num_ctx=m, bq=b, bk=b, banded=False)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(full),
                               rtol=2e-5, atol=2e-5)


def test_gpo_banded_grid_visits_fewer_tiles():
    """The O(S*m + S) claim at the grid level: tiles visited is
    num_qb * (ctx_blocks + 1), not num_qb * num_kb."""
    from repro.kernels.gpo_attention import gpo_tile_counts

    banded, full = gpo_tile_counts(512, 8, 32, 32)
    assert banded == (512 // 32) * 2  # one ctx block + diagonal step
    assert full == (512 // 32) ** 2
    assert banded * 8 == full


def test_gpo_attention_matches_module_mask():
    """The kernel's mask must equal core.gpo._np_mask semantics."""
    from repro.core.gpo import _np_mask

    m, t = 8, 24
    mask = np.asarray(_np_mask(m, t))
    # kernel semantics: key < m or key == query
    s = m + t
    expected = (np.arange(s)[None, :] < m) | np.eye(s, dtype=bool)
    np.testing.assert_array_equal(mask, expected)


@pytest.mark.parametrize("s,chunk", [(64, 16), (75, 16), (128, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(s, chunk, dtype):
    key = jax.random.PRNGKey(2)
    b, h, p, n = 2, 3, 16, 8
    x = (jax.random.normal(key, (b, s, h, p)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(
        jax.random.normal(jax.random.fold_in(key, 5), (b, s, h)))
    A_log = jax.random.normal(jax.random.fold_in(key, 6), (h,)) * 0.5
    B = (jax.random.normal(jax.random.fold_in(key, 7), (b, s, n)) * 0.5
         ).astype(dtype)
    C = (jax.random.normal(jax.random.fold_in(key, 8), (b, s, n)) * 0.5
         ).astype(dtype)
    D = jax.random.normal(jax.random.fold_in(key, 9), (h,))
    y = ssd_scan(x, dt, A_log, B, C, D, chunk=chunk)
    yr = ref_ssd(x, dt, A_log, B, C, D)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_ssd_kernel_matches_model_path():
    """kernel == model ssd_chunked == step-by-step ref (triangulation)."""
    from repro.models.ssm import ssd_chunked

    key = jax.random.PRNGKey(4)
    b, s, h, p, n = 1, 48, 2, 8, 4
    x = jax.random.normal(key, (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (b, s, h)))
    A_log = jax.random.normal(jax.random.fold_in(key, 2), (h,)) * 0.5
    B = jax.random.normal(jax.random.fold_in(key, 3), (b, s, n)) * 0.5
    C = jax.random.normal(jax.random.fold_in(key, 4), (b, s, n)) * 0.5
    D = jnp.ones((h,))
    y_kernel = ssd_scan(x, dt, A_log, B, C, D, chunk=16)
    y_model, _ = ssd_chunked(x, dt, A_log, B, C, D, chunk=16)
    y_ref = ref_ssd(x, dt, A_log, B, C, D)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("c,p", [(2, 100), (5, 10001), (16, 4096)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedavg_reduce_sweep(c, p, dtype):
    key = jax.random.PRNGKey(5)
    stacked = jax.random.normal(key, (c, p), dtype)
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (c,)))
    out = fedavg_reduce(stacked, w)
    ref = ref_fedavg_flat(stacked, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("c,p", [(2, 100), (5, 10001), (16, 4096)])
@pytest.mark.parametrize("beta", [0.0, 0.9])
def test_momentum_reduce_sweep(c, p, beta):
    """Weighted delta-moment kernel == the obvious two-liner, and its
    delta output == the plain fedavg reduction (beta only shapes m)."""
    key = jax.random.PRNGKey(7)
    stacked = jax.random.normal(key, (c, p))
    m = jax.random.normal(jax.random.fold_in(key, 1), (p,))
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 2), (c,)))
    d, nm = agg_momentum_reduce(stacked, w, m, beta=beta)
    d_ref, nm_ref = ref_momentum_reduce_flat(stacked, w, m, beta=beta)
    np.testing.assert_allclose(np.asarray(d), np.asarray(d_ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(nm), np.asarray(nm_ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(d),
                               np.asarray(fedavg_reduce(stacked, w)),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("c,trim", [(3, 1), (5, 2), (8, 1), (9, 4)])
@pytest.mark.parametrize("p", [100, 5000])
def test_trimmed_reduce_sweep(c, trim, p):
    """Client-axis rank/trim kernel == the stable-argsort oracle
    (trim=(C-1)//2 cases are the coordinate-wise median)."""
    key = jax.random.PRNGKey(8)
    stacked = jax.random.normal(key, (c, p))
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (c,)))
    out = agg_trimmed_reduce(stacked, w, trim=trim)
    ref = ref_trimmed_flat(stacked, w, trim=trim)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_trimmed_reduce_handles_ties_stably():
    """Duplicate values across clients: ranks break ties by client index
    (a stable sort), so kernel and oracle agree bit-for-bit."""
    stacked = jnp.array([[1.0, 2.0], [1.0, 2.0], [0.0, 3.0], [1.0, 2.0]])
    w = jnp.array([0.4, 0.3, 0.2, 0.1])
    out = agg_trimmed_reduce(stacked, w, trim=1)
    ref = ref_trimmed_flat(stacked, w, trim=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-7)


def test_trimmed_reduce_rejects_bad_trim():
    stacked = jnp.ones((4, 8))
    w = jnp.full((4,), 0.25)
    with pytest.raises(ValueError):
        agg_trimmed_reduce(stacked, w, trim=2)


def test_fedavg_reduce_tree_matches_stacked():
    from repro.core import fedavg_stacked

    key = jax.random.PRNGKey(6)
    tree = {"a": jax.random.normal(key, (3, 8, 4)),
            "b": {"c": jax.random.normal(jax.random.fold_in(key, 1),
                                         (3, 5))}}
    w = jnp.array([0.5, 0.3, 0.2])
    out = fedavg_reduce_tree(tree, w)
    ref = fedavg_stacked(tree, w)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
