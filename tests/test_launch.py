"""Launch-layer units: sharding rules, specs, HLO cost engine, roofline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ALL_ARCHS, INPUT_SHAPES, get_arch
from repro.launch.hlo_cost import analyze_hlo, parse_module
from repro.launch.roofline import model_flops, parse_collectives
from repro.launch.sharding import param_spec
from repro.launch.specs import (
    batch_specs,
    cache_specs,
    count_params,
    input_specs,
    serving_config,
)


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_specs_divisibility(arch):
    """Every sharded dim must be divisible by its mesh axes product."""
    cfg = get_arch(arch)
    shapes = jax.eval_shape(
        lambda k: __import__("repro.models", fromlist=["init_params"])
        .init_params(cfg, k), jax.random.PRNGKey(0))
    mesh = FakeMesh()
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for path, leaf in flat:
        spec = param_spec(jax.tree_util.keystr(path), tuple(leaf.shape),
                          cfg, mesh, fsdp=True)
        for dim, axis in zip(leaf.shape, spec):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            total = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % total == 0, (arch, path, leaf.shape, spec)


@pytest.mark.parametrize("arch", ALL_ARCHS)
@pytest.mark.parametrize("shape", sorted(INPUT_SHAPES))
def test_input_specs_cover_all_inputs(arch, shape):
    cfg = serving_config(get_arch(arch), INPUT_SHAPES[shape])
    specs = input_specs(cfg, INPUT_SHAPES[shape])
    sh = INPUT_SHAPES[shape]
    if sh.kind == "train":
        b = specs["batch"]
        assert "labels" in b
        key = "embeds" if cfg.input_kind == "embeddings" else "tokens"
        assert b[key].shape[0] == sh.global_batch
        assert b[key].shape[1] == sh.seq_len
        if cfg.is_encoder_decoder:
            assert b["enc_embeds"].shape[1] == cfg.enc_seq_len
    elif sh.kind == "prefill":
        assert "labels" not in specs["batch"]
    else:
        assert specs["tokens"].shape == (sh.global_batch, 1)
        assert len(specs["cache"]) > 0


def test_long500k_variant_only_for_full_attention():
    for arch in ALL_ARCHS:
        cfg = get_arch(arch)
        served = serving_config(cfg, INPUT_SHAPES["long_500k"])
        if cfg.long_context_variant:
            assert max(served.window_pattern) <= cfg.long_context_window
        else:
            assert served.window_pattern == cfg.window_pattern


def test_hlo_cost_trip_count_awareness():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=13)
        return y.sum()

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    hlo = jax.jit(f).lower(x, w).compile().as_text()
    totals = analyze_hlo(hlo)
    expected = 13 * 2 * 32 ** 3
    assert 0.95 * expected < totals.flops < 1.2 * expected
    # XLA's own analysis counts the body once — our reason to exist
    xla = jax.jit(f).lower(x, w).compile().cost_analysis()
    if isinstance(xla, (list, tuple)):  # pre-0.5 jax wraps it in a list
        xla = xla[0] if xla else {}
    if "flops" not in xla:  # don't let the undercount claim pass vacuously
        pytest.skip("cost_analysis() reports no flops on this backend")
    assert xla["flops"] < totals.flops / 5


def test_parse_module_entry():
    hlo = jax.jit(lambda a: a * 2 + 1).lower(
        jax.ShapeDtypeStruct((8,), jnp.float32)).compile().as_text()
    comps, entry = parse_module(hlo)
    assert entry is not None and entry in comps


def test_collective_regex():
    text = """
  %ar = f32[16,512]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = bf16[2,128]{1,0} all-gather(%y), dimensions={0}
  %rs = (f32[8,8]{1,0}, f32[8,8]{1,0}) reduce-scatter(%a, %b)
"""
    stats = parse_collectives(text)
    assert stats.bytes_by_kind["all-reduce"] == 16 * 512 * 4
    assert stats.bytes_by_kind["all-gather"] == 2 * 128 * 2
    assert stats.bytes_by_kind["reduce-scatter"] == 2 * 8 * 8 * 4


def test_model_flops_moe_counts_active_only():
    dense = get_arch("qwen2-0.5b")
    moe = get_arch("grok-1-314b")
    f_moe = model_flops(moe, INPUT_SHAPES["train_4k"], 256)
    n_total = count_params(moe)
    # active params far below total for 8-expert top-2
    assert f_moe < 6 * n_total * INPUT_SHAPES["train_4k"].global_batch \
        * INPUT_SHAPES["train_4k"].seq_len / 256
    assert f_moe > 0
    assert model_flops(dense, INPUT_SHAPES["decode_32k"], 256) > 0


def test_count_params_sane():
    assert 0.4e9 < count_params(get_arch("qwen2-0.5b")) < 0.7e9
    assert 250e9 < count_params(get_arch("grok-1-314b")) < 400e9
    assert 20e9 < count_params(get_arch("gemma2-27b")) < 35e9
