"""LoRA / FedLoRA tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_variant
from repro.core import (
    apply_lora,
    broadcast_to_clients,
    init_lora,
    lora_param_count,
    make_fedlora_round,
    normalize_weights,
)
from repro.models import init_params
from repro.optim import adam


def test_zero_b_is_identity(rng):
    cfg = smoke_variant(get_arch("qwen2-0.5b"))
    params = init_params(cfg, rng)
    lora = init_lora(params, rng, rank=4)
    assert lora_param_count(lora) > 0
    eff = apply_lora(params, lora)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(eff)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_nonzero_b_changes_only_targets(rng):
    cfg = smoke_variant(get_arch("qwen2-0.5b"))
    params = init_params(cfg, rng)
    lora = init_lora(params, rng, rank=4)
    lora = jax.tree.map(lambda x: x + 0.01, lora)
    eff = apply_lora(params, lora)
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_e = jax.tree.leaves(eff)
    adapted_idx = {int(i) for i in lora["adapters"]}
    for i, ((path, a), b) in enumerate(zip(flat_p, flat_e)):
        changed = bool(jnp.any(jnp.abs(a.astype(jnp.float32)
                                       - b.astype(jnp.float32)) > 1e-6))
        assert changed == (i in adapted_idx), jax.tree_util.keystr(path)


def test_stacked_per_layer_adapters(rng):
    """Scanned (L, d, f) leaves must get per-layer (L, d, r) adapters."""
    cfg = smoke_variant(get_arch("qwen2-0.5b"))
    params = init_params(cfg, rng)
    lora = init_lora(params, rng, rank=4)
    flat = jax.tree.leaves(params)
    found_3d = False
    for idx_str, ad in lora["adapters"].items():
        leaf = flat[int(idx_str)]
        if leaf.ndim == 3:
            found_3d = True
            assert ad["a"].shape == (leaf.shape[0], leaf.shape[1], 4)
            assert ad["b"].shape == (leaf.shape[0], 4, leaf.shape[2])
    assert found_3d


def test_fedlora_round_learns(rng):
    from repro.data import LMDataConfig, synthetic_lm_batches

    cfg = smoke_variant(get_arch("qwen2-0.5b"))
    params = init_params(cfg, rng)
    lora = init_lora(params, rng, rank=4)
    c, ls = 2, 2
    client_lora = broadcast_to_clients(lora, c)
    opt = adam(1e-3)
    opt_states = jax.vmap(opt.init)(client_lora)
    rnd = jax.jit(make_fedlora_round(cfg, params, opt, ls))
    it = synthetic_lm_batches(LMDataConfig(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=2))
    batches = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[jax.tree.map(lambda *ys: jnp.stack(ys),
                       *[next(it) for _ in range(ls)]) for _ in range(c)])
    w = normalize_weights(jnp.ones((c,)))
    losses_hist = []
    for _ in range(3):
        client_lora, opt_states, losses = rnd(client_lora, opt_states,
                                              batches, w)
        losses_hist.append(float(losses.mean()))
    assert losses_hist[-1] < losses_hist[0]
    # redistribution: all clients share the adapter state after a round
    a0 = jax.tree.leaves(client_lora)[0]
    np.testing.assert_allclose(np.asarray(a0[0]), np.asarray(a0[1]),
                               rtol=1e-6)
