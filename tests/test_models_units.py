"""Model-layer unit tests: rope, masks, moe, ssd, conv."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import make_causal_window_mask
from repro.models.layers import rms_norm, rope, softcap
from repro.models.moe import expert_capacity, moe_ffn, init_moe_params
from repro.models.ssm import causal_depthwise_conv, ssd_chunked
from repro.kernels.ref import ref_ssd


def test_rope_preserves_norm_and_relative_positions(rng):
    b, s, h, hd = 1, 8, 2, 32
    x = jax.random.normal(rng, (b, s, h, hd))
    pos = jnp.arange(s)[None, :].repeat(b, 0)
    y = rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(x, axis=-1)),
        np.asarray(jnp.linalg.norm(y, axis=-1)), rtol=1e-4)
    # inner products depend only on relative offsets
    q = jax.random.normal(jax.random.fold_in(rng, 1), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.fold_in(rng, 2), (1, 1, 1, hd))
    def dot_at(pq, pk):
        qr = rope(q, jnp.array([[pq]]), 10_000.0)
        kr = rope(k, jnp.array([[pk]]), 10_000.0)
        return float(jnp.sum(qr * kr))
    np.testing.assert_allclose(dot_at(5, 3), dot_at(9, 7), rtol=1e-4)


def test_rms_norm_unit_scale():
    x = jnp.array([[3.0, 4.0]])
    y = rms_norm(x, jnp.zeros(2))
    np.testing.assert_allclose(
        float(jnp.sqrt(jnp.mean(jnp.square(y)))), 1.0, rtol=1e-4)


def test_causal_window_mask():
    pos = jnp.arange(6)[None, :]
    m_global = make_causal_window_mask(pos, pos, 0)  # 0 == global
    assert bool(m_global[0, 5, 0]) and not bool(m_global[0, 0, 5])
    m_win = make_causal_window_mask(pos, pos, 2)
    # window 2: attend self and previous only
    assert bool(m_win[0, 3, 3]) and bool(m_win[0, 3, 2])
    assert not bool(m_win[0, 3, 1])


def test_expert_capacity_alignment():
    c = expert_capacity(1024, 8, 2, 1.25)
    assert c % 8 == 0 and c >= 1024 * 2 / 8


def test_moe_load_is_conserved(rng):
    """With drop-free capacity, combine weights per token sum to 1 and the
    layer output is a convex mix of expert outputs (checked via linearity
    against manual dense routing)."""
    from repro.configs import get_arch, smoke_variant

    cfg = smoke_variant(get_arch("grok-1-314b"))
    p = init_moe_params(rng, cfg, jnp.float32)
    x = jax.random.normal(rng, (2, 8, cfg.d_model))
    out, aux = moe_ffn(p, x, cfg.num_experts, cfg.experts_per_token,
                       capacity_factor=8.0)
    # manual dense: route every token through all experts, mix by topk probs
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p.router
    probs = jax.nn.softmax(logits, -1)
    topk_p, topk_i = jax.lax.top_k(probs, cfg.experts_per_token)
    topk_p = topk_p / topk_p.sum(-1, keepdims=True)
    g = jnp.einsum("td,edf->tef", xf, p.w_gate)
    u = jnp.einsum("td,edf->tef", xf, p.w_up)
    h = jax.nn.silu(g) * u
    all_out = jnp.einsum("tef,efd->ted", h, p.w_down)
    mix = jnp.take_along_axis(all_out, topk_i[..., None], axis=1)
    manual = (mix * topk_p[..., None]).sum(1).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(manual),
                               rtol=1e-3, atol=1e-4)
    assert float(aux) > 0


def test_conv_causality(rng):
    b, s, c = 1, 10, 4
    x = jax.random.normal(rng, (b, s, c))
    w = jax.random.normal(jax.random.fold_in(rng, 1), (4, c))
    bias = jnp.zeros((c,))
    y1, _ = causal_depthwise_conv(x, w, bias)
    x2 = x.at[:, 7].set(99.0)  # perturb the future
    y2, _ = causal_depthwise_conv(x2, w, bias)
    np.testing.assert_allclose(np.asarray(y1[:, :7]), np.asarray(y2[:, :7]),
                               rtol=1e-5)
    assert not np.allclose(np.asarray(y1[:, 7:]), np.asarray(y2[:, 7:]))


def test_conv_streaming_matches_full(rng):
    """Decode-time conv with state == full-sequence conv."""
    b, s, c, w_len = 1, 12, 3, 4
    x = jax.random.normal(rng, (b, s, c))
    w = jax.random.normal(jax.random.fold_in(rng, 1), (w_len, c))
    bias = jax.random.normal(jax.random.fold_in(rng, 2), (c,))
    full, _ = causal_depthwise_conv(x, w, bias)
    state = jnp.zeros((b, w_len - 1, c))
    outs = []
    for t in range(s):
        y, state = causal_depthwise_conv(x[:, t:t + 1], w, bias, state=state)
        outs.append(y)
    stream = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(stream),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_chunk_size_invariance(rng, chunk):
    b, s, h, p, n = 1, 64, 2, 8, 4
    x = jax.random.normal(rng, (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(rng, 1),
                                           (b, s, h)))
    A_log = jax.random.normal(jax.random.fold_in(rng, 2), (h,)) * 0.5
    B = jax.random.normal(jax.random.fold_in(rng, 3), (b, s, n)) * 0.5
    C = jax.random.normal(jax.random.fold_in(rng, 4), (b, s, n)) * 0.5
    D = jnp.ones((h,))
    y, state = ssd_chunked(x, dt, A_log, B, C, D, chunk)
    y_ref = ref_ssd(x, dt, A_log, B, C, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_chunked_attention_matches_dense(rng):
    """The flash-style q-chunked XLA path == dense masked softmax."""
    from repro.models import attention as A
    from repro.configs import get_arch, smoke_variant

    cfg = smoke_variant(get_arch("qwen2-0.5b"))
    p = A.init_attn_params(rng, cfg, jnp.float32)
    b, s = 1, 64
    x = jax.random.normal(rng, (b, s, cfg.d_model))
    q, k, v = A._project_qkv(p, x, cfg.num_heads, cfg.num_kv_heads,
                             cfg.head_dim, cfg.norm_eps)
    pos = jnp.arange(s)[None, :]
    q = A.rope(q, pos, 10_000.0)
    k = A.rope(k, pos, 10_000.0)
    mask = A.make_causal_window_mask(pos, pos, 0)
    dense = A.gqa_scores_softmax(q, k, v, mask, None)
    chunked = A._chunked_gqa(q, k, v, jnp.asarray(0), None, q_chunk=16)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               rtol=1e-5, atol=1e-6)


def test_softcap_values():
    np.testing.assert_allclose(float(softcap(jnp.asarray(0.0), 30.0)), 0.0)
    assert float(softcap(jnp.asarray(1e6), 30.0)) <= 30.0
