"""Optimizer unit tests (adam / adamw / sgd / adafactor / clipping)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adafactor, adam, adamw, clip_by_global_norm, sgd
from repro.optim.schedules import (
    constant_schedule,
    cosine_schedule,
    linear_warmup_cosine,
)


def _rosenbrock_ish(p):
    return jnp.sum(jnp.square(p["a"] - 1.3)) + jnp.sum(
        jnp.square(p["b"] @ p["b"].T - jnp.eye(3)))


@pytest.mark.parametrize("make_opt", [
    lambda: adam(5e-2), lambda: adamw(5e-2, weight_decay=1e-4),
    lambda: sgd(5e-3, momentum=0.9), lambda: adafactor(5e-2)])
def test_optimizers_descend(make_opt):
    key = jax.random.PRNGKey(0)
    params = {"a": jax.random.normal(key, (6,)),
              "b": jax.random.normal(jax.random.fold_in(key, 1), (3, 3))}
    opt = make_opt()
    state = opt.init(params)
    l0 = float(_rosenbrock_ish(params))
    for _ in range(120):
        grads = jax.grad(_rosenbrock_ish)(params)
        params, state = opt.update(grads, state, params)
    l1 = float(_rosenbrock_ish(params))
    assert l1 < 0.3 * l0
    assert all(bool(jnp.all(jnp.isfinite(x)))
               for x in jax.tree.leaves(params))


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((7,))}
    opt = adafactor(1e-2)
    state = opt.init(params)
    assert state.v_row["w"].shape == (64,)
    assert state.v_col["w"].shape == (32,)
    assert state.v_full["b"].shape == (7,)
    # factored state never stores the full (64, 32) second moment
    assert state.v_full["w"].shape == (1,)


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    np.testing.assert_allclose(float(norm), 20.0, rtol=1e-5)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-4)
    small = {"a": jnp.full((4,), 0.01)}
    out, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.asarray(small["a"]), rtol=1e-6)


def test_schedules():
    assert float(constant_schedule(0.1)(1000)) == pytest.approx(0.1)
    cos = cosine_schedule(1.0, 100, final_frac=0.1)
    assert float(cos(0)) == pytest.approx(1.0, rel=1e-3)
    assert float(cos(100)) == pytest.approx(0.1, rel=1e-3)
    warm = linear_warmup_cosine(1.0, 10, 110)
    assert float(warm(0)) == pytest.approx(0.1, rel=1e-3)
    assert float(warm(9)) == pytest.approx(1.0, rel=1e-3)
    assert float(warm(110)) < 0.2
