"""Activation-sharding context tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.partitioning import activation_sharding, default_rules, shard_act


def test_identity_without_context(rng):
    x = jax.random.normal(rng, (4, 8))
    y = shard_act(x, ("batch", "embed"))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_with_single_device_mesh(rng):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    x = jax.random.normal(rng, (4, 6, 8))

    @jax.jit
    def f(x):
        with activation_sharding(mesh):
            return shard_act(x, ("batch", "seq", "ff")) * 2

    np.testing.assert_allclose(np.asarray(f(x)), np.asarray(x) * 2)


def test_divisibility_guard():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = default_rules(mesh)
    assert rules["heads"] == "model"
    # dims not divisible by the axis are left unsharded -> no error
    x = jnp.zeros((3, 5, 7))
    with activation_sharding(mesh):
        y = shard_act(x, ("batch", "seq", "heads"))
    assert y.shape == x.shape


def test_rank_mismatch_is_noop(rng):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    x = jax.random.normal(rng, (4, 8))
    with activation_sharding(mesh):
        y = shard_act(x, ("batch", "seq", "heads"))  # wrong rank
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
