"""The differentially-private client-delta pipeline (DESIGN.md §9).

Five contracts:

1. degeneracy — ``PrivacyConfig(clip_norm=0)`` disables the pipeline and
   every engine (scan / loop / sharded) traces the exact pre-privacy
   computation: histories and parameters are BIT-equal to a default run;
2. clipping semantics — privatized per-client norms never exceed the
   bound, non-binding clips are exact no-ops, and engine results with a
   generous clip match the unclipped baseline;
3. kernel oracle — the fused ``agg_clip_reduce`` kernel matches the
   explicit ``ref.py`` formula across ragged client counts, non-uniform
   weights, noise on/off and interpret modes, and the engine-level
   Pallas path matches the jnp path for every registry strategy;
4. determinism — same ``FedConfig.seed`` under subsampling AND DP noise
   reproduces histories exactly, in both drivers, and the sharded
   engine derives bit-identical noise from the same per-client keys;
5. accounting — the Rényi accountant's closed forms, monotonicity, and
   the ε stream recorded into ``History.round_eps``.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import AggConfig, FedConfig, GPOConfig, PrivacyConfig
from repro.core import (
    FederatedGPO,
    RdpAccountant,
    broadcast_to_clients,
    clip_noise_reduce,
    clip_scales,
    make_accountant,
    make_aggregator,
    normalize_weights,
    privatize_flat,
)
from repro.core.federated import _make_local_train, make_sharded_round
from repro.core.gpo import init_gpo_params
from repro.core import privacy as dp
from repro.data import SurveyConfig, make_survey_data, split_groups
from repro.kernels import agg_clip_reduce
from repro.kernels.ref import ref_clip_reduce, ref_fedavg_flat
from repro.optim import adam
from repro.utils.pytree import (
    tree_ravel_clients,
    tree_sub,
    tree_unflatten_from_vector,
)

GCFG = GPOConfig(d_embed=8, d_model=16, num_layers=1, num_heads=2, d_ff=32)


def _make_fed(privacy=PrivacyConfig(), agg=AggConfig(), use_pallas=False,
              batch_groups=0, seed=3):
    data = make_survey_data(SurveyConfig(
        num_groups=6, num_questions=24, d_embed=8, seed=seed))
    tr, ev = split_groups(data, seed=seed)
    fcfg = FedConfig(num_clients=len(tr), rounds=3, local_epochs=2,
                     eval_every=2, num_context=4, num_target=4,
                     batch_groups=batch_groups, agg=agg,
                     use_pallas_aggregation=use_pallas, privacy=privacy,
                     seed=seed)
    return FederatedGPO(GCFG, fcfg, data, tr, ev)


def _assert_bit_equal(fed_a, fed_b, hist_a, hist_b):
    assert hist_a.round_loss == hist_b.round_loss  # floats, bit-for-bit
    np.testing.assert_array_equal(np.stack(hist_a.eval_scores),
                                  np.stack(hist_b.eval_scores))
    for a, b in zip(jax.tree.leaves(fed_a.global_params),
                    jax.tree.leaves(fed_b.global_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# 1. degeneracy: clip_norm == 0 is the exact pre-privacy trace
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ["scan", "loop"])
def test_disabled_privacy_is_bit_equal_to_fedavg(engine):
    """PrivacyConfig(0, 0) must not perturb a single bit of the FedAvg
    run — the pipeline is statically traced out, not multiplied by 1."""
    fed_ref = _make_fed()
    hist_ref = fed_ref.run(rounds=3, engine=engine)
    fed = _make_fed(PrivacyConfig(clip_norm=0.0, noise_multiplier=0.0))
    hist = fed.run(rounds=3, engine=engine)
    _assert_bit_equal(fed_ref, fed, hist_ref, hist)
    assert hist.round_eps == []  # no accounting without a pipeline


def test_disabled_privacy_is_bit_equal_in_sharded_round():
    C = 4
    data = make_survey_data(SurveyConfig(
        num_groups=C, num_questions=24, d_embed=8, seed=0))
    opt = adam(1e-3)
    params = init_gpo_params(GCFG, jax.random.PRNGKey(0))
    groups = jnp.arange(C, dtype=jnp.int32)
    weights = normalize_weights(data.sizes[groups])
    keys = jax.random.split(jax.random.PRNGKey(1), C)
    cp = broadcast_to_clients(params, C)
    opt_states = jax.vmap(opt.init)(cp)
    mesh = jax.make_mesh((1,), ("data",))
    outs = []
    for priv in (PrivacyConfig(),
                 PrivacyConfig(clip_norm=0.0, noise_multiplier=0.0)):
        fcfg = FedConfig(num_clients=C, local_epochs=2, lr=1e-3,
                         num_context=4, num_target=4, privacy=priv)
        agg = make_aggregator(fcfg.agg, num_clients=C)
        round_fn = make_sharded_round(GCFG, fcfg, data, mesh, opt=opt,
                                      agg=agg)
        cp_out, _, losses, _ = jax.jit(round_fn)(
            cp, opt_states, keys, groups, weights, agg.init(params))
        outs.append((cp_out, losses))
    np.testing.assert_array_equal(np.asarray(outs[0][1]),
                                  np.asarray(outs[1][1]))
    for a, b in zip(jax.tree.leaves(outs[0][0]),
                    jax.tree.leaves(outs[1][0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_noise_without_clip_is_rejected():
    with pytest.raises(ValueError, match="clip_norm"):
        PrivacyConfig(clip_norm=0.0, noise_multiplier=1.0).validate()
    with pytest.raises(ValueError):
        PrivacyConfig(clip_norm=-1.0).validate()
    with pytest.raises(ValueError, match="target_delta"):
        PrivacyConfig(clip_norm=1.0, target_delta=0.0).validate()


# ---------------------------------------------------------------------------
# 2. clipping semantics
# ---------------------------------------------------------------------------
def test_privatized_norms_never_exceed_bound():
    key = jax.random.PRNGKey(0)
    vecs = jax.random.normal(key, (8, 257)) * 10.0
    priv = PrivacyConfig(clip_norm=0.7)
    keys = jax.random.split(jax.random.fold_in(key, 1), 8)
    out = privatize_flat(vecs, keys, priv)  # clip-only
    norms = np.linalg.norm(np.asarray(out), axis=1)
    assert np.all(norms <= 0.7 * (1 + 1e-5))


def test_clip_is_identity_below_bound_and_handles_zero():
    key = jax.random.PRNGKey(1)
    vecs = jax.random.normal(key, (4, 64))
    vecs = vecs / jnp.linalg.norm(vecs, axis=1, keepdims=True)  # norm 1
    vecs = vecs.at[2].set(0.0)  # zero delta: scale must stay 1, not 0/0
    scales = clip_scales(vecs, 2.0)
    np.testing.assert_array_equal(np.asarray(scales), np.ones(4))
    priv = PrivacyConfig(clip_norm=2.0)
    keys = jax.random.split(key, 4)
    np.testing.assert_array_equal(np.asarray(privatize_flat(
        vecs, keys, priv)), np.asarray(vecs, np.float32))


def test_generous_clip_matches_unclipped_engine():
    """A clip bound no client ever hits makes scale exactly 1.0, so the
    engine must reproduce the unclipped run (up to the reduce's float
    reassociation — the privacy path reduces the raveled matrix)."""
    hist_ref = _make_fed().run(rounds=3)
    fed = _make_fed(PrivacyConfig(clip_norm=1e6))
    hist = fed.run(rounds=3)
    np.testing.assert_allclose(hist_ref.round_loss, hist.round_loss,
                               rtol=1e-4, atol=1e-6)
    assert hist.round_eps == [float("inf")] * 3  # clip-only: no DP claim


def test_tight_clip_changes_the_run_and_noise_changes_it_further():
    hist_ref = _make_fed().run(rounds=3)
    hist_clip = _make_fed(PrivacyConfig(clip_norm=1e-3)).run(rounds=3)
    assert not np.allclose(hist_ref.round_loss, hist_clip.round_loss)
    hist_noise = _make_fed(PrivacyConfig(
        clip_norm=1e-3, noise_multiplier=1.0)).run(rounds=3)
    assert hist_noise.round_loss != hist_clip.round_loss


# ---------------------------------------------------------------------------
# 3. kernel == oracle == engine jnp path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("c,p", [(2, 100), (5, 10001), (9, 4096),
                                 (16, 2048)])
@pytest.mark.parametrize("with_noise", [False, True])
def test_clip_reduce_kernel_matches_ref(c, p, with_noise):
    """Fused kernel vs the explicit formula across ragged client counts,
    non-uniform weights and noise on/off (test_aggregation sweep style).
    Mixed clipped/unclipped clients: half the rows sit below the bound."""
    key = jax.random.PRNGKey(5)
    stacked = jax.random.normal(key, (c, p))
    stacked = stacked.at[::2].mul(10.0)  # alternate binding / non-binding
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (c,)))
    noise = (0.3 * jax.random.normal(jax.random.fold_in(key, 2), (c, p))
             if with_noise else None)
    clip = float(jnp.median(jnp.linalg.norm(stacked, axis=1)))
    out = agg_clip_reduce(stacked, w, clip=clip, noise=noise)
    ref = ref_clip_reduce(stacked, w, clip=clip, noise=noise)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("interpret", [True, None])
def test_clip_reduce_interpret_modes(interpret):
    """Explicit interpret=True and the backend default agree (on CPU the
    default IS interpret; on TPU this pins native == interpret)."""
    key = jax.random.PRNGKey(6)
    stacked = jax.random.normal(key, (5, 300)) * 4.0
    w = jnp.full((5,), 0.2)
    out = agg_clip_reduce(stacked, w, clip=1.0, interpret=interpret)
    ref = ref_clip_reduce(stacked, w, clip=1.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_clip_reduce_kernel_rejects_disabled_clip():
    stacked = jnp.ones((3, 8))
    w = jnp.full((3,), 1.0 / 3)
    with pytest.raises(ValueError, match="clip"):
        agg_clip_reduce(stacked, w, clip=0.0)


def test_clip_noise_reduce_pallas_equals_jnp_path():
    """Both clip_noise_reduce branches (fused kernel / privatize+einsum)
    must produce the same privatized reduction, noise included."""
    key = jax.random.PRNGKey(7)
    vecs = jax.random.normal(key, (6, 513)) * 3.0
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (6,)))
    keys = jax.random.split(jax.random.fold_in(key, 2), 6)
    priv = PrivacyConfig(clip_norm=0.8, noise_multiplier=0.5)
    out_pal = clip_noise_reduce(vecs, w, keys, priv, use_pallas=True)
    out_jnp = clip_noise_reduce(vecs, w, keys, priv, use_pallas=False)
    np.testing.assert_allclose(np.asarray(out_pal), np.asarray(out_jnp),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.slow
@pytest.mark.parametrize("name", ["fedavg", "fedavgm", "fedadam",
                                  "trimmed_mean", "median", "adaptive"])
def test_private_pallas_engine_matches_jnp_per_strategy(name):
    """use_pallas_aggregation under DP routes the linear family through
    agg_clip_reduce and the robust family through privatize + the trim
    kernel; metrics must match the jnp reference for every strategy."""
    priv = PrivacyConfig(clip_norm=0.3, noise_multiplier=0.7)
    cfg = AggConfig(name=name)
    fed_jnp = _make_fed(priv, agg=cfg)
    hist_jnp = fed_jnp.run(rounds=3)
    fed_pal = _make_fed(priv, agg=cfg, use_pallas=True)
    hist_pal = fed_pal.run(rounds=3)
    np.testing.assert_allclose(hist_jnp.round_loss, hist_pal.round_loss,
                               rtol=1e-4, atol=1e-6)
    for a, b in zip(jax.tree.leaves(fed_jnp.global_params),
                    jax.tree.leaves(fed_pal.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)
    for a, b in zip(jax.tree.leaves(fed_jnp.server_state),
                    jax.tree.leaves(fed_pal.server_state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# 4. determinism + engine equivalence under DP
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ["scan", "loop"])
def test_same_seed_reproduces_run_under_subsampling_and_noise(engine):
    """Two trainers built from the same FedConfig.seed, with client
    subsampling AND DP noise, must produce identical histories: the
    noise keys fold out of the per-client training keys, which the round
    key chain derives deterministically."""
    priv = PrivacyConfig(clip_norm=0.5, noise_multiplier=1.0)
    hist_a = _make_fed(priv, batch_groups=2).run(rounds=3, engine=engine)
    hist_b = _make_fed(priv, batch_groups=2).run(rounds=3, engine=engine)
    assert hist_a.round_loss == hist_b.round_loss
    np.testing.assert_array_equal(np.stack(hist_a.eval_scores),
                                  np.stack(hist_b.eval_scores))
    assert hist_a.round_eps == hist_b.round_eps


def test_scan_matches_loop_under_noise():
    """Both drivers derive per-round keys identically, so the SAME noise
    realizations are drawn and the histories agree to float tolerance."""
    priv = PrivacyConfig(clip_norm=0.5, noise_multiplier=1.0)
    fed_scan = _make_fed(priv)
    hist_scan = fed_scan.run(rounds=3, engine="scan")
    fed_loop = _make_fed(priv)
    hist_loop = fed_loop.run(rounds=3, engine="loop")
    np.testing.assert_allclose(hist_scan.round_loss, hist_loop.round_loss,
                               rtol=1e-3, atol=1e-5)
    for a, b in zip(jax.tree.leaves(fed_scan.global_params),
                    jax.tree.leaves(fed_loop.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)


@pytest.mark.parametrize("name", ["fedavg", "median"])
def test_sharded_private_round_matches_stacked(name):
    """make_sharded_round under DP == the stacked reference with the
    same per-client keys: clip + noise happen before the collective and
    the noise realizations are identical by construction."""
    C = 5
    data = make_survey_data(SurveyConfig(
        num_groups=C, num_questions=24, d_embed=8, seed=0))
    priv = PrivacyConfig(clip_norm=0.3, noise_multiplier=0.8)
    fcfg = FedConfig(num_clients=C, local_epochs=2, lr=1e-3,
                     num_context=4, num_target=4,
                     agg=AggConfig(name=name), privacy=priv)
    opt = adam(fcfg.lr)
    agg = make_aggregator(fcfg.agg, num_clients=C)
    params = init_gpo_params(GCFG, jax.random.PRNGKey(0))
    server_state = agg.init(params)
    groups = jnp.arange(C, dtype=jnp.int32)
    weights = normalize_weights(data.sizes[groups])
    keys = jax.random.split(jax.random.PRNGKey(1), C)
    cp = broadcast_to_clients(params, C)
    opt_states = jax.vmap(opt.init)(cp)

    local_train = _make_local_train(GCFG, fcfg, data, opt)
    cp_ref, _, losses = jax.jit(jax.vmap(local_train))(
        cp, opt_states, keys, groups)
    vecs = tree_ravel_clients(tree_sub(cp_ref, cp))
    if agg.linear:
        delta_vec = clip_noise_reduce(vecs, weights, keys, priv)
    else:
        delta_vec = agg.reduce_flat(privatize_flat(vecs, keys, priv),
                                    weights)
    delta = tree_unflatten_from_vector(delta_vec, params)
    global_ref, _ = agg.apply(server_state, params, delta, losses=losses,
                              idx=None)

    mesh = jax.make_mesh((1,), ("data",))
    round_fn = make_sharded_round(GCFG, fcfg, data, mesh, opt=opt, agg=agg)
    cp_s, _, _, _ = jax.jit(round_fn)(cp, opt_states, keys, groups,
                                      weights, server_state)
    for a, b in zip(jax.tree.leaves(global_ref), jax.tree.leaves(cp_s)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b)[0],
                                   rtol=1e-4, atol=1e-5)


def test_noise_keys_are_distinct_from_training_keys():
    """The fold_in tag must yield noise independent of the local-epoch
    key chain (no key reuse between training batches and the noise)."""
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    nkeys = dp.client_noise_keys(keys)
    assert not np.any(np.all(np.asarray(nkeys) == np.asarray(keys),
                             axis=-1))
    # and distinct across clients
    assert len({tuple(np.asarray(k)) for k in nkeys}) == 4


# ---------------------------------------------------------------------------
# 5. Rényi accounting
# ---------------------------------------------------------------------------
def test_accountant_full_participation_closed_form():
    """q=1 is the plain Gaussian mechanism: RDP(α) = α/(2z²), so ε after
    one round is min_α [α/(2z²) + log(1/δ)/(α−1)] exactly."""
    z, delta = 1.0, 1e-5
    acct = RdpAccountant(z, 1.0, delta)
    expected = min(a / (2 * z * z) + math.log(1 / delta) / (a - 1)
                   for a in acct.orders)
    assert acct.epsilon(1) == pytest.approx(expected, rel=1e-12)
    assert acct.epsilon(0) == 0.0


def test_accountant_monotone_in_rounds_noise_and_sampling():
    acct = RdpAccountant(1.0, 0.25, 1e-5)
    eps = [acct.epsilon(r) for r in (1, 10, 100)]
    assert eps[0] < eps[1] < eps[2]
    # more noise -> less eps
    assert (RdpAccountant(2.0, 0.25, 1e-5).epsilon(10)
            < RdpAccountant(1.0, 0.25, 1e-5).epsilon(10))
    # subsampling amplifies: q < 1 spends less than q = 1
    assert (RdpAccountant(1.0, 0.25, 1e-5).epsilon(10)
            < RdpAccountant(1.0, 1.0, 1e-5).epsilon(10))
    # zero noise carries no guarantee
    assert RdpAccountant(0.0, 1.0, 1e-5).epsilon(5) == float("inf")


def test_accountant_composition_is_linear_in_rdp():
    """Composing r rounds multiplies the per-step RDP by r; at a fixed
    order the bound grows linearly, so ε(r) is subadditive-ish but never
    super-linear in the per-order bound: ε(2r) <= 2 ε(r) + slack from
    the log(1/δ) term being counted once instead of twice."""
    acct = RdpAccountant(1.2, 0.5, 1e-5)
    assert acct.epsilon(20) <= 2 * acct.epsilon(10)


def test_make_accountant_gating():
    assert make_accountant(PrivacyConfig(), 1.0) is None
    assert make_accountant(PrivacyConfig(clip_norm=1.0), 1.0) is None
    acct = make_accountant(
        PrivacyConfig(clip_norm=1.0, noise_multiplier=1.0), 0.5)
    assert acct is not None and acct.sampling_rate == 0.5


@pytest.mark.slow
def test_history_records_eps_stream_across_engines_and_chunks():
    """round_eps grows by one cumulative ε per round, matches the
    accountant, continues across run() calls, and is identical between
    the fused block, the chunked-logging path and the loop driver."""
    priv = PrivacyConfig(clip_norm=0.5, noise_multiplier=1.0)
    fed = _make_fed(priv, batch_groups=2)
    hist = fed.run(rounds=3)
    q = 2 / len(fed.train_groups)
    acct = RdpAccountant(1.0, q, priv.target_delta,
                         priv.accountant_orders)
    np.testing.assert_allclose(hist.round_eps,
                               [acct.epsilon(r) for r in (1, 2, 3)],
                               rtol=1e-12)
    hist2 = fed.run(rounds=2)  # continues the spend: rounds 4, 5
    np.testing.assert_allclose(hist2.round_eps,
                               [acct.epsilon(r) for r in (4, 5)],
                               rtol=1e-12)
    hist_chunked = _make_fed(priv, batch_groups=2).run(rounds=3,
                                                       log_every=2)
    np.testing.assert_allclose(hist_chunked.round_eps, hist.round_eps,
                               rtol=1e-12)
    hist_loop = _make_fed(priv, batch_groups=2).run(rounds=3,
                                                    engine="loop")
    np.testing.assert_allclose(hist_loop.round_eps, hist.round_eps,
                               rtol=1e-12)


# ---------------------------------------------------------------------------
# backbone/LoRA trainers
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_backbone_round_applies_dp_pipeline():
    """make_backbone_fedavg_round with privacy clips+noises the deltas:
    the round runs, differs from the non-private round, and a zero-clip
    config keeps the original signature (no noise_key argument)."""
    from repro.configs import get_arch, smoke_variant
    from repro.core import make_backbone_fedavg_round
    from repro.data import LMDataConfig, synthetic_lm_batches

    cfg = smoke_variant(get_arch("qwen2-0.5b"))
    from repro.models import init_params
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adam(1e-3)
    c = 2
    agg = make_aggregator(AggConfig(), num_clients=c)
    it = synthetic_lm_batches(LMDataConfig(
        vocab_size=cfg.vocab_size, seq_len=16, global_batch=2, seed=0))
    batches = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[jax.tree.map(lambda *ys: jnp.stack(ys), *[next(it)])
          for _ in range(c)])
    weights = jnp.full((c,), 0.5)
    cp = broadcast_to_clients(params, c)
    opt_states = jax.vmap(opt.init)(cp)
    server_state = agg.init(params)

    rnd_plain = make_backbone_fedavg_round(cfg, opt, 1, agg=agg)
    out_plain, _, losses_plain, _ = jax.jit(rnd_plain)(
        cp, opt_states, batches, weights, server_state)

    priv = PrivacyConfig(clip_norm=1e-3, noise_multiplier=0.5)
    rnd_priv = make_backbone_fedavg_round(cfg, opt, 1, agg=agg,
                                          privacy=priv)
    out_priv, _, losses_priv, _ = jax.jit(rnd_priv)(
        cp, opt_states, batches, weights, server_state,
        jax.random.PRNGKey(9))
    # local training is untouched; only the aggregate differs
    np.testing.assert_allclose(np.asarray(losses_plain),
                               np.asarray(losses_priv), rtol=1e-6)
    diffs = [float(jnp.max(jnp.abs(a - b))) for a, b in
             zip(jax.tree.leaves(out_plain), jax.tree.leaves(out_priv))]
    assert max(diffs) > 0.0
    # disabled privacy keeps the 5-arg signature
    rnd_off = make_backbone_fedavg_round(
        cfg, opt, 1, agg=agg, privacy=PrivacyConfig())
    out_off, _, _, _ = jax.jit(rnd_off)(
        cp, opt_states, batches, weights, server_state)
    for a, b in zip(jax.tree.leaves(out_plain), jax.tree.leaves(out_off)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_private_round_requires_aggregator():
    from repro.configs import get_arch, smoke_variant
    from repro.core import make_backbone_fedavg_round

    cfg = smoke_variant(get_arch("qwen2-0.5b"))
    with pytest.raises(ValueError, match="ServerAggregator"):
        make_backbone_fedavg_round(
            cfg, adam(1e-3), 1, agg=None,
            privacy=PrivacyConfig(clip_norm=1.0))


# ---------------------------------------------------------------------------
# adaptive aggregation x DP noise guard (DESIGN.md §9: the loss
# side-channel makes the reported epsilon an over-claim)
# ---------------------------------------------------------------------------
def test_adaptive_plus_noise_warns_on_construction():
    priv = PrivacyConfig(clip_norm=1.0, noise_multiplier=0.8)
    with pytest.warns(UserWarning, match="side-channel"):
        _make_fed(privacy=priv, agg=AggConfig(name="adaptive"))


def test_adaptive_plus_noise_strict_privacy_raises():
    data = make_survey_data(SurveyConfig(
        num_groups=6, num_questions=24, d_embed=8, seed=3))
    tr, ev = split_groups(data, seed=3)
    fcfg = FedConfig(num_clients=len(tr), rounds=2, local_epochs=1,
                     num_context=4, num_target=4, seed=3,
                     agg=AggConfig(name="adaptive"),
                     privacy=PrivacyConfig(clip_norm=1.0,
                                           noise_multiplier=0.8),
                     strict_privacy=True)
    with pytest.raises(ValueError, match="side-channel"):
        FederatedGPO(GCFG, fcfg, data, tr, ev)


def test_adaptive_guard_silent_when_benign():
    """No warning for clip-only adaptive runs (no epsilon is claimed)
    or for noised non-adaptive runs (no raw-loss side-channel)."""
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error")
        _make_fed(privacy=PrivacyConfig(clip_norm=1.0),
                  agg=AggConfig(name="adaptive"))
        _make_fed(privacy=PrivacyConfig(clip_norm=1.0,
                                        noise_multiplier=0.8))
