"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import PrivacyConfig
from repro.core import fedavg_stacked, normalize_weights
from repro.core.fairness import fairness_index, js_distance
from repro.core.privacy import clip_scales, privatize_flat
from repro.kernels import fedavg_reduce
from repro.kernels.ref import ref_fedavg_flat
from repro.models.layers import softcap

SETTINGS = dict(max_examples=25, deadline=None)


def _simplex(draw, n):
    raw = draw(st.lists(st.floats(0.01, 10.0), min_size=n, max_size=n))
    arr = np.asarray(raw)
    return arr / arr.sum()


@settings(**SETTINGS)
@given(st.data(), st.integers(2, 6))
def test_jsd_bounds_and_symmetry(data, n):
    p = jnp.asarray([_simplex(data.draw, n)])
    q = jnp.asarray([_simplex(data.draw, n)])
    d_pq = float(js_distance(p, q)[0])
    d_qp = float(js_distance(q, p)[0])
    assert 0.0 <= d_pq <= 1.0 + 1e-6
    assert abs(d_pq - d_qp) < 1e-5
    assert float(js_distance(p, p)[0]) < 1e-5


@settings(**SETTINGS)
@given(st.lists(st.floats(0.05, 1.0), min_size=2, max_size=8))
def test_fairness_index_in_unit_interval(scores):
    fi = float(fairness_index(jnp.asarray(scores)))
    assert 0.0 < fi <= 1.0 + 1e-6
    # perfect equality -> 1
    fi_eq = float(fairness_index(jnp.full(len(scores), scores[0])))
    assert abs(fi_eq - 1.0) < 1e-5


@settings(**SETTINGS)
@given(st.integers(2, 6), st.integers(1, 40), st.integers(0, 2 ** 31 - 1))
def test_fedavg_convex_hull_and_permutation(c, p, seed):
    """Eq. 3 output lies in the per-coordinate convex hull of the client
    parameters and is permutation-equivariant."""
    key = jax.random.PRNGKey(seed)
    stacked = {"w": jax.random.normal(key, (c, p))}
    sizes = jax.random.uniform(jax.random.fold_in(key, 1), (c,),
                               minval=1.0, maxval=100.0)
    w = normalize_weights(sizes)
    agg = fedavg_stacked(stacked, w)["w"]
    lo = stacked["w"].min(axis=0) - 1e-5
    hi = stacked["w"].max(axis=0) + 1e-5
    assert bool(jnp.all((agg >= lo) & (agg <= hi)))
    perm = jax.random.permutation(jax.random.fold_in(key, 2), c)
    agg_p = fedavg_stacked({"w": stacked["w"][perm]}, w[perm])["w"]
    np.testing.assert_allclose(np.asarray(agg), np.asarray(agg_p),
                               rtol=1e-5, atol=1e-6)


@settings(**SETTINGS)
@given(st.integers(2, 8), st.integers(1, 5000), st.integers(0, 2 ** 31 - 1))
def test_fedavg_kernel_matches_ref_random_shapes(c, p, seed):
    key = jax.random.PRNGKey(seed)
    stacked = jax.random.normal(key, (c, p))
    w = normalize_weights(
        jax.random.uniform(jax.random.fold_in(key, 1), (c,), minval=0.1,
                           maxval=10.0))
    out = fedavg_reduce(stacked, w)
    ref = ref_fedavg_flat(stacked, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(st.integers(1, 8), st.integers(1, 300),
       st.floats(0.05, 5.0), st.integers(0, 2 ** 31 - 1))
def test_clipped_delta_norms_never_exceed_bound(c, p, clip, seed):
    """DP pipeline invariant (DESIGN.md §9): after clipping, every
    client's flat-delta L2 norm is <= clip_norm, for any shape/scale."""
    key = jax.random.PRNGKey(seed)
    vecs = jax.random.normal(key, (c, p)) * 10.0 ** jax.random.randint(
        jax.random.fold_in(key, 1), (c, 1), -2, 4)
    keys = jax.random.split(jax.random.fold_in(key, 2), c)
    out = privatize_flat(vecs, keys, PrivacyConfig(clip_norm=clip))
    norms = np.linalg.norm(np.asarray(out), axis=1)
    assert np.all(norms <= clip * (1 + 1e-4))


@settings(**SETTINGS)
@given(st.integers(1, 6), st.integers(1, 100),
       st.floats(0.01, 1.0), st.integers(0, 2 ** 31 - 1))
def test_clipping_is_scale_equivariant_below_the_bound(c, p, s, seed):
    """For deltas that stay under the bound after scaling by s <= 1,
    clip(s * d) == s * clip(d) == s * d: clipping is a no-op on the
    whole homothety class below the bound (no hidden renormalization)."""
    key = jax.random.PRNGKey(seed)
    vecs = jax.random.normal(key, (c, p))
    # normalize so every client sits exactly at norm 1, bound above it
    vecs = vecs / jnp.maximum(
        jnp.linalg.norm(vecs, axis=1, keepdims=True), 1e-12)
    clip = 1.0 + 1e-3
    assert np.all(np.asarray(clip_scales(vecs * s, clip)) == 1.0)
    keys = jax.random.split(jax.random.fold_in(key, 1), c)
    priv = PrivacyConfig(clip_norm=clip)
    out_scaled = privatize_flat(vecs * s, keys, priv)
    out = privatize_flat(vecs, keys, priv)
    np.testing.assert_allclose(np.asarray(out_scaled),
                               np.asarray(out) * s, rtol=1e-5, atol=1e-6)


@settings(**SETTINGS)
@given(st.integers(2, 8), st.integers(1, 5000), st.floats(0.1, 3.0),
       st.integers(0, 2 ** 31 - 1))
def test_clip_reduce_kernel_matches_ref_random_shapes(c, p, clip, seed):
    from repro.kernels import agg_clip_reduce
    from repro.kernels.ref import ref_clip_reduce

    key = jax.random.PRNGKey(seed)
    stacked = jax.random.normal(key, (c, p)) * 3.0
    w = normalize_weights(
        jax.random.uniform(jax.random.fold_in(key, 1), (c,), minval=0.1,
                           maxval=10.0))
    out = agg_clip_reduce(stacked, w, clip=clip)
    ref = ref_clip_reduce(stacked, w, clip=clip)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(st.integers(1, 6), st.integers(1, 400), st.integers(0, 2 ** 31 - 1))
def test_stochastic_rounding_is_unbiased_on_uniform_grid(c, p, seed):
    """Compression invariant (DESIGN.md §10): E_υ[Q(x)] = x for the int8
    stochastic rounder. Averaging over a deterministic N-point uniform
    grid υ_j = j/N equals the expectation to within one grid step, so
    the property is exact (no statistical flakiness): the grid mean of
    dequant(⌊x/s + υ_j⌋)·s lies within s·(1/N + fp slack) of x."""
    from repro.core import dequantize_int8, quantize_int8

    key = jax.random.PRNGKey(seed)
    vecs = jax.random.normal(key, (c, p)) * 5.0
    n = 64
    grid = jnp.broadcast_to(
        (jnp.arange(n, dtype=jnp.float32) / n)[:, None, None], (n, c, p))
    q, s = jax.vmap(lambda u: quantize_int8(vecs, uniform=u))(grid)
    mean = np.asarray(jnp.mean(
        jax.vmap(dequantize_int8)(q, s), axis=0))
    _, s0 = quantize_int8(vecs)
    bound = np.asarray(s0)[:, None] * (1.0 / n + 1e-4)
    assert np.all(np.abs(mean - np.asarray(vecs)) <= bound)


@settings(**SETTINGS)
@given(st.integers(1, 6), st.integers(1, 500),
       st.sampled_from(["int8", "topk"]), st.integers(0, 2 ** 31 - 1))
def test_ef_residual_identity_and_determinism(c, p, kind, seed):
    """EF21 invariants: t + e' == d̃ + e exactly (the residual is the
    codec error, nothing more), and the transport is a deterministic
    function of (values, keys) — same inputs, same transmitted values."""
    from repro.configs import CompressionConfig
    from repro.core import compression as cx

    key = jax.random.PRNGKey(seed)
    vecs = jax.random.normal(key, (c, p))
    resid = 0.5 * jax.random.normal(jax.random.fold_in(key, 1), (c, p))
    keys = jax.random.split(jax.random.fold_in(key, 2), c)
    comp = CompressionConfig(kind=kind, topk_frac=0.1)
    t, new_r = cx.ef_compress_flat(vecs, keys, comp, resid)
    np.testing.assert_allclose(np.asarray(t + new_r),
                               np.asarray(vecs + resid),
                               rtol=1e-5, atol=1e-6)
    t2, new_r2 = cx.ef_compress_flat(vecs, keys, comp, resid)
    np.testing.assert_array_equal(np.asarray(t), np.asarray(t2))
    np.testing.assert_array_equal(np.asarray(new_r), np.asarray(new_r2))


@settings(**SETTINGS)
@given(st.integers(2, 8), st.integers(1, 1024), st.booleans(),
       st.integers(0, 2 ** 31 - 1))
def test_quant_clip_reduce_kernel_matches_ref_random_shapes(
        c, p, stochastic, seed):
    """Kernel == oracle on random shapes. p <= 1024 keeps the kernel to
    a single Pallas block, so its norm/absmax reductions are the same
    single op as the oracle's and no rounding decision can flip on
    float reassociation (multi-block coverage with a level-sized
    tolerance lives in tests/test_compression.py)."""
    from repro.core import client_uniform
    from repro.kernels import agg_quant_clip_reduce
    from repro.kernels.ref import ref_quant_clip_reduce

    key = jax.random.PRNGKey(seed)
    stacked = jax.random.normal(key, (c, p)) * 3.0
    w = normalize_weights(
        jax.random.uniform(jax.random.fold_in(key, 1), (c,), minval=0.1,
                           maxval=10.0))
    keys = jax.random.split(jax.random.fold_in(key, 2), c)
    uniform = client_uniform(keys, (c, p)) if stochastic else None
    clip = float(jnp.mean(jnp.linalg.norm(stacked, axis=1)))
    out, _ = agg_quant_clip_reduce(stacked, w, clip=clip, uniform=uniform)
    ref, _ = ref_quant_clip_reduce(stacked, w, clip=clip, uniform=uniform)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(st.integers(2, 8), st.integers(1, 3000), st.floats(0.01, 1.0),
       st.integers(0, 2 ** 31 - 1))
def test_topk_reduce_kernel_matches_ref_random_shapes(c, p, frac, seed):
    from repro.core import topk_thresholds
    from repro.kernels import agg_topk_reduce
    from repro.kernels.ref import ref_topk_reduce

    key = jax.random.PRNGKey(seed)
    stacked = jax.random.normal(key, (c, p)) * 2.0
    w = normalize_weights(
        jax.random.uniform(jax.random.fold_in(key, 1), (c,), minval=0.1,
                           maxval=10.0))
    tau = topk_thresholds(stacked, frac)
    out, er = agg_topk_reduce(stacked, w, tau, with_residual=True)
    ref, ref_er = ref_topk_reduce(stacked, w, frac=frac)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(er), np.asarray(ref_er),
                               rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(st.floats(-100.0, 100.0), st.floats(1.0, 60.0))
def test_softcap_bounded_and_monotone(x, cap):
    y = float(softcap(jnp.asarray(x), cap))
    assert abs(y) <= cap + 1e-4
    y2 = float(softcap(jnp.asarray(x + 1.0), cap))
    assert y2 >= y - 1e-6


@settings(**SETTINGS)
@given(st.integers(1, 3), st.integers(2, 32), st.integers(0, 2 ** 31 - 1))
def test_survey_preferences_are_distributions(groups, questions, seed):
    from repro.data import SurveyConfig, make_survey_data

    cfg = SurveyConfig(num_groups=groups, num_questions=questions,
                       num_options=4, d_embed=8, seed=seed % 1000)
    data = make_survey_data(cfg)
    sums = np.asarray(data.prefs.sum(-1))
    np.testing.assert_allclose(sums, np.ones_like(sums), rtol=1e-5)
    assert bool(jnp.all(data.sizes >= 1))


@settings(**SETTINGS)
@given(st.integers(5, 10), st.integers(2, 40),
       st.floats(2.0, 100.0), st.integers(0, 2 ** 31 - 1))
def test_defenses_bounded_near_honest_envelope(c, p, scale, seed):
    """Robustness invariant (DESIGN.md §13): with f attackers below the
    breakdown point shipping arbitrarily scaled rows, Krum and the
    geometric median land within a PROVABLE slack of the honest
    coordinate-wise envelope [lo, hi].

    The naive "inside the honest envelope" claim is false (an attacker
    can pull the geometric median slightly outside it), so each defense
    gets its own derived bound around the honest mean m, with
    r = max_i ||honest_i - m||:

    * Krum with nn = C − f − 2 neighbors and C − 2f − 2 ≥ 1: the
      winner's score is ≤ the best honest score ≤ nn·(2r)², and at
      least one of its nn neighbors is honest, so the selected row is
      within 2r·√nn + r of m.
    * geomedian with attacker weight fraction α < 1/2: the classic
      aggregation lemma gives ||gm − m|| ≤ 2(1−α)r/(1−2α) (plus
      Weiszfeld smoothing/iteration slack).

    Both bounds are independent of the attack ``scale`` — that is the
    robustness being asserted; fedavg's error grows linearly in it.
    """
    from repro.core.aggregation import geometric_median_flat, krum_scores

    f = (c - 3) // 2  # breakdown condition C - 2f - 2 >= 1
    key = jax.random.PRNGKey(seed)
    honest = jax.random.normal(key, (c - f, p))
    x = jnp.concatenate(
        [honest, scale * jnp.ones((f, p), jnp.float32)], axis=0)
    w = jnp.full((c,), 1.0 / c, jnp.float32)

    m = jnp.mean(honest, axis=0)
    r = float(jnp.max(jnp.linalg.norm(honest - m[None, :], axis=1)))
    lo = np.asarray(honest.min(axis=0))
    hi = np.asarray(honest.max(axis=0))

    # krum: the implementation's selection with its own nn clamp
    scores = krum_scores(x, w, f)
    sel = np.asarray(x[jnp.argmin(scores)])
    nn = max(1, c - f - 2)
    b_krum = 2.0 * r * np.sqrt(nn) + r
    assert np.all(sel >= lo - b_krum - 1e-4)
    assert np.all(sel <= hi + b_krum + 1e-4)

    # geomedian: attacker mass fraction alpha = f/c < 1/2
    alpha = f / c
    gm = np.asarray(geometric_median_flat(x, w, iters=50, eps=1e-6))
    b_gm = 2.0 * (1.0 - alpha) * r / (1.0 - 2.0 * alpha) + 0.05 * r
    assert np.all(gm >= lo - b_gm - 1e-3)
    assert np.all(gm <= hi + b_gm + 1e-3)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_adam_step_finite_and_descends_quadratic(seed):
    from repro.optim import adam

    key = jax.random.PRNGKey(seed)
    target = jax.random.normal(key, (8,))
    params = {"x": jnp.zeros(8)}
    opt = adam(0.1)
    state = opt.init(params)

    def loss_fn(p):
        return jnp.sum(jnp.square(p["x"] - target))

    l0 = float(loss_fn(params))
    for _ in range(50):
        grads = jax.grad(loss_fn)(params)
        params, state = opt.update(grads, state, params)
        assert bool(jnp.all(jnp.isfinite(params["x"])))
    assert float(loss_fn(params)) < l0
