"""Ring-buffer SWA decode (§Perf optimization) must match the baseline
full-cache decode bit-for-bit (up to float tolerance)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, override, smoke_variant
from repro.models import forward, init_params
from repro.models.transformer import (
    init_ring_cache,
    ring_cache_from_full,
    uses_ring_cache,
)

B, S, P = 2, 24, 12


def _gemma_smoke(arch):
    cfg = smoke_variant(get_arch(arch))
    # at least one full local:global period (+ a tail layer to cover the
    # unrolled-tail path), small windows, ring caches on
    n_layers = len(cfg.window_pattern) + 1
    return override(cfg, ring_cache=True, num_layers=n_layers)


@pytest.mark.parametrize("arch", ["gemma3-27b", "gemma2-27b"])
def test_ring_decode_matches_baseline(arch):
    key = jax.random.PRNGKey(2)
    cfg_ring = _gemma_smoke(arch)
    cfg_base = override(cfg_ring, ring_cache=False)
    assert uses_ring_cache(cfg_ring)
    params = init_params(cfg_base, key)
    tokens = jax.random.randint(key, (B, S), 0, cfg_base.vocab_size)

    # baseline: standard prefill + full-cache decode
    _, full_cache, _ = forward(params, cfg_base, tokens=tokens[:, :P],
                               prefill_len=S)
    # ring: convert the prefill cache, then decode with ring semantics
    ring_cache = ring_cache_from_full(cfg_ring, full_cache, P - 1, B, S)

    base_outs, ring_outs = [], []
    cache_b, cache_r = full_cache, ring_cache
    for t in range(P, S):
        lb, cache_b, _ = forward(params, cfg_base, tokens=tokens[:, t:t + 1],
                                 cache=cache_b,
                                 cache_pos=jnp.asarray(t, jnp.int32))
        lr, cache_r, _ = forward(params, cfg_ring, tokens=tokens[:, t:t + 1],
                                 cache=cache_r,
                                 cache_pos=jnp.asarray(t, jnp.int32))
        base_outs.append(lb[:, 0])
        ring_outs.append(lr[:, 0])
    base = jnp.stack(base_outs, 1)
    ring = jnp.stack(ring_outs, 1)
    rel = float(jnp.max(jnp.abs(base - ring))) / (
        float(jnp.max(jnp.abs(base))) + 1e-9)
    assert rel < 1e-4, f"{arch}: rel={rel}"


def test_ring_cache_memory_footprint():
    """The ring cache must be much smaller than the full cache for a
    local-dominated pattern (the point of the optimization)."""
    cfg = override(get_arch("gemma3-27b"), ring_cache=True)
    max_seq = 32768
    ring = jax.eval_shape(lambda: init_ring_cache(cfg, 1, max_seq))
    full_elems = cfg.num_layers * max_seq  # per (B, KV, hd) unit
    ring_elems = sum(
        int(np.prod(v.shape)) for v in jax.tree.leaves(ring)
    ) // (2 * cfg.num_kv_heads * cfg.head_dim)  # k+v
    assert ring_elems < 0.3 * full_elems, (ring_elems, full_elems)
