"""Fused scan round driver == per-round loop driver, and the Pallas
aggregation path == the jnp weighted sum, over multi-round runs.

The two drivers share one round_step and derive RNG keys identically, so
their History metrics and final parameters must agree to float
tolerance (they differ only in how XLA schedules the same ops).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import AggConfig, FedConfig, GPOConfig
from repro.core import FederatedGPO, fedavg_stacked, make_aggregator
from repro.core.federated import make_sharded_round, _make_local_train
from repro.core.fedavg import broadcast_to_clients, normalize_weights
from repro.core.gpo import init_gpo_params
from repro.data import SurveyConfig, make_survey_data, split_groups
from repro.optim import adam

GCFG = GPOConfig(d_embed=24, d_model=48, num_layers=2, num_heads=4, d_ff=96)


def _make_fed(batch_groups=0, use_pallas_aggregation=False, seed=5,
              agg=AggConfig(), use_pallas_attention=None):
    data = make_survey_data(SurveyConfig(
        num_groups=8, num_questions=40, d_embed=24, seed=seed))
    tr, ev = split_groups(data, seed=seed)
    fcfg = FedConfig(num_clients=len(tr), rounds=4, local_epochs=2,
                     eval_every=2, num_context=6, num_target=6,
                     batch_groups=batch_groups,
                     use_pallas_aggregation=use_pallas_aggregation,
                     use_pallas_attention=use_pallas_attention,
                     agg=agg, seed=seed)
    return FederatedGPO(GCFG, fcfg, data, tr, ev)


def _assert_hist_close(ha, hb, tol=dict(rtol=2e-4, atol=1e-5)):
    np.testing.assert_allclose(ha.round_loss, hb.round_loss, **tol)
    assert ha.eval_rounds == hb.eval_rounds
    np.testing.assert_allclose(np.stack(ha.eval_scores),
                               np.stack(hb.eval_scores), rtol=2e-4,
                               atol=1e-4)
    np.testing.assert_allclose(ha.eval_mean_as, hb.eval_mean_as,
                               rtol=2e-4, atol=1e-4)
    np.testing.assert_allclose(ha.eval_fi, hb.eval_fi, rtol=2e-4, atol=1e-4)


@pytest.mark.parametrize("batch_groups", [0, 3],
                         ids=["full_participation", "partial_participation"])
def test_scan_engine_matches_loop(batch_groups):
    fed_loop = _make_fed(batch_groups)
    hist_loop = fed_loop.run(rounds=4, engine="loop")
    fed_scan = _make_fed(batch_groups)
    hist_scan = fed_scan.run(rounds=4, engine="scan")

    _assert_hist_close(hist_loop, hist_scan)
    for a, b in zip(jax.tree.leaves(fed_loop.global_params),
                    jax.tree.leaves(fed_scan.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)
    # optimizer states advanced identically too (donated buffers returned)
    for a, b in zip(jax.tree.leaves(fed_loop.opt_states),
                    jax.tree.leaves(fed_scan.opt_states)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)


def test_scan_engine_chunked_logging_matches_single_block(capsys):
    """log_every chunks the scan into blocks; the RNG chain threads
    through the carried key, so metrics must equal the one-block run."""
    hist_one = _make_fed().run(rounds=4)
    hist_chunked = _make_fed().run(rounds=4, log_every=2)
    _assert_hist_close(hist_one, hist_chunked)
    assert "[fed] round" in capsys.readouterr().out  # logging still live


def test_scan_engine_chunk_remainder_matches_single_block():
    """rounds not divisible by log_every: the tail runs per-round on the
    same key chain instead of recompiling the fused block."""
    fed_one = _make_fed()
    hist_one = fed_one.run(rounds=3)
    fed_rem = _make_fed()
    hist_rem = fed_rem.run(rounds=3, log_every=2)  # chunk of 2 + tail of 1
    _assert_hist_close(hist_one, hist_rem)
    for a, b in zip(jax.tree.leaves(fed_one.global_params),
                    jax.tree.leaves(fed_rem.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)


def test_run_zero_rounds_returns_empty_history():
    """FedConfig(rounds=0) + run() must return an empty History (the
    pre-scan loop driver's behavior), not crash building the eval mask."""
    data = make_survey_data(SurveyConfig(
        num_groups=8, num_questions=40, d_embed=24, seed=5))
    tr, ev = split_groups(data, seed=5)
    fcfg = FedConfig(num_clients=len(tr), rounds=0, local_epochs=1,
                     num_context=6, num_target=6)
    fed = FederatedGPO(GCFG, fcfg, data, tr, ev)
    for engine in ("scan", "loop"):
        hist = fed.run(engine=engine)
        assert hist.round_loss == [] and hist.eval_rounds == []


def test_scan_engine_is_default_and_resumable():
    fed = _make_fed()
    hist1 = fed.run(rounds=3)  # FedConfig.engine == "scan"
    assert len(hist1.round_loss) == 3
    assert hist1.eval_rounds == [0, 2]
    # a second block continues from the advanced state without error
    hist2 = fed.run(rounds=3)
    assert len(hist2.round_loss) == 3
    assert np.mean(hist2.round_loss) < np.mean(hist1.round_loss)


def test_scan_carries_server_optimizer_state():
    """Stateful server aggregation (fedadam) rides the fused scan carry:
    both drivers advance the same moments, chunked logging does not
    perturb them, and a second ``run`` resumes from the carried state."""
    agg = AggConfig(name="fedadam", beta1=0.9, beta2=0.99, tau=1e-2,
                    server_lr=0.1)
    fed_scan = _make_fed(agg=agg)
    hist_scan = fed_scan.run(rounds=4, engine="scan")
    fed_loop = _make_fed(agg=agg)
    hist_loop = fed_loop.run(rounds=4, engine="loop")
    _assert_hist_close(hist_scan, hist_loop)
    for a, b in zip(jax.tree.leaves(fed_scan.server_state),
                    jax.tree.leaves(fed_loop.server_state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)
    assert int(fed_scan.server_state.step) == 4
    fed_scan.run(rounds=3, log_every=2)  # chunked block + tail round
    assert int(fed_scan.server_state.step) == 7


@pytest.mark.slow
def test_scan_engine_matches_loop_with_pallas_attention():
    """Both round drivers differentiate THROUGH the banded custom-VJP
    attention kernels (DESIGN.md §8) when the runtime override is set:
    scan == loop, and both == the dense-attention run (same math)."""
    fed_loop = _make_fed(use_pallas_attention=True)
    assert fed_loop.gpo_cfg.use_pallas_attention  # override reached cfg
    hist_loop = fed_loop.run(rounds=3, engine="loop")
    fed_scan = _make_fed(use_pallas_attention=True)
    hist_scan = fed_scan.run(rounds=3, engine="scan")
    _assert_hist_close(hist_loop, hist_scan)
    for a, b in zip(jax.tree.leaves(fed_loop.global_params),
                    jax.tree.leaves(fed_scan.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)
    hist_dense = _make_fed().run(rounds=3, engine="scan")
    _assert_hist_close(hist_dense, hist_scan,
                       tol=dict(rtol=1e-3, atol=1e-4))


def test_interrupted_scan_block_recovers_donated_state():
    """A failure mid fused-scan block after the donated buffers were
    consumed (preemption, OOM, Ctrl-C) must not brick the trainer:
    ``run`` re-raises, rebuilds the opt states / EF residual / fault
    state from the still-valid global params, and the next ``run``
    trains normally."""
    from repro.configs import AvailabilityConfig, CompressionConfig

    data = make_survey_data(SurveyConfig(
        num_groups=8, num_questions=40, d_embed=24, seed=5))
    tr, ev = split_groups(data, seed=5)
    fcfg = FedConfig(
        num_clients=len(tr), rounds=4, local_epochs=1, eval_every=2,
        num_context=6, num_target=6, seed=5,
        compression=CompressionConfig(kind="int8"),
        avail=AvailabilityConfig(online_prob=0.8, crash_prob=0.1,
                                 straggler_prob=0.2, max_staleness=3))
    fed = FederatedGPO(GCFG, fcfg, data, tr, ev)
    hist1 = fed.run(rounds=2, engine="scan")
    assert len(hist1.round_loss) == 2

    real_block = fed._block

    def dying_block(g, opt_s, resid, fault, srv, key, mask):
        # the jit consumed its donated arguments, then the host died
        jax.tree.map(lambda x: x.delete(), opt_s)
        resid.delete()
        jax.tree.map(lambda x: x.delete(), fault)
        raise RuntimeError("simulated preemption mid-block")

    fed._block = dying_block
    with pytest.raises(RuntimeError, match="simulated preemption"):
        fed.run(rounds=2, engine="scan")
    fed._block = real_block

    # every donated buffer was rebuilt (nothing still points at freed
    # device memory), EF restarts at zero, the in-flight buffer is empty
    for leaf in (jax.tree.leaves(fed.opt_states) + [fed.ef_resid]
                 + jax.tree.leaves(fed.fault_state)):
        assert not leaf.is_deleted()
    assert not np.asarray(fed.ef_resid).any()
    assert not np.asarray(fed.fault_state.pending).any()

    hist2 = fed.run(rounds=2, engine="scan")
    assert len(hist2.round_loss) == 2
    assert np.all(np.isfinite(hist2.round_loss))


def test_pallas_aggregation_round_path_matches_stacked():
    hist_jnp = _make_fed().run(rounds=4)
    fed_pal = _make_fed(use_pallas_aggregation=True)
    hist_pal = fed_pal.run(rounds=4)
    _assert_hist_close(hist_jnp, hist_pal, tol=dict(rtol=1e-4, atol=1e-5))


def test_sharded_round_pallas_aggregation_wiring():
    """make_sharded_round with use_pallas_aggregation on a 1-device mesh
    must equal the plain vmap round + fedavg_stacked aggregation."""
    C = 4
    data = make_survey_data(SurveyConfig(
        num_groups=C, num_questions=30, d_embed=16, seed=0))
    gcfg = GPOConfig(d_embed=16, d_model=32, num_layers=1, num_heads=2,
                     d_ff=32)
    fcfg = FedConfig(num_clients=C, local_epochs=2, lr=1e-3,
                     num_context=6, num_target=6,
                     use_pallas_aggregation=True)
    opt = adam(fcfg.lr)
    params = init_gpo_params(gcfg, jax.random.PRNGKey(0))
    groups = jnp.arange(C, dtype=jnp.int32)
    weights = normalize_weights(data.sizes[groups])
    keys = jax.random.split(jax.random.PRNGKey(1), C)
    client_params = broadcast_to_clients(params, C)
    opt_states = jax.vmap(opt.init)(client_params)

    local_train = _make_local_train(gcfg, fcfg, data, opt)
    cp_ref, _, losses_ref = jax.jit(jax.vmap(local_train))(
        client_params, opt_states, keys, groups)
    global_ref = fedavg_stacked(cp_ref, weights)

    mesh = jax.make_mesh((1,), ("data",))
    round_fn = make_sharded_round(gcfg, fcfg, data, mesh, opt=opt)
    agg = make_aggregator(fcfg.agg, num_clients=C,
                          use_pallas=fcfg.use_pallas_aggregation)
    srv = agg.init(params)
    cp_s, _, losses_s, _ = jax.jit(round_fn)(
        client_params, opt_states, keys, groups, weights, srv)

    np.testing.assert_allclose(np.asarray(losses_ref), np.asarray(losses_s),
                               rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(global_ref), jax.tree.leaves(cp_s)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b)[0],
                                   rtol=1e-4, atol=1e-5)
