"""Serving-engine invariants (DESIGN.md §12): prefix-split exactness,
ragged-batch equivalence, cache hit==miss numerics, int8 tolerance,
deterministic scheduling, admission, and the checkpoint restore contract.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import (
    restore_checkpoint,
    restore_checkpoint_quantized,
    save_checkpoint,
)
from repro.configs import FedConfig, GPOConfig, ServeConfig
from repro.core import (
    FederatedGPO,
    GPOPrefix,
    PreferenceServer,
    Request,
    gpo_apply,
    gpo_decode,
    gpo_prefill,
    init_gpo_params,
    make_request_trace,
    predict_preferences,
    quantize_gpo_params,
)
from repro.data import SurveyConfig, make_survey_data, split_groups
from repro.kernels import (
    QuantizedLinear,
    dequantize_linear,
    int8_matmul,
    quantize_linear,
)
from repro.kernels.ref import ref_int8_matmul

CFG = GPOConfig(d_embed=16, d_model=32, num_layers=2, num_heads=4, d_ff=64)
SCFG = ServeConfig(max_batch=4, batch_buckets=(1, 2, 4),
                   ctx_buckets=(20, 40), tgt_buckets=(10, 20),
                   cache_entries=16)


def _params(key=0, scale=1.0):
    p = init_gpo_params(CFG, jax.random.PRNGKey(key))
    return jax.tree.map(lambda a: a * scale, p) if scale != 1.0 else p


def _icl(key, m=6, t=10):
    kx, ky, kt = jax.random.split(jax.random.PRNGKey(key), 3)
    ctx_x = jax.random.normal(kx, (m, CFG.d_embed))
    ctx_y = jax.random.uniform(ky, (m,))
    tgt_x = jax.random.normal(kt, (t, CFG.d_embed))
    return ctx_x, ctx_y, tgt_x


# ---------------------------------------------------------------------------
# prefix split
# ---------------------------------------------------------------------------
def test_prefill_decode_matches_monolithic():
    """The neural-process mask makes the context encoding target-
    independent, so prefill+decode must reproduce gpo_apply."""
    params = _params(0)
    ctx_x, ctx_y, tgt_x = _icl(1)
    mu_ref, _ = gpo_apply(params, CFG, ctx_x, ctx_y, tgt_x)
    prefix = gpo_prefill(params, CFG, ctx_x, ctx_y)
    mu_split, _ = gpo_decode(params, CFG, prefix, tgt_x)
    assert prefix.k.shape == (CFG.num_layers, 6, CFG.num_heads,
                              CFG.d_model // CFG.num_heads)
    np.testing.assert_allclose(np.asarray(mu_split), np.asarray(mu_ref),
                               rtol=1e-5, atol=1e-6)


def test_prefill_padded_ctx_len_equivalence():
    """Padding context rows past ctx_len must not change predictions —
    the masked padded keys never participate as attention keys."""
    params = _params(0)
    ctx_x, ctx_y, tgt_x = _icl(2, m=6)
    prefix = gpo_prefill(params, CFG, ctx_x, ctx_y)
    mu_ref, _ = gpo_decode(params, CFG, prefix, tgt_x)
    pad_x = jnp.concatenate([ctx_x, jnp.full((5, CFG.d_embed), 7.0)])
    pad_y = jnp.concatenate([ctx_y, jnp.full((5,), -3.0)])
    prefix_p = gpo_prefill(params, CFG, pad_x, pad_y, ctx_len=6)
    mu_pad, _ = gpo_decode(params, CFG, prefix_p, tgt_x, ctx_len=6)
    np.testing.assert_allclose(np.asarray(mu_pad), np.asarray(mu_ref),
                               rtol=1e-5, atol=1e-6)


def test_decode_matches_monolithic_under_vmap():
    params = _params(0)
    batches = [_icl(k, m=6, t=10) for k in range(3, 6)]
    cx = jnp.stack([b[0] for b in batches])
    cy = jnp.stack([b[1] for b in batches])
    tx = jnp.stack([b[2] for b in batches])
    prefix = jax.vmap(lambda a, b: gpo_prefill(params, CFG, a, b))(cx, cy)
    mu = jax.vmap(lambda k, v, t: gpo_decode(
        params, CFG, GPOPrefix(k=k, v=v), t)[0])(prefix.k, prefix.v, tx)
    for i, (a, b, t) in enumerate(batches):
        ref, _ = gpo_apply(params, CFG, a, b, t)
        np.testing.assert_allclose(np.asarray(mu[i]), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# int8 quantization + kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n", [(7, 16, 5), (64, 128, 64),
                                   (130, 200, 257), (1, 8, 1)])
def test_int8_matmul_matches_oracle(m, k, n):
    kx, kw = jax.random.split(jax.random.PRNGKey(m * 1000 + n), 2)
    x = jax.random.normal(kx, (m, k))
    ql = quantize_linear(jax.random.normal(kw, (k, n)))
    got = int8_matmul(x, ql.q, ql.scale)
    want = ref_int8_matmul(x, ql.q, ql.scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_quantize_linear_roundtrip_error_bound():
    """Symmetric per-output-channel int8: dequant error per element is at
    most half a quantization step of that column."""
    w = jax.random.normal(jax.random.PRNGKey(0), (96, 48))
    ql = quantize_linear(w)
    assert ql.q.dtype == jnp.int8 and ql.scale.shape == (48,)
    err = np.abs(np.asarray(dequantize_linear(ql)) - np.asarray(w))
    step = np.asarray(ql.scale)[None, :]
    assert (err <= 0.5 * step + 1e-7).all()


def test_quantize_gpo_params_structure():
    """Only dense matmul weights become QuantizedLinear; stacked norm
    scales stay f32 and the tree still drives gpo_apply (via _mm)."""
    params = _params(0)
    qp = quantize_gpo_params(params)
    assert isinstance(qp["in_proj"], QuantizedLinear)
    assert isinstance(qp["head"], QuantizedLinear)
    assert isinstance(qp["layers"].wq, QuantizedLinear)
    assert qp["layers"].wq.q.shape[0] == CFG.num_layers  # stacked axis
    assert qp["layers"].ln1.dtype == jnp.float32
    assert not isinstance(qp["layers"].ln1, QuantizedLinear)
    assert qp["final_norm"].dtype == jnp.float32
    ctx_x, ctx_y, tgt_x = _icl(7)
    mu_q, _ = gpo_apply(qp, CFG, ctx_x, ctx_y, tgt_x)
    mu_f, _ = gpo_apply(params, CFG, ctx_x, ctx_y, tgt_x)
    assert np.isfinite(np.asarray(mu_q)).all()
    # int8 weights perturb, but do not destroy, the f32 prediction
    assert 0.0 < np.abs(np.asarray(mu_q) - np.asarray(mu_f)).max() < 0.25


def test_int8_predictions_within_tolerance():
    """The documented serving tolerance (DESIGN.md §12): int8 preference
    rows stay within 0.05 max-abs of f32 on normalized outputs."""
    params = _params(0)
    ctx_x, ctx_y, tgt_x = _icl(8, m=6, t=10)
    f32 = predict_preferences(params, CFG, ctx_x, ctx_y, tgt_x,
                              num_options=5)
    q = predict_preferences(quantize_gpo_params(params), CFG, ctx_x,
                            ctx_y, tgt_x, num_options=5)
    rows = np.asarray(q)
    np.testing.assert_allclose(rows.sum(-1), 1.0, rtol=1e-5)
    assert np.abs(rows - np.asarray(f32)).max() < 0.05


# ---------------------------------------------------------------------------
# engine: batching, cache, scheduling, admission
# ---------------------------------------------------------------------------
def _request(rid, key, m=6, t=10, prefix_key=None):
    ctx_x, ctx_y, tgt_x = _icl(key, m=m, t=t)
    return Request(rid=rid, ctx_x=np.asarray(ctx_x),
                   ctx_y=np.asarray(ctx_y), tgt_x=np.asarray(tgt_x),
                   prefix_key=prefix_key)


def test_ragged_batch_equals_one_at_a_time():
    """A fused ragged batch must produce the same rows as serving each
    request alone (padding + bucketing are numerically invisible)."""
    params = _params(0, scale=2.0)  # avoid clip-saturated uniform rows
    reqs = [_request(0, 10, m=6, t=10), _request(1, 11, m=14, t=5),
            _request(2, 12, m=3, t=8)]
    srv = PreferenceServer(params, CFG, SCFG, num_options=5)
    for r in reqs:
        srv.submit(r)
    batched = {c.rid: c.pred for c in srv.step()}
    assert len(srv.batches) == 1 and srv.batches[0].batch_pad == 4
    solo_cfg = ServeConfig(max_batch=1, batch_buckets=(1,),
                           ctx_buckets=(20, 40), tgt_buckets=(10, 20),
                           cache_entries=0)
    for r in reqs:
        solo = PreferenceServer(params, CFG, solo_cfg, num_options=5)
        solo.submit(r)
        np.testing.assert_allclose(solo.step()[0].pred, batched[r.rid],
                                   rtol=1e-5, atol=1e-6)
        assert batched[r.rid].shape == (r.tgt_x.shape[0] // 5, 5)


def test_engine_matches_predict_preferences():
    params = _params(0, scale=2.0)
    r = _request(0, 20)
    srv = PreferenceServer(params, CFG, SCFG, num_options=5)
    srv.submit(r)
    pred = srv.step()[0].pred
    ref = predict_preferences(params, CFG, r.ctx_x, r.ctx_y, r.tgt_x,
                              num_options=5)
    np.testing.assert_allclose(pred, np.asarray(ref), rtol=1e-5,
                               atol=1e-6)


def test_prefix_cache_hit_bit_equal_to_miss():
    """The cache stores the prefill output at the request's own ctx
    bucket, so a hit replays the identical decode inputs: bit-equal."""
    params = _params(0, scale=2.0)
    srv = PreferenceServer(params, CFG, SCFG, num_options=5)
    a = _request(0, 30, prefix_key="g7")
    b = _request(1, 30, prefix_key="g7")  # same context, fresh arrival
    srv.submit(a)
    cold = srv.step()[0]
    srv.submit(b)
    warm = srv.step()[0]
    assert not cold.cache_hit and warm.cache_hit
    assert srv.stats.cache_hits == 1 and srv.stats.cache_misses == 1
    assert srv.stats.prefills == 1  # the hit skipped prefill entirely
    assert np.array_equal(cold.pred, warm.pred)


def test_prefix_cache_hit_independent_of_batch_composition():
    """Prefill-at-own-bucket: the cached entry (and thus a hit's result)
    must not depend on which other requests shared the cold batch."""
    params = _params(0, scale=2.0)
    probe = _request(99, 40, m=6, t=10, prefix_key="shared")

    def serve_after_cold_batch(extra_ctx_len):
        srv = PreferenceServer(params, CFG, SCFG, num_options=5)
        srv.submit(_request(0, 41, m=6, t=10, prefix_key="shared"))
        srv.submit(_request(1, 42, m=extra_ctx_len, t=5))
        srv.step()
        srv.submit(probe)
        return srv.step()[0]

    small = serve_after_cold_batch(3)   # cold batch padded to ctx 20
    large = serve_after_cold_batch(15)  # cold batch padded to ctx 20 too
    assert small.cache_hit and large.cache_hit
    assert np.array_equal(small.pred, large.pred)


def test_cache_lru_eviction():
    cfg = ServeConfig(max_batch=1, batch_buckets=(1,), ctx_buckets=(20,),
                      tgt_buckets=(10, 20), cache_entries=2)
    srv = PreferenceServer(_params(0), CFG, cfg, num_options=5)
    for i, key in enumerate(["a", "b", "c"]):
        srv.submit(_request(i, 50 + i, prefix_key=key))
        srv.step()
    assert srv.stats.evictions == 1
    srv.submit(_request(3, 50, prefix_key="a"))  # evicted -> miss again
    srv.step()
    assert srv.stats.cache_hits == 0 and srv.stats.cache_misses == 4


def test_scheduler_deterministic_batch_composition():
    """A fixed arrival trace yields a fixed batch composition — FIFO
    order, bucket choices, pad sizes, and hit flags are all replayed."""
    data = make_survey_data(SurveyConfig(num_groups=6, num_questions=40))
    trace = make_request_trace(data, list(range(6)), num_requests=13,
                               hit_ratio=0.4, seed=5)
    params = init_gpo_params(GPOConfig(d_embed=data.phi.shape[-1]),
                             jax.random.PRNGKey(0))

    def run():
        srv = PreferenceServer(
            params, GPOConfig(d_embed=data.phi.shape[-1]),
            ServeConfig(max_batch=4, batch_buckets=(1, 2, 4),
                        ctx_buckets=(40, 80), tgt_buckets=(20, 40)),
            num_options=data.num_options)
        srv.run_trace(trace)
        return srv.batches

    first, second = run(), run()
    assert first == second
    assert [b.rids for b in first] == [
        (0, 1, 2, 3), (4, 5, 6, 7), (8, 9, 10, 11), (12,)]
    assert first[-1].batch_pad == 1


def test_admission_rejects_when_queue_full():
    cfg = ServeConfig(max_queue=2, ctx_buckets=(20,), tgt_buckets=(10,))
    srv = PreferenceServer(_params(0), CFG, cfg, num_options=5)
    results = [srv.submit(_request(i, 60 + i)) for i in range(5)]
    assert results == [True, True, False, False, False]
    assert srv.stats.rejected == 3 and srv.queue_depth == 2
    srv.step()  # drains the queue, admitting again
    assert srv.submit(_request(9, 69))


def test_request_trace_hit_ratio_and_shapes():
    data = make_survey_data(SurveyConfig(num_groups=6, num_questions=40))
    trace = make_request_trace(data, [0, 1, 2], num_requests=20,
                               hit_ratio=0.75, rate=100.0, seed=1)
    assert len(trace) == 20
    assert len({r.prefix_key for r in trace}) == 5  # ceil(0.25 * 20)
    for r in trace:
        assert r.ctx_x.shape[0] % data.num_options == 0
        assert r.tgt_x.shape[0] % data.num_options == 0
        assert r.ctx_x.shape[0] == r.ctx_y.shape[0]
    arrivals = [r.arrival for r in trace]
    assert arrivals == sorted(arrivals) and arrivals[1] == pytest.approx(0.01)


def test_serve_config_validation():
    with pytest.raises(ValueError):
        ServeConfig(ctx_buckets=()).validate()
    with pytest.raises(ValueError):
        ServeConfig(ctx_buckets=(40, 40)).validate()
    with pytest.raises(ValueError):
        ServeConfig(max_batch=16, batch_buckets=(1, 8)).validate()
    with pytest.raises(ValueError):
        # tgt bucket not a multiple of num_options
        PreferenceServer(_params(0), CFG,
                         ServeConfig(tgt_buckets=(7,)), num_options=5)


# ---------------------------------------------------------------------------
# checkpoint restore contract
# ---------------------------------------------------------------------------
def test_restore_roundtrip_served_outputs_bit_equal(tmp_path):
    """Train briefly, checkpoint, restore: the served predictions must be
    bit-equal to the post-train ones (the serving contract)."""
    data = make_survey_data(SurveyConfig(num_groups=6, num_questions=40))
    tr, ev = split_groups(data)
    gcfg = GPOConfig(d_embed=data.phi.shape[-1])
    fed = FederatedGPO(gcfg, FedConfig(num_clients=len(tr), rounds=2),
                       data, tr, ev)
    fed.run(rounds=2)
    params = fed.global_params
    path = save_checkpoint(str(tmp_path), 2, params)
    like = init_gpo_params(gcfg, jax.random.PRNGKey(0))
    restored = restore_checkpoint(path, like)

    trace = make_request_trace(data, list(ev), num_requests=4, seed=9)
    scfg = ServeConfig(ctx_buckets=(40, 80), tgt_buckets=(20, 40))

    def serve(p):
        srv = PreferenceServer(p, gcfg, scfg,
                               num_options=data.num_options)
        return {c.rid: c.pred for c in srv.run_trace(trace)}

    before, after = serve(params), serve(restored)
    for rid in before:
        assert np.array_equal(before[rid], after[rid])


def test_restore_quantized_leaf_types(tmp_path):
    params = _params(0)
    path = save_checkpoint(str(tmp_path), 1, params)
    qp = restore_checkpoint_quantized(path, params)
    assert isinstance(qp["head"], QuantizedLinear)
    assert qp["layers"].w1.q.dtype == jnp.int8
    assert qp["layers"].ln2.dtype == jnp.float32
    mu, _ = gpo_apply(qp, CFG, *_icl(3))
    assert np.isfinite(np.asarray(mu)).all()


def test_serve_restore_missing_checkpoint_clear_error(tmp_path):
    from repro.launch.serve import _restore_params

    with pytest.raises(SystemExit, match="no checkpoint under"):
        _restore_params(str(tmp_path / "empty"), CFG, seed=0)


def test_serve_restore_corrupt_checkpoint_clear_error(tmp_path):
    from repro.launch.serve import _restore_params

    (tmp_path / "ckpt_00000001.npz").write_bytes(b"not a real npz")
    with pytest.raises(SystemExit, match="unreadable or does not match"):
        _restore_params(str(tmp_path), CFG, seed=0)


def test_serve_restore_shape_mismatch_clear_error(tmp_path):
    from repro.launch.serve import _restore_params

    other = init_gpo_params(
        GPOConfig(d_embed=16, d_model=64, num_layers=2, num_heads=4,
                  d_ff=64), jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 1, other)
    with pytest.raises(SystemExit, match="does not match"):
        _restore_params(str(tmp_path), CFG, seed=0)


def test_serve_restore_flipped_byte_clear_error(tmp_path):
    """Silent corruption AFTER a durable save: the CRC32 content check
    fails as ValueError inside restore_checkpoint and rides
    _restore_params' actionable SystemExit path."""
    from repro.launch.serve import _restore_params

    params = init_gpo_params(CFG, jax.random.PRNGKey(0))
    path = save_checkpoint(str(tmp_path), 1, params)
    raw = bytearray(open(path, "rb").read())
    raw[len(raw) // 2] ^= 0xFF
    with open(path, "wb") as f:
        f.write(bytes(raw))
    with pytest.raises(SystemExit, match="unreadable or does not match"):
        _restore_params(str(tmp_path), CFG, seed=0)


# ---------------------------------------------------------------------------
# per-request deadlines (DESIGN.md §12)
# ---------------------------------------------------------------------------
def test_expired_head_of_line_requests_dropped():
    """Queued requests whose deadline already passed must be dropped at
    dispatch — counted in stats.expired, never decoded, never completed
    — while live requests behind them still serve."""
    srv = PreferenceServer(_params(0), CFG, SCFG, num_options=5)
    dead = [_request(i, 30 + i) for i in range(2)]
    for r in dead:
        r.deadline = -1.0  # already expired on the engine clock
        srv.submit(r)
    live = _request(7, 40)
    live.deadline = srv.now() + 60.0  # comfortably in the future
    srv.submit(live)
    out = srv.step()
    assert [c.rid for c in out] == [7]
    assert srv.stats.expired == 2
    assert srv.stats.completed == 1
    # the dropped rids never reached a batch record
    assert all(0 not in b.rids and 1 not in b.rids for b in srv.batches)


def test_expired_mid_queue_requests_dropped():
    """Regression: expiry once only checked the HEAD of the queue
    (``_queue[0]``), so an expired request sitting behind a fresh head
    was still decoded and returned after its deadline. Batch assembly
    must skip expired entries ANYWHERE in the queue (counted in
    stats.expired, never decoded) while the live requests keep strict
    FIFO order — the no-reorder determinism contract."""
    srv = PreferenceServer(_params(0), CFG, SCFG, num_options=5)
    head = _request(0, 70)
    head.deadline = srv.now() + 60.0  # fresh head shields the queue
    srv.submit(head)
    stale = _request(1, 71)
    stale.deadline = -1.0  # already expired, BEHIND the fresh head
    srv.submit(stale)
    srv.submit(_request(2, 72))  # no deadline: live
    out = srv.step()
    assert [c.rid for c in out] == [0, 2]  # FIFO among live requests
    assert srv.stats.expired == 1
    assert srv.stats.completed == 2
    assert all(1 not in b.rids for b in srv.batches)


def test_deadline_none_never_expires():
    """Requests without a deadline keep the pre-deadline behavior
    exactly: nothing is dropped, stats.expired stays 0."""
    srv = PreferenceServer(_params(0), CFG, SCFG, num_options=5)
    for i in range(3):
        srv.submit(_request(i, 50 + i))
    out = srv.step()
    assert sorted(c.rid for c in out) == [0, 1, 2]
    assert srv.stats.expired == 0


def test_all_expired_queue_drains_without_batch():
    """A queue of only-expired work drains to nothing: step() returns []
    and dispatches no batch (no decode slot is wasted)."""
    srv = PreferenceServer(_params(0), CFG, SCFG, num_options=5)
    for i in range(3):
        r = _request(i, 55 + i)
        r.deadline = -1.0
        srv.submit(r)
    assert srv.step() == []
    assert srv.stats.expired == 3 and not srv.batches
    assert srv.queue_depth == 0
