"""Distribution correctness of the paper's technique: the shard_map
client-parallel FedAvg round must equal the vmap simulation bit-for-bit
(up to float tolerance).

Runs in a subprocess with 8 forced host devices so the main test process
keeps its single-device view.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import FedConfig, GPOConfig
from repro.core import (broadcast_to_clients, fedavg_stacked,
                        make_aggregator, normalize_weights)
from repro.core.federated import _make_local_train, make_sharded_round
from repro.core.gpo import init_gpo_params
from repro.data import SurveyConfig, make_survey_data
from repro.launch.sharding import server_state_shardings
from repro.optim import adam

C = 8
data = make_survey_data(SurveyConfig(num_groups=C, num_questions=30,
                                     d_embed=16, seed=0))
gcfg = GPOConfig(d_embed=16, d_model=32, num_layers=1, num_heads=2, d_ff=32)
fcfg = FedConfig(num_clients=C, local_epochs=2, lr=1e-3,
                 num_context=6, num_target=6)
opt = adam(fcfg.lr)
key = jax.random.PRNGKey(0)
params = init_gpo_params(gcfg, key)
groups = jnp.arange(C, dtype=jnp.int32)
weights = normalize_weights(data.sizes[groups])
keys = jax.random.split(jax.random.PRNGKey(1), C)

client_params = broadcast_to_clients(params, C)
opt_states = jax.vmap(opt.init)(client_params)

# --- reference: vmap engine ---
local_train = _make_local_train(gcfg, fcfg, data, opt)
cp_v, os_v, losses_v = jax.jit(jax.vmap(local_train))(
    client_params, opt_states, keys, groups)
global_v = fedavg_stacked(cp_v, weights)

# --- shard_map engine on an 8-device 'data' mesh ---
mesh = jax.make_mesh((8,), ("data",))
round_fn = make_sharded_round(gcfg, fcfg, data, mesh, client_axes=("data",),
                              opt=opt)
agg = make_aggregator(fcfg.agg, num_clients=C)
srv = agg.init(params)
spec = NamedSharding(mesh, P("data"))
put = lambda t: jax.tree.map(
    lambda x: jax.device_put(x, spec), t)
put_repl = lambda t: jax.tree.map(
    lambda x, s: jax.device_put(x, s), t, server_state_shardings(t, mesh))
cp_s, os_s, losses_s, srv_s = jax.jit(round_fn)(
    put(client_params), put(opt_states), put(keys), put(groups),
    put(weights), put_repl(srv))

# every client shard must now hold the SAME global params == vmap result
ok_losses = np.allclose(np.asarray(losses_v), np.asarray(losses_s),
                        rtol=1e-4, atol=1e-5)
errs = []
for a, b in zip(jax.tree.leaves(global_v), jax.tree.leaves(cp_s)):
    b0 = np.asarray(b)[0]
    errs.append(float(np.max(np.abs(np.asarray(a) - b0))))
clients_equal = all(
    np.allclose(np.asarray(b)[0], np.asarray(b)[-1], rtol=1e-5, atol=1e-6)
    for b in jax.tree.leaves(cp_s))
print(json.dumps({"ok_losses": bool(ok_losses),
                  "max_err": max(errs),
                  "clients_equal": bool(clients_equal)}))
"""


@pytest.mark.slow
def test_shard_map_round_matches_vmap():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SCRIPT], cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), env=env,
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result["ok_losses"]
    assert result["max_err"] < 1e-4
    assert result["clients_equal"]
