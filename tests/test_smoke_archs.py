"""Per-architecture smoke tests (deliverable f).

Each assigned architecture is instantiated as its REDUCED variant
(2 layers, d_model <= 512, <= 4 experts) and runs one forward and one
train step on CPU, asserting output shapes and the absence of NaNs.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, get_arch, smoke_variant
from repro.core import make_train_step
from repro.models import forward, init_params
from repro.optim import adam

B, S = 2, 32


def _inputs(cfg, key, with_labels=False):
    kw = {}
    if cfg.input_kind == "embeddings":
        kw["embeds"] = jax.random.normal(key, (B, S, cfg.d_model))
    else:
        kw["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.is_encoder_decoder:
        kw["enc_embeds"] = jax.random.normal(
            key, (B, cfg.enc_seq_len, cfg.d_model))
    if with_labels:
        kw["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return kw


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_no_nans(arch, rng):
    cfg = smoke_variant(get_arch(arch))
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.num_experts <= 4
    params = init_params(cfg, rng)
    logits, cache, aux = forward(params, cfg, **_inputs(cfg, rng))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert jnp.isfinite(jnp.asarray(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch, rng):
    cfg = smoke_variant(get_arch(arch))
    params = init_params(cfg, rng)
    opt = adam(1e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    batch = _inputs(cfg, rng, with_labels=True)
    new_params, opt_state, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert jnp.isfinite(loss) and loss > 0
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), params, new_params)
    assert any(jax.tree.leaves(moved))


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-780m",
                                  "zamba2-1.2b", "grok-1-314b",
                                  "whisper-small"])
def test_prefill_decode_shapes(arch, rng):
    cfg = smoke_variant(get_arch(arch))
    params = init_params(cfg, rng)
    kw = _inputs(cfg, rng)
    kw.pop("embeds", None)
    if "tokens" not in kw:
        kw["tokens"] = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    logits, cache, _ = forward(params, cfg, prefill_len=S + 4, **kw)
    tok = jax.random.randint(rng, (B, 1), 0, cfg.vocab_size)
    dl, cache2, _ = forward(params, cfg, tokens=tok, cache=cache,
                            cache_pos=jnp.asarray(S, jnp.int32))
    assert dl.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(dl)))
