"""Partial-participation FedAvg (beyond-paper extension) tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FedConfig, GPOConfig
from repro.core import FederatedGPO
from repro.data import SurveyConfig, make_survey_data, split_groups


def _setup(batch_groups):
    data = make_survey_data(SurveyConfig(
        num_groups=10, num_questions=50, d_embed=24, seed=4))
    tr, ev = split_groups(data, seed=4)
    gcfg = GPOConfig(d_embed=24, d_model=32, num_layers=1, num_heads=2,
                     d_ff=64)
    fcfg = FedConfig(num_clients=len(tr), rounds=10, local_epochs=2,
                     batch_groups=batch_groups, num_context=6, num_target=6,
                     eval_every=5, seed=4)
    return FederatedGPO(gcfg, fcfg, data, tr, ev)


def test_subsampled_round_learns():
    fed = _setup(batch_groups=3)
    hist = fed.run(rounds=12)
    assert len(hist.round_loss) == 12
    # per-round losses come from exactly 3 participants
    assert hist.round_loss[-1] < hist.round_loss[0]


def test_full_participation_unchanged():
    """batch_groups=0 must behave as the paper's all-clients protocol."""
    fed_full = _setup(batch_groups=0)
    h1 = fed_full.run(rounds=5)
    fed_zero = _setup(batch_groups=10_000)  # clipped to num_clients
    h2 = fed_zero.run(rounds=5)
    np.testing.assert_allclose(h1.round_loss, h2.round_loss, rtol=1e-5)


def _replay_sampled_sets(seed, num_clients, m, rounds):
    """Host replay of the round-key chain (PRNGKey(seed+1); per round
    k, k_round, _ = split(k, 3); k_sub, _ = split(k_round)) — the same
    derivation both drivers trace, so this predicts the sampled sets."""
    key = jax.random.PRNGKey(seed + 1)
    out = []
    for _ in range(rounds):
        key, k_round, _ = jax.random.split(key, 3)
        k_sub, _ = jax.random.split(k_round)
        idx = jax.random.choice(k_sub, num_clients, (m,), replace=False)
        out.append(sorted(int(i) for i in np.asarray(idx)))
    return out


def test_batch_groups_one():
    """The degenerate cohort of a single client per round still trains
    (weights renormalize to [1.0]) and touches exactly one opt state."""
    fed = _setup(batch_groups=1)
    hist = fed.run(rounds=1, engine="loop")
    assert np.isfinite(hist.round_loss).all()
    steps = np.asarray(fed.opt_states.step)
    (touched,) = np.nonzero(steps > 0)
    assert touched.size == 1
    (expected,) = _replay_sampled_sets(4, len(fed.train_groups), 1, 1)
    assert touched.tolist() == expected
    hist2 = fed.run(rounds=8, engine="scan")
    assert np.isfinite(hist2.round_loss).all()


def test_batch_groups_equals_num_clients_is_full_participation():
    """batch_groups == C takes the full-participation trace (idx becomes
    arange, no random.choice) — BIT-equal, not merely close."""
    fed_full = _setup(batch_groups=0)
    h_full = fed_full.run(rounds=4)
    fed_c = _setup(batch_groups=len(fed_full.train_groups))
    h_c = fed_c.run(rounds=4)
    assert h_full.round_loss == h_c.round_loss  # floats, bit-for-bit
    for a, b in zip(jax.tree.leaves(fed_full.global_params),
                    jax.tree.leaves(fed_c.global_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sampled_sets_deterministic_across_engines():
    """Same seed => the same per-round cohorts in both drivers. The set
    each engine consumed is observed through which per-client opt states
    advanced, and both must equal the host replay of the key chain."""
    observed, expected = {}, None
    for engine in ("loop", "scan"):
        fed = _setup(batch_groups=3)
        expected = _replay_sampled_sets(4, len(fed.train_groups), 3, 1)[0]
        fed.run(rounds=1, engine=engine)
        steps = np.asarray(fed.opt_states.step)
        observed[engine] = sorted(np.nonzero(steps > 0)[0].tolist())
        assert len(observed[engine]) == 3
    assert observed["loop"] == observed["scan"] == expected
