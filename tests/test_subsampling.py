"""Partial-participation FedAvg (beyond-paper extension) tests."""
import jax.numpy as jnp
import numpy as np

from repro.configs import FedConfig, GPOConfig
from repro.core import FederatedGPO
from repro.data import SurveyConfig, make_survey_data, split_groups


def _setup(batch_groups):
    data = make_survey_data(SurveyConfig(
        num_groups=10, num_questions=50, d_embed=24, seed=4))
    tr, ev = split_groups(data, seed=4)
    gcfg = GPOConfig(d_embed=24, d_model=32, num_layers=1, num_heads=2,
                     d_ff=64)
    fcfg = FedConfig(num_clients=len(tr), rounds=10, local_epochs=2,
                     batch_groups=batch_groups, num_context=6, num_target=6,
                     eval_every=5, seed=4)
    return FederatedGPO(gcfg, fcfg, data, tr, ev)


def test_subsampled_round_learns():
    fed = _setup(batch_groups=3)
    hist = fed.run(rounds=12)
    assert len(hist.round_loss) == 12
    # per-round losses come from exactly 3 participants
    assert hist.round_loss[-1] < hist.round_loss[0]


def test_full_participation_unchanged():
    """batch_groups=0 must behave as the paper's all-clients protocol."""
    fed_full = _setup(batch_groups=0)
    h1 = fed_full.run(rounds=5)
    fed_zero = _setup(batch_groups=10_000)  # clipped to num_clients
    h2 = fed_zero.run(rounds=5)
    np.testing.assert_allclose(h1.round_loss, h2.round_loss, rtol=1e-5)
