"""End-to-end behaviour of the paper's system (PluralLLM).

One compact run of the full pipeline: synthetic Pew-style survey ->
frozen-embedding features -> federated GPO training (FedAvg rounds with
local epochs) vs the centralized GPO baseline -> alignment + fairness
evaluation on unseen groups. Asserts the qualitative paper claims hold in
miniature: both learn; federated achieves comparable alignment and
near-1 fairness index; aggregation weights follow Eq. 2.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FedConfig, GPOConfig
from repro.core import CentralizedGPO, FederatedGPO, normalize_weights
from repro.core.fairness import convergence_round
from repro.data import SurveyConfig, make_survey_data, split_groups

pytestmark = pytest.mark.slow  # paper-experiment in miniature (40 rounds x2)


def test_pluralllm_end_to_end():
    data = make_survey_data(SurveyConfig(
        num_groups=12, num_questions=80, d_embed=32, seed=11))
    tr, ev = split_groups(data, train_frac=0.6, seed=11)
    assert len(tr) == 7 and len(ev) == 5

    gcfg = GPOConfig(d_embed=32, d_model=64, num_layers=2, num_heads=4,
                     d_ff=128)
    fcfg = FedConfig(num_clients=len(tr), rounds=40, local_epochs=3,
                     eval_every=10, num_context=8, num_target=8, seed=11)

    fed = FederatedGPO(gcfg, fcfg, data, tr, ev)
    w = np.asarray(fed.weights)
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-5)
    np.testing.assert_allclose(
        w, np.asarray(normalize_weights(data.sizes[jnp.asarray(tr)])),
        rtol=1e-6)

    hist_fed = fed.run(rounds=40)
    cen = CentralizedGPO(gcfg, fcfg, data, tr, ev)
    hist_cen = cen.run(epochs=40)

    # both engines learn
    assert hist_fed.round_loss[-1] < 0.6 * hist_fed.round_loss[0]
    assert hist_cen.round_loss[-1] < 0.6 * hist_cen.round_loss[0]

    # alignment scores are valid and not degenerate
    assert 0.3 < hist_fed.eval_mean_as[-1] <= 1.0
    # fairness: near-equal opportunity across unseen groups (paper Fig. 5)
    assert hist_fed.eval_fi[-1] > 0.9

    # convergence metric is computable on both curves
    r_fed = convergence_round(hist_fed.round_loss)
    r_cen = convergence_round(hist_cen.round_loss)
    assert 0 <= r_fed < 40 and 0 <= r_cen < 40
