"""Backbone trainer tests: microbatch/remat equivalence, fedavg rounds."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_variant
from repro.core import (
    broadcast_to_clients,
    make_backbone_fedavg_round,
    make_train_step,
    normalize_weights,
)
from repro.data import LMDataConfig, synthetic_lm_batches
from repro.models import init_params
from repro.optim import adam, sgd


def _setup(rng, arch="qwen2-0.5b", batch=4, seq=32):
    cfg = smoke_variant(get_arch(arch))
    params = init_params(cfg, rng)
    it = synthetic_lm_batches(LMDataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch))
    return cfg, params, next(it)


def test_microbatch_equivalence(rng):
    """grad accumulation over microbatches == single-shot gradients (SGD
    makes the param update linear in the gradient)."""
    cfg, params, batch = _setup(rng)
    opt = sgd(1e-2)
    s1 = jax.jit(make_train_step(cfg, opt, microbatch=1))
    s2 = jax.jit(make_train_step(cfg, opt, microbatch=2))
    p1, _, m1 = s1(params, opt.init(params), batch)
    p2, _, m2 = s2(params, opt.init(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)


def test_remat_equivalence(rng):
    cfg, params, batch = _setup(rng)
    opt = sgd(1e-2)
    s1 = jax.jit(make_train_step(cfg, opt, remat=False))
    s2 = jax.jit(make_train_step(cfg, opt, remat=True))
    p1, _, m1 = s1(params, opt.init(params), batch)
    p2, _, m2 = s2(params, opt.init(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)


def test_backbone_fedavg_equal_weights_equals_mean(rng):
    """With identical starts and equal weights, Eq. 3 averages the client
    deltas; all clients end the round with identical params."""
    cfg, params, _ = _setup(rng, batch=2)
    c, ls = 3, 2
    opt = adam(1e-3)
    cp = broadcast_to_clients(params, c)
    ost = jax.vmap(opt.init)(cp)
    rnd = jax.jit(make_backbone_fedavg_round(cfg, opt, ls))
    it = synthetic_lm_batches(LMDataConfig(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=2, seed=5))
    batches = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[jax.tree.map(lambda *ys: jnp.stack(ys),
                       *[next(it) for _ in range(ls)]) for _ in range(c)])
    w = normalize_weights(jnp.ones((c,)))
    cp2, _, losses = rnd(cp, ost, batches, w)
    assert losses.shape == (c,)
    leaf = jax.tree.leaves(cp2)[1]
    np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[1]),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[2]),
                               rtol=1e-6)


def test_vlm_and_encdec_train_steps(rng):
    """embeddings-input (llava) and enc-dec (whisper) batches train."""
    for arch in ["llava-next-34b", "whisper-small"]:
        cfg = smoke_variant(get_arch(arch))
        params = init_params(cfg, rng)
        b, s = 2, 16
        batch = {"labels": jax.random.randint(rng, (b, s), 0,
                                              cfg.vocab_size)}
        if cfg.input_kind == "embeddings":
            batch["embeds"] = jax.random.normal(rng, (b, s, cfg.d_model))
        else:
            batch["tokens"] = jax.random.randint(rng, (b, s), 0,
                                                 cfg.vocab_size)
        if cfg.is_encoder_decoder:
            batch["enc_embeds"] = jax.random.normal(
                rng, (b, cfg.enc_seq_len, cfg.d_model))
        opt = adam(1e-3)
        step = jax.jit(make_train_step(cfg, opt))
        _, _, m = step(params, opt.init(params), batch)
        assert jnp.isfinite(m["loss"]), arch
